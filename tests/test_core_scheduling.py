"""Unit + property tests: latency planes, T_tx tracking, CI decision rule."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.latency_model import DeviceProfile, LinearLatencyModel, bytes_for_tokens
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import CLOUD, EDGE, CNMTScheduler, StaticScheduler
from repro.core.tx_estimator import TxEstimator


# ---------------------------------------------------------------- latency --
def test_latency_plane_exact_fit():
    rng = np.random.default_rng(0)
    n = rng.uniform(1, 100, 400)
    m = rng.uniform(1, 100, 400)
    t = 2e-3 * n + 7e-3 * m + 0.05
    lm = LinearLatencyModel().fit(n, m, t)
    assert lm.alpha_n == pytest.approx(2e-3, rel=1e-3)
    assert lm.alpha_m == pytest.approx(7e-3, rel=1e-3)
    assert lm.beta == pytest.approx(0.05, rel=1e-2)
    assert lm.r2(n, m, t) > 0.999


def test_scaled_device_is_uniformly_faster():
    lm = LinearLatencyModel(1e-3, 5e-3, 0.02)
    fast = lm.scaled(4.0)
    n, m = np.array([10.0, 50.0]), np.array([12.0, 40.0])
    assert np.allclose(np.asarray(fast.predict(n, m)),
                       np.asarray(lm.predict(n, m)) / 4.0, rtol=1e-6)


def test_roofline_constructed_plane():
    lm = LinearLatencyModel.from_roofline(
        prefill_flops_per_token=2e9,
        decode_flops_per_token=2e9,
        decode_bytes_per_token=16e9,   # memory-bound decode
        peak_flops=197e12, hbm_bw=819e9, mfu=0.5, overhead_s=0.001,
    )
    # decode term must be the max(compute, memory) = memory path
    assert lm.alpha_m == pytest.approx(16e9 / 819e9, rel=1e-6)
    assert lm.alpha_n == pytest.approx(2e9 / (0.5 * 197e12), rel=1e-6)
    assert lm.beta == 0.001


def test_true_time_noise_bounded_and_positive():
    dp = DeviceProfile("d", LinearLatencyModel(0, 1e-3, 0.01), noise_frac=0.1)
    rng = np.random.default_rng(0)
    t = dp.true_time(np.full(1000, 10.0), np.full(1000, 10.0), rng)
    base = 0.02
    assert np.all(t > 0)
    assert np.all(t <= base * (1 + 0.1 * 3) + 1e-9)
    assert np.all(t >= base * (1 - 0.1 * 3) - 1e-9)


# --------------------------------------------------------------------- tx --
def test_tx_estimator_ewma_converges():
    est = TxEstimator(alpha=0.5, init_rtt_s=0.5)
    for i in range(50):
        est.observe(float(i), 0.02)
    assert est.rtt(50.0) == pytest.approx(0.02, rel=1e-3)


def test_tx_estimator_last_mode_tracks_instantly():
    est = TxEstimator(mode="last", init_rtt_s=0.5)
    est.observe(0.0, 0.1)
    est.observe(1.0, 0.3)
    assert est.rtt(2.0) == 0.3


def test_tx_estimator_staleness_probe():
    est = TxEstimator(max_age_s=10.0, init_rtt_s=0.5)
    probe = lambda t: 0.03
    r = est.rtt(100.0, probe_fn=probe)
    assert r == pytest.approx(0.03, rel=0.5)
    assert est.n_probes == 1
    # fresh estimate -> no second probe
    est.rtt(101.0, probe_fn=probe)
    assert est.n_probes == 1


def test_tx_estimator_drops_out_of_order_samples():
    """Causal ordering: a sample older than the newest ingested one must
    not move the EWMA or rewind ``_last_update``."""
    est = TxEstimator(alpha=0.5, init_rtt_s=0.1)
    est.observe(10.0, 0.1)
    before = est.rtt(10.0)
    est.observe(5.0, 5.0)                 # stale: completed out of order
    assert est.rtt(10.0) == before
    assert est.n_samples == 1 and est.n_stale == 1
    assert est._last_update == 10.0
    est.observe(10.0, 0.2)                # equal timestamps are fine
    assert est.n_samples == 2


def test_tx_time_includes_bandwidth_term():
    est = TxEstimator(init_rtt_s=0.010, bandwidth_bps=100e6)
    # 1 MB payload at 100 Mbps = 80 ms
    assert est.tx_time(0.0, 1e6) == pytest.approx(0.010 + 0.08, rel=1e-6)


def test_bytes_for_tokens_paper_encoding():
    assert np.asarray(bytes_for_tokens(10)).item() == 20  # 2 bytes/token §II


# -------------------------------------------------------------- scheduler --
def _mk_pair(edge_speed=1.0, cloud_speedup=5.0):
    edge_lm = LinearLatencyModel(2e-3, 8e-3, 0.01).scaled(edge_speed)
    cloud_lm = LinearLatencyModel(2e-3, 8e-3, 0.01).scaled(cloud_speedup)
    return (DeviceProfile("e", edge_lm, 0.0), DeviceProfile("c", cloud_lm, 0.0))


def test_decision_rule_eq1_short_edge_long_cloud():
    """Paper Fig. 2b: short sequences -> Edge Region, long -> Cloud Region."""
    edge, cloud = _mk_pair()
    sched = CNMTScheduler(edge=edge, cloud=cloud, n2m=LinearN2M(1.0, 0.0))
    tx = TxEstimator(init_rtt_s=0.05)
    short = sched.decide(2, 0.0, tx)
    long = sched.decide(200, 0.0, tx)
    assert short.device == EDGE
    assert long.device == CLOUD


def test_decision_flips_with_rtt():
    """Higher RTT shifts the cloud plane up -> edge region grows (Fig. 2b)."""
    edge, cloud = _mk_pair()
    n2m = LinearN2M(1.0, 0.0)
    sched = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
    n = 20
    fast = sched.decide(n, 0.0, TxEstimator(init_rtt_s=0.001))
    slow = sched.decide(n, 0.0, TxEstimator(init_rtt_s=10.0))
    assert fast.device == CLOUD
    assert slow.device == EDGE


def test_hedge_margin_prefers_edge_near_breakeven():
    edge, cloud = _mk_pair()
    n2m = LinearN2M(1.0, 0.0)
    base = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
    hedged = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m, hedge_margin_s=1e9)
    tx = TxEstimator(init_rtt_s=0.001)
    assert base.decide(200, 0.0, tx).device == CLOUD
    assert hedged.decide(200, 0.0, tx).device == EDGE  # absurd margin -> all edge


def test_decide_batch_matches_decide():
    edge, cloud = _mk_pair()
    sched = CNMTScheduler(edge=edge, cloud=cloud, n2m=LinearN2M(0.9, 1.0))
    ns = np.array([2, 10, 50, 120, 200])
    rtts = np.full(5, 0.05)
    batch = sched.decide_batch(ns, rtts)
    for i, n in enumerate(ns):
        d = sched.decide(int(n), 0.0, TxEstimator(init_rtt_s=0.05))
        assert batch[i] == d.device


def test_decide_batch_uses_configured_bandwidth():
    """Regression: the payload term was hardcoded to 100 Mbps.  On a slow
    link the serialization delay must push borderline requests back to
    the edge."""
    edge, cloud = _mk_pair()
    sched = CNMTScheduler(edge=edge, cloud=cloud, n2m=LinearN2M(1.0, 0.0))
    ns = np.arange(2, 300)
    rtts = np.full(ns.shape, 0.01)
    dev_fast = sched.decide_batch(ns, rtts)
    dev_slow = sched.decide_batch(ns, rtts, bandwidth_bps=1e3)
    assert not np.array_equal(dev_fast, dev_slow)
    assert (dev_slow == EDGE).sum() > (dev_fast == EDGE).sum()
    # exact arithmetic of the slow-link payload term for one request
    n, m_hat = 100.0, 100.0
    payload = bytes_for_tokens(n + m_hat, 2)
    t_e = float(np.asarray(edge.model.predict(n, m_hat)))
    t_c = float(np.asarray(cloud.model.predict(n, m_hat))) \
        + 0.01 + payload * 8.0 / 1e3
    want = EDGE if t_e <= t_c else CLOUD
    assert sched.decide_batch(np.array([n]), np.array([0.01]),
                              bandwidth_bps=1e3)[0] == want


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 300),
    rtt=st.floats(1e-4, 1.0),
    speedup=st.floats(1.5, 20.0),
)
def test_property_decision_optimal_under_own_model(n, rtt, speedup):
    """Eq. (1) is optimal by construction *under the scheduler's model*:
    the predicted time of the chosen device never exceeds the other's."""
    edge, cloud = _mk_pair(cloud_speedup=speedup)
    sched = CNMTScheduler(edge=edge, cloud=cloud, n2m=LinearN2M(1.0, 0.0))
    d = sched.decide(n, 0.0, TxEstimator(init_rtt_s=rtt))
    if d.device == EDGE:
        assert d.t_edge_pred <= d.t_cloud_pred + 1e-12
    else:
        assert d.t_cloud_pred < d.t_edge_pred + 1e-12


def test_static_schedulers():
    gw = StaticScheduler(EDGE)
    sv = StaticScheduler(CLOUD)
    n = np.arange(5)
    assert np.all(gw.decide_batch(n, None) == EDGE)
    assert np.all(sv.decide_batch(n, None) == CLOUD)
    assert gw.name == "gw" and sv.name == "server"
