"""Data substrate tests: synthetic corpora, tokenizer, batching."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import (
    TokenBatcher,
    bucket_by_length,
    lm_batches,
    padded_batches,
)
from repro.data.synthetic import LANGUAGE_PAIRS, make_corpus
from repro.data.tokenizer import BOS_ID, EOS_ID, PAD_ID, HashTokenizer


# ------------------------------------------------------------- synthetic --
def test_corpus_statistics_match_pair():
    for pair, lp in LANGUAGE_PAIRS.items():
        c = make_corpus(pair, 20000, seed=0)
        # verbosity slope recovered from the raw (unfiltered) corpus
        slope = np.polyfit(c.n, c.m_real, 1)[0]
        assert abs(slope - lp.gamma) < 0.12, pair
        assert c.n.min() >= lp.min_len and c.n.max() <= lp.max_len


def test_corpus_split_is_disjoint_head_tail():
    c = make_corpus("de-en", 100, seed=1, with_tokens=True)
    a, b = c.split(30)
    assert len(a) == 30 and len(b) == 70
    assert np.array_equal(np.concatenate([a.n, b.n]), c.n)
    assert len(a.src) == 30 and len(b.src) == 70


def test_corpus_deterministic():
    a = make_corpus("en-zh", 500, seed=5)
    b = make_corpus("en-zh", 500, seed=5)
    assert np.array_equal(a.n, b.n) and np.array_equal(a.m_out, b.m_out)


# ------------------------------------------------------------- tokenizer --
def test_tokenizer_stable_and_bounded():
    tok = HashTokenizer(1000)
    ids = tok.encode("the quick brown fox")
    assert ids == tok.encode("the quick brown fox")
    assert ids[-1] == EOS_ID
    assert all(0 <= i < 1000 for i in ids)
    assert tok.encode("hello", add_bos=True)[0] == BOS_ID


def test_tokenizer_decode_stops_at_eos():
    tok = HashTokenizer(1000)
    ids = tok.encode("a b") + [77]
    text = tok.decode(ids)
    assert "<w77>" not in text          # after EOS


# --------------------------------------------------------------- batching --
def test_bucket_by_length():
    buckets = bucket_by_length([3, 10, 40, 200], boundaries=(16, 64))
    assert buckets[0] == [0, 1]
    assert buckets[1] == [2]
    assert buckets[2] == [3]


def test_padded_batches_shapes_and_masks():
    c = make_corpus("de-en", 200, seed=2, with_tokens=True)
    seen = 0
    for b in padded_batches(c.src, c.tgt, batch_size=16, max_len=64):
        B, N = b["src"].shape
        _, M = b["tgt_in"].shape
        assert b["tgt_out"].shape == (B, M)
        assert b["src_mask"].shape == (B, N)
        # BOS-shifted: tgt_in starts with BOS, tgt_out ends with EOS
        assert (b["tgt_in"][:, 0] == BOS_ID).all()
        row_lens = (b["tgt_out"] != PAD_ID).sum(1)
        for i, L in enumerate(row_lens):
            assert b["tgt_out"][i, L - 1] == EOS_ID
        seen += B
    assert seen == 200                  # every pair appears exactly once


def test_lm_batches_next_token_alignment():
    stream = np.arange(1000, dtype=np.int32)
    for b in lm_batches(stream, batch_size=2, seq_len=8, seed=0):
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_token_batcher_respects_budget():
    tb = TokenBatcher(max_batch=8, max_tokens_per_batch=64)
    rng = np.random.default_rng(0)
    for i in range(20):
        tb.add(i, rng.integers(1, 100, rng.integers(4, 30)))
    total = 0
    while len(tb):
        ids, batch = tb.next_batch()
        assert batch.shape[0] == len(ids) <= 8
        assert batch.size <= 64 or batch.shape[0] == 1
        total += len(ids)
    assert total == 20


def test_token_batcher_length_only_mode():
    """The DES drain path carries only lengths; bucketing and budgets
    must behave exactly like the token path."""
    tb = TokenBatcher(max_batch=3, max_tokens_per_batch=1 << 20)
    lengths = [30, 4, 28, 5, 6]
    for i, L in enumerate(lengths):
        tb.add(i, length=L)
    ids, width = tb.next_batch_ids()
    assert ids == [1, 3, 4]               # shortest three bucket together
    assert width == 6
    ids2, width2 = tb.next_batch_ids()
    assert ids2 == [2, 0] and width2 == 30
    assert tb.next_batch_ids() is None and len(tb) == 0
    with pytest.raises(ValueError):
        tb.add(9)                         # neither tokens nor length


def test_token_batcher_mixed_batch_requires_tokens():
    tb = TokenBatcher(max_batch=4)
    tb.add(0, np.ones(3, np.int32))
    tb.add(1, np.ones(5, np.int32))
    ids, batch = tb.next_batch()
    assert ids == [0, 1] and batch.shape == (2, 5)


@settings(max_examples=20, deadline=None)
@given(sizes=st.lists(st.integers(1, 50), min_size=1, max_size=30))
def test_property_batcher_serves_all_exactly_once(sizes):
    tb = TokenBatcher(max_batch=4, max_tokens_per_batch=128)
    for i, s in enumerate(sizes):
        tb.add(i, np.ones(s, np.int32))
    served = []
    while len(tb):
        ids, _ = tb.next_batch()
        served += ids
    assert sorted(served) == list(range(len(sizes)))
