"""Run every example script in smoke mode so example drift fails tier-1.

Each ``examples/*.py`` honours ``REPRO_SMOKE=1`` (shrunk request
streams / step counts); this test executes each one in a fresh
interpreter — an example that raises, asserts, or rots against the API
fails the suite instead of rotting silently.  The re-anchor at PR 5
deleted the original file and left only its ``.pyc`` ghost; this is the
restored surface.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

_ROOT = Path(__file__).resolve().parent.parent
_EXAMPLES = sorted((_ROOT / "examples").glob("*.py"))


def test_every_example_is_covered():
    """The parametrized list below must track examples/ exactly."""
    assert [p.name for p in _EXAMPLES] == [
        "big_model_serving.py",
        "collaborative_serving.py",
        "continuous_serving.py",
        "fault_tolerant_serving.py",
        "multitier_serving.py",
        "partitioned_serving.py",
        "quickstart.py",
        "train_nmt.py",
    ]


@pytest.mark.slow
@pytest.mark.parametrize("script", _EXAMPLES, ids=lambda p: p.stem)
def test_example_runs_in_smoke_mode(script, tmp_path):
    env = dict(os.environ,
               REPRO_SMOKE="1",
               PYTHONPATH=str(_ROOT / "src"),
               # keep any example's checkpoint/json artifacts out of the
               # repo and isolated per test run
               TMPDIR=str(tmp_path))
    proc = subprocess.run(
        [sys.executable, str(script)],
        cwd=str(tmp_path), env=env,
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"{script.name} failed (exit {proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout[-4000:]}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}")
    assert proc.stdout.strip(), f"{script.name} printed nothing"
