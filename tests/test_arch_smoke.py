"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as the REDUCED same-family
variant (<=2 layers per group kind, d_model<=512, <=4 experts) and runs
train / prefill / decode steps on CPU, asserting shapes and finiteness.
The FULL configs are exercised only by the dry-run (launch/dryrun.py).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config, smoke_config
from repro.models.model import LM

B, S = 2, 16


def _inputs(cfg, key, s=S):
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (B, s)).astype(np.int32)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder.max_frames, cfg.d_model)),
            jnp.float32)
    return jnp.asarray(toks), kw


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_forward(arch):
    cfg = smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks, kw = _inputs(cfg, 0)
    out = jax.jit(lambda p, t: model.train_logits(p, t, **kw))(params, toks)
    assert out["logits"].shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(out["logits"]).all()), f"{arch}: NaN in logits"
    assert bool(jnp.isfinite(out["aux_loss"]))
    if cfg.mtp_depth:
        assert out["mtp_logits"].shape == (B, S, cfg.vocab_size)
        assert bool(jnp.isfinite(out["mtp_logits"]).all())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step_grads(arch):
    """One SGD step: grads exist, are finite, and change the loss."""
    cfg = smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks, kw = _inputs(cfg, 0)
    targets = jnp.roll(toks, -1, axis=1)

    def loss_fn(p):
        out = model.train_logits(p, toks, **kw)
        logits = out["logits"].astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
        return (logz - gold).mean() + 0.01 * out["aux_loss"]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: NaN grad"
    # at least some gradient signal
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_then_decode(arch):
    cfg = smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks, kw = _inputs(cfg, 0)
    max_len = S + 4

    logits, state = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=max_len, **kw)
    )(params, toks)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(state["pos"][0]) == S

    step = jax.jit(lambda p, st, tk: model.decode_step(p, st, tk))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, state = step(params, state, tok)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN in decode"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    assert int(state["pos"][0]) == S + 3


@pytest.mark.parametrize("arch", ["qwen3-8b", "zamba2-1.2b", "rwkv6-3b",
                                  "deepseek-v3-671b"])
def test_decode_matches_train_forward(arch):
    """prefill+decode logits == teacher-forced forward logits (same tokens).

    The strongest cache-correctness check: runs the *whole model* both
    ways. (For archs whose decode path is exactly the full path's math.)
    """
    cfg = smoke_config(arch)
    # rwkv chunk=32 demands seq%32==0 on the full path; use s=32 inputs
    s = 32
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, s)), jnp.int32)

    out = model.train_logits(params, toks)
    full_logits = out["logits"]                      # (B,s,V)

    k = 4  # decode the last k tokens incrementally
    _, state = model.prefill(params, toks[:, : s - k], max_len=s)
    step = jax.jit(lambda p, st, tk: model.decode_step(p, st, tk))
    for t in range(s - k, s):
        logits, state = step(params, state, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t, :]),
            rtol=2e-3, atol=2e-3,
        )


def test_assigned_dims_match_assignment():
    """The FULL configs carry the exact assigned hyper-parameters."""
    expect = {
        "rwkv6-3b": dict(num_layers=32, d_model=2560, vocab_size=65536),
        "whisper-large-v3": dict(num_layers=32, d_model=1280,
                                 vocab_size=51866, num_heads=20),
        "moonshot-v1-16b-a3b": dict(num_layers=48, d_model=2048,
                                    vocab_size=163840),
        "qwen3-moe-30b-a3b": dict(num_layers=48, d_model=2048,
                                  vocab_size=151936, num_heads=32,
                                  num_kv_heads=4),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, vocab_size=32000),
        "qwen3-32b": dict(num_layers=64, d_model=5120, vocab_size=151936,
                          num_heads=64, num_kv_heads=8, d_ff=25600),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168,
                                 vocab_size=129280, num_heads=128),
        "deepseek-67b": dict(num_layers=95, d_model=8192,
                             vocab_size=102400, d_ff=22016),
        "qwen3-8b": dict(num_layers=36, d_model=4096, vocab_size=151936,
                         d_ff=12288),
        "chameleon-34b": dict(num_layers=48, d_model=8192, vocab_size=65536,
                              d_ff=22016),
    }
    for arch, exp in expect.items():
        cfg = get_config(arch)
        for k, v in exp.items():
            got = getattr(cfg, k) if k != "num_layers" else cfg.num_layers
            assert got == v, f"{arch}.{k}: {got} != {v}"


def test_moe_expert_counts():
    assert get_config("qwen3-moe-30b-a3b").moe.num_experts == 128
    assert get_config("qwen3-moe-30b-a3b").moe.top_k == 8
    assert get_config("deepseek-v3-671b").moe.num_experts == 256
    assert get_config("deepseek-v3-671b").moe.top_k == 8
    assert get_config("deepseek-v3-671b").moe.num_shared_experts == 1
    assert get_config("moonshot-v1-16b-a3b").moe.num_experts == 64
    assert get_config("moonshot-v1-16b-a3b").moe.top_k == 6


def test_param_counts_sane():
    """Total param counts are in the advertised ballpark."""
    cases = {
        "deepseek-v3-671b": (550e9, 780e9),
        "deepseek-67b": (55e9, 80e9),
        "qwen3-32b": (25e9, 40e9),
        "qwen3-8b": (6e9, 10e9),
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        # assignment dims (48L x 64e) give ~28B, larger than the real
        # 27-layer Moonlight-16B; the assigned numbers are authoritative
        "moonshot-v1-16b-a3b": (25e9, 32e9),
        "chameleon-34b": (30e9, 40e9),
        "rwkv6-3b": (2e9, 4e9),
        "zamba2-1.2b": (0.9e9, 1.9e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
    # MoE active << total
    ds = get_config("deepseek-v3-671b").param_counts()
    assert ds["active"] < 0.12 * ds["total"]


def test_long_500k_support_flags():
    from repro.configs import shape_supported
    ok = {a for a in ARCH_NAMES if shape_supported(a, "long_500k")[0]}
    assert ok == {"rwkv6-3b", "zamba2-1.2b", "qwen3-8b"}
    for a in ARCH_NAMES:
        assert shape_supported(a, "train_4k")[0]
