"""Regression tests for §II-C staleness dynamics (the T_tx estimate moves
ONLY on offloaded requests) and for CollaborativeEngine.stats() math on a
deterministic seeded run."""

import dataclasses

import numpy as np
import pytest

from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import CLOUD, EDGE, CNMTScheduler
from repro.core.simulator import RequestStream, simulate
from repro.core.tx_estimator import TxEstimator
from repro.runtime.engine import CollaborativeEngine, Tier


# --------------------------------------------------- §II-C staleness -------
def test_tx_estimate_frozen_while_traffic_stays_local():
    """In the analytic replay, an all-edge run must leave the estimator
    exactly at its initial value: zero samples, zero drift."""
    edge = DeviceProfile("e", LinearLatencyModel(1e-4, 1e-4, 1e-4), 0.0)
    slow_cloud = DeviceProfile("c", edge.model.scaled(0.1), 0.0)
    profile = make_profile("cp1", seed=1)
    rng = np.random.default_rng(0)
    k = 500
    n = rng.integers(2, 200, k).astype(np.float64)
    stream = RequestStream(np.sort(rng.uniform(0, 3600, k)), n, n, n)
    est = TxEstimator(init_rtt_s=0.123)
    r = simulate(CNMTScheduler(edge=edge, cloud=slow_cloud,
                               n2m=LinearN2M(1.0, 0.0)),
                 stream, profile, edge, slow_cloud, seed=0,
                 tx_estimator=est)
    assert r.offload_frac == 0.0
    assert est.n_samples == 0
    assert est.rtt(1e9) == 0.123           # stale forever, per the paper


def test_tx_estimate_updates_exactly_on_offloads():
    """Mixed run: sample count == offload count, and the estimate moved."""
    edge = DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.0)
    cloud = DeviceProfile("c", edge.model.scaled(5.0), 0.0)
    profile = make_profile("cp2", seed=1)
    rng = np.random.default_rng(0)
    k = 800
    n = rng.integers(2, 200, k).astype(np.float64)
    stream = RequestStream(np.sort(rng.uniform(0, 3600, k)), n, n, n)
    est = TxEstimator(init_rtt_s=5.0)      # absurd prior: forces all-edge...
    r = simulate(CNMTScheduler(edge=edge, cloud=cloud,
                               n2m=LinearN2M(1.0, 0.0)),
                 stream, profile, edge, cloud, seed=0, tx_estimator=est,
                 probe_interval_s=600.0)   # ...until a probe corrects it
    n_off = int((r.device == CLOUD).sum())
    assert n_off > 0
    # every offload contributed one timestamped sample; the remainder are
    # the (at most ceil(3600/600)+1) periodic probe refreshes
    assert n_off <= est.n_samples <= n_off + 8
    assert est.rtt(0.0) < 5.0


def test_engine_tx_samples_equal_offload_count():
    edge = Tier(DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.0))
    cloud = Tier(DeviceProfile("c", LinearLatencyModel(4e-4, 1.6e-3, 0.002),
                               0.0))
    profile = make_profile("cp2", seed=7)
    cloud = dataclasses.replace(cloud,
                                rtt_fn=lambda t: float(profile.rtt_at(t)))
    eng = CollaborativeEngine(tiers=[edge, cloud], n2m=LinearN2M(1.0, 0.0),
                              seed=0)
    rng = np.random.default_rng(3)
    for i in range(300):
        eng.submit(np.zeros(int(rng.integers(2, 200)), np.int32),
                   now_s=float(i))
    offloads = sum(r.device == CLOUD for r in eng.results)
    assert 0 < offloads < 300
    assert eng.tx.n_samples == offloads


# ------------------------------------------------------------ stats math ---
def _run_engine(k=400, seed=0):
    edge = Tier(DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.05))
    cloud = Tier(DeviceProfile("c", LinearLatencyModel(4e-4, 1.6e-3, 0.002),
                               0.08))
    profile = make_profile("cp2", seed=3)
    cloud = dataclasses.replace(cloud,
                                rtt_fn=lambda t: float(profile.rtt_at(t)))
    eng = CollaborativeEngine(tiers=[edge, cloud], n2m=LinearN2M(0.9, 2.0),
                              seed=seed)
    rng = np.random.default_rng(42)
    for i in range(k):
        eng.submit(np.zeros(int(rng.integers(2, 200)), np.int32),
                   now_s=float(i))
    return eng


def test_stats_percentiles_and_offload_fraction():
    eng = _run_engine()
    s = eng.stats()
    lat = np.array([r.latency_s for r in eng.results])
    dev = np.array([r.device for r in eng.results])
    assert s["requests"] == 400
    assert s["total_latency_s"] == pytest.approx(lat.sum())
    assert s["mean_latency_s"] == pytest.approx(lat.mean())
    assert s["p50_latency_s"] == pytest.approx(np.percentile(lat, 50))
    assert s["p95_latency_s"] == pytest.approx(np.percentile(lat, 95))
    assert s["offload_frac"] == pytest.approx(np.mean(dev != EDGE))
    assert s["p50_latency_s"] <= s["p95_latency_s"] <= lat.max()
    fr = s["tier_frac"]
    assert sum(fr.values()) == pytest.approx(1.0)
    assert fr["c"] == pytest.approx(s["offload_frac"])
    assert s["rejected"] == 0


def test_stats_deterministic_given_seed():
    a = _run_engine(seed=11).stats()
    b = _run_engine(seed=11).stats()
    assert a == b


def test_stats_empty_engine():
    edge = Tier(DeviceProfile("e", LinearLatencyModel(1e-3, 1e-3, 1e-3), 0.0))
    eng = CollaborativeEngine(tiers=[edge], n2m=LinearN2M(1.0, 0.0), seed=0)
    assert eng.stats() == {}
    assert eng.tx is None
