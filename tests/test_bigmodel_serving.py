"""Big-model tier serving: the unified model/executor API and the
sharded LM sessions.

Covers the PR's acceptance surface:

* ``LM(mixer_impl=...)`` parity — the "pallas" route (rwkv6 prefill via
  ``kernels/ops.rwkv6_wkv``, mamba2 via ``ops.ssd_scan``) is BIT-FOR-BIT
  equal to the "xla" chunked math on CPU (interpret mode traces the same
  jnp ops), at the full-LM level (the raw-kernel parity lives in
  tests/test_kernels.py).
* Sharded-vs-unsharded decode parity — a smoke qwen3-8b / rwkv6-3b
  served through :func:`repro.runtime.sharded.make_sharded_session` on a
  forced 4-device host mesh emits token-identical output to the
  unsharded session, through both ``GenerationSession`` and
  ``ContinuousGenerationSession.serve`` (subprocess tests: the device
  count must be set before jax initializes).
* The unified API itself — ``models.registry.resolve``,
  ``build_executor`` kinds, and the ``DeprecationWarning`` contracts on
  every legacy entry point.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------- mixer_impl parity ----
@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b"])
def test_lm_mixer_impl_pallas_matches_xla_bitwise(arch):
    """Full-LM prefill logits and decode tokens agree bitwise between
    mixer_impl='xla' and 'pallas' (rwkv6 + mamba2-hybrid plans)."""
    import jax
    from repro.configs import smoke_config
    from repro.models.model import LM
    from repro.runtime.serving import GenerationSession

    cfg = smoke_config(arch)
    xla = LM(cfg, mixer_impl="xla")
    pal = LM(cfg, mixer_impl="pallas")
    params = xla.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(4, cfg.vocab_size, (2, 16)).astype(np.int32)

    logits_x, _ = xla.prefill(params, toks, max_len=24)
    logits_p, _ = pal.prefill(params, toks, max_len=24)
    assert np.array_equal(np.asarray(logits_x), np.asarray(logits_p))

    out_x = GenerationSession(xla, params, max_len=24).generate(
        toks, max_new=6)
    out_p = GenerationSession(pal, params, max_len=24).generate(
        toks, max_new=6)
    assert np.array_equal(np.asarray(out_x), np.asarray(out_p))


def test_lm_mixer_impl_validated():
    from repro.configs import smoke_config
    from repro.models.model import LM

    with pytest.raises(ValueError, match="mixer_impl"):
        LM(smoke_config("rwkv6-3b"), mixer_impl="triton")


# --------------------------------------- sharded decode parity ----------
@pytest.mark.slow
@pytest.mark.parametrize("arch,layout", [("qwen3-8b", "auto"),
                                         ("qwen3-8b", "tp"),
                                         ("rwkv6-3b", "auto")])
def test_sharded_session_decode_is_bitwise_equal(arch, layout):
    """GenerationSession over a (2,2) host mesh == unsharded, token for
    token (ragged prompts via generate_with_lengths)."""
    out = _run(f"""
        import jax, numpy as np
        from repro.configs import smoke_config
        from repro.models.model import LM
        from repro.runtime.serving import GenerationSession
        from repro.runtime.sharded import make_sharded_session

        cfg = smoke_config("{arch}")
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        toks = rng.integers(4, cfg.vocab_size, (4, 12)).astype(np.int32)
        lens = np.array([12, 7, 12, 9], np.int32)

        ref = GenerationSession(model, params, max_len=32)
        m_ref, out_ref = ref.generate_with_lengths(toks, max_new=8)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        sess = make_sharded_session(model, params, mesh, max_len=32,
                                    batch_size=4, layout="{layout}")
        m_s, out_s = sess.generate_with_lengths(toks, max_new=8)
        assert np.array_equal(np.asarray(m_ref), np.asarray(m_s))
        assert np.array_equal(np.asarray(out_ref), np.asarray(out_s))
        print("layout", sess.layout, "equal True")
    """)
    assert "equal True" in out


@pytest.mark.slow
def test_sharded_continuous_session_matches_unsharded():
    """ContinuousGenerationSession.serve over the mesh == unsharded
    (slot-table in-flight batching on sharded params)."""
    out = _run("""
        import jax, numpy as np
        from repro.configs import smoke_config
        from repro.models.model import LM
        from repro.runtime.serving import ContinuousGenerationSession
        from repro.runtime.sharded import make_sharded_session

        cfg = smoke_config("qwen3-8b")
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        prompts = [rng.integers(4, cfg.vocab_size,
                                int(rng.integers(4, 12))).astype(np.int32)
                   for _ in range(6)]

        ref = ContinuousGenerationSession(model, params, max_slots=4,
                                          max_len=32)
        got_ref = ref.serve(prompts, max_new=6)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        sess = make_sharded_session(model, params, mesh, continuous=True,
                                    max_slots=4, max_len=32, batch_size=4)
        got = sess.serve(prompts, max_new=6)
        assert len(got) == len(got_ref)
        for (m_a, t_a), (m_b, t_b) in zip(got_ref, got):
            assert m_a == m_b
            assert np.array_equal(np.asarray(t_a), np.asarray(t_b))
        print("continuous equal True")
    """)
    assert "continuous equal True" in out


# ----------------------------------------------- unified registry -------
def test_registry_resolves_lm_and_cnmt_names():
    from repro.models.model import LM
    from repro.models.registry import available, resolve

    r = resolve("qwen3_8b")                 # underscore form normalizes
    assert r.family == "lm" and r.name == "qwen3-8b"
    assert isinstance(r.model, LM) and r.pair is None
    assert r.cfg.d_model == 256             # size="smoke" default

    r2 = resolve("cnmt:en-de", scale=0.1, vocab=128)
    assert r2.family == "nmt" and r2.pair == "de-en"
    assert r2.name == "cnmt:de-en"          # direction normalized

    names = available()
    assert "cnmt:de-en" in names and "qwen3-8b" in names

    with pytest.raises(KeyError, match="available"):
        resolve("not-a-model")
    with pytest.raises(ValueError, match="size"):
        resolve("qwen3-8b", size="huge")


def test_registry_threads_mixer_impl():
    from repro.models.registry import resolve

    assert resolve("rwkv6-3b", mixer_impl="pallas").model.mixer_impl == \
        "pallas"


def test_make_paper_model_shim_warns_and_delegates():
    from repro.nmt import GRUSeq2Seq
    from repro.nmt.registry import make_paper_model

    with pytest.warns(DeprecationWarning, match="make_paper_model"):
        model, pair = make_paper_model("fr-en", scale=0.1, vocab=128)
    assert isinstance(model, GRUSeq2Seq) and pair == "fr-en"


# ----------------------------------------------- unified executors ------
@pytest.fixture(scope="module")
def lm_session():
    import jax
    from repro.configs import smoke_config
    from repro.models.model import LM
    from repro.runtime.serving import GenerationSession

    cfg = smoke_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, GenerationSession(model, params, max_len=32)


def test_build_executor_solo_and_alias_agree(lm_session):
    from repro.runtime.serving import build_executor, make_tier_executor

    cfg, sess = lm_session
    new = build_executor(sess, kind="solo", max_new=4,
                         vocab_clip=cfg.vocab_size)
    with pytest.warns(DeprecationWarning, match="make_tier_executor"):
        old = make_tier_executor(sess, max_new=4, vocab_clip=cfg.vocab_size)
    toks = np.arange(4, 10, dtype=np.int32)
    m_n, t_n = new(toks)
    m_o, t_o = old(toks)
    assert m_n == m_o and np.array_equal(np.asarray(t_n), np.asarray(t_o))


def test_build_executor_batched_alias_warns(lm_session):
    from repro.runtime.serving import make_batched_tier_executor

    cfg, sess = lm_session
    with pytest.warns(DeprecationWarning, match="make_batched_tier_executor"):
        make_batched_tier_executor(sess, max_new=4)


def test_build_executor_raw_faults_and_errors():
    from repro.runtime.serving import TierFaultError, build_executor

    ex = build_executor(lambda t: (len(t), t), kind="raw", faults={0},
                        fault_message="boom")
    with pytest.raises(TierFaultError, match="boom"):
        ex(np.zeros(3, np.int32))
    assert ex(np.zeros(3, np.int32))[0] == 3
    assert ex.calls == {"n": 2, "faults": 1}

    with pytest.raises(ValueError, match="kind"):
        build_executor(lambda t: t, kind="bogus")
    with pytest.raises(ValueError, match="callable"):
        build_executor(object(), kind="raw")
    with pytest.raises(ValueError, match="params"):
        build_executor(object(), kind="split")
    with pytest.raises(ValueError, match="split"):
        build_executor(object(), kind="split", params={}, faults={0})


def test_make_faulty_executor_alias_warns():
    from repro.runtime.serving import make_faulty_executor

    with pytest.warns(DeprecationWarning, match="make_faulty_executor"):
        wrapped = make_faulty_executor(lambda t: (1, t), {0})
    assert wrapped.calls["n"] == 0


def test_build_executor_split_matches_deprecated_name():
    import jax
    from repro.models.registry import resolve
    from repro.runtime.serving import (build_executor,
                                       make_split_tier_executors)

    model = resolve("cnmt:fr-en", scale=0.1, vocab=128,
                    max_decode_len=24).model
    params = model.init(jax.random.PRNGKey(0))
    enc, dec = build_executor(model, kind="split", params=params)
    with pytest.warns(DeprecationWarning, match="make_split_tier_executors"):
        enc_o, dec_o = make_split_tier_executors(model, params)
    toks = np.arange(3, 9, dtype=np.int32)
    m_n, out_n = dec(enc(toks))
    m_o, out_o = dec_o(enc_o(toks))
    assert m_n == m_o and np.array_equal(np.asarray(out_n), np.asarray(out_o))


# -------------------------------------------- engine legacy kwargs ------
def test_engine_legacy_edge_cloud_kwargs_warn_but_work():
    """PR-1 constructor form still routes identically to tiers= — it just
    warns now."""
    import dataclasses

    from repro.core.latency_model import DeviceProfile, LinearLatencyModel
    from repro.core.length_regressor import LinearN2M
    from repro.runtime.engine import CollaborativeEngine, Tier

    edge = Tier(DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.0))
    cloud = Tier(DeviceProfile("c", LinearLatencyModel(4e-4, 1.6e-3, 2e-3),
                               0.0))
    rtt = lambda t: 0.05

    with pytest.warns(DeprecationWarning, match="tiers="):
        legacy = CollaborativeEngine(edge=edge, cloud=cloud,
                                     n2m=LinearN2M(1.0, 0.0), rtt_fn=rtt,
                                     seed=0)
    modern = CollaborativeEngine(
        tiers=[dataclasses.replace(edge, name="edge"),
               dataclasses.replace(cloud, name="cloud", rtt_fn=rtt)],
        n2m=LinearN2M(1.0, 0.0), seed=0)

    rng = np.random.default_rng(5)
    lens = rng.integers(2, 200, 40)
    for i, n in enumerate(lens):
        a = legacy.submit(np.zeros(int(n), np.int32), now_s=float(i))
        b = modern.submit(np.zeros(int(n), np.int32), now_s=float(i))
        assert a.device == b.device and a.latency_s == b.latency_s


def test_engine_tiers_form_does_not_warn():
    from repro.core.latency_model import DeviceProfile, LinearLatencyModel
    from repro.core.length_regressor import LinearN2M
    from repro.runtime.engine import CollaborativeEngine, Tier

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        CollaborativeEngine(
            tiers=[Tier(DeviceProfile("e", LinearLatencyModel(1e-3, 1e-3,
                                                              1e-3), 0.0)),
                   Tier(DeviceProfile("c", LinearLatencyModel(1e-4, 1e-4,
                                                              1e-4), 0.0),
                        rtt_fn=lambda t: 0.05)],
            n2m=LinearN2M(1.0, 0.0), seed=0)
