"""Unit + property tests for the N->M length estimators (paper Fig. 3)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.length_regressor import (
    BucketN2M,
    HuberN2M,
    LinearN2M,
    MeanN2M,
    RidgeN2M,
    prefilter_pairs,
)
from repro.data.synthetic import LANGUAGE_PAIRS, make_corpus


def test_linear_recovers_exact_line():
    n = np.arange(1, 100, dtype=float)
    m = 0.7 * n + 3.0
    r = LinearN2M().fit(n, m)
    assert r.gamma == pytest.approx(0.7, abs=1e-4)
    assert r.delta == pytest.approx(3.0, abs=1e-3)
    assert r.r2(n, m) == pytest.approx(1.0, abs=1e-5)


@pytest.mark.parametrize("pair", list(LANGUAGE_PAIRS))
def test_fig3_r2_on_synthetic_corpora(pair):
    """Paper Fig. 3: linear N->M fit reaches R^2 ~ 0.99 on all 3 pairs.

    (R^2 computed on bucket-averaged M as in the figure, which plots the
    average M for a given N.)
    """
    corpus = make_corpus(pair, 20000, seed=1)
    n, m = prefilter_pairs(corpus.n, corpus.m_real)
    reg = LinearN2M().fit(n, m)
    # recovered slope close to the generating verbosity factor
    assert reg.gamma == pytest.approx(LANGUAGE_PAIRS[pair].gamma, rel=0.1)
    # bucket-averaged R^2 as plotted in Fig. 3 (buckets with enough support;
    # the figure's dots are averages over all outputs of the same length)
    uniq = np.unique(n)
    uniq = np.array([u for u in uniq if (n == u).sum() >= 5])
    avg_m = np.array([m[n == u].mean() for u in uniq])
    assert reg.r2(uniq, avg_m) > 0.97
    if pair in ("fr-en", "en-zh"):
        assert reg.gamma < 1.0  # paper: EN less verbose than FR, ZH than EN


def test_prefilter_removes_mismatched_pairs():
    n = np.array([10.0, 20.0, 5.0, 50.0])
    m = np.array([11.0, 90.0, 4.0, 1.0])  # 2nd and 4th are misaligned
    nf, mf = prefilter_pairs(n, m, max_ratio=3.0)
    assert len(nf) == 2
    assert set(nf.tolist()) == {10.0, 5.0}


def test_huber_resists_outliers():
    rng = np.random.default_rng(0)
    n = rng.uniform(1, 100, 500)
    m = 0.8 * n + 2 + rng.normal(0, 0.5, 500)
    m[:50] = rng.uniform(150, 200, 50)  # 10% gross outliers
    ols = LinearN2M().fit(n, m)
    hub = HuberN2M(huber_delta=2.0).fit(n, m)
    assert abs(hub.gamma - 0.8) < abs(ols.gamma - 0.8)
    assert hub.gamma == pytest.approx(0.8, abs=0.05)


def test_ridge_shrinks_towards_zero():
    n = np.array([1.0, 2.0, 3.0, 4.0])
    m = 2.0 * n
    big_lam = RidgeN2M(lam=1e6).fit(n, m)
    assert abs(big_lam.gamma) < 0.1
    small_lam = RidgeN2M(lam=1e-6).fit(n, m)
    assert small_lam.gamma == pytest.approx(2.0, abs=1e-3)


def test_mean_estimator_ignores_n():
    n = np.array([1.0, 100.0])
    m = np.array([10.0, 20.0])
    r = MeanN2M().fit(n, m)
    pred = np.asarray(r.predict(np.array([5.0, 500.0])))
    assert pred[0] == pred[1] == pytest.approx(15.0)


def test_bucket_estimator_captures_nonlinearity():
    rng = np.random.default_rng(0)
    n = rng.uniform(1, 100, 5000)
    m = 0.5 * n + 0.004 * n**2  # mildly super-linear
    b = BucketN2M(n_buckets=25).fit(n, m)
    lin = LinearN2M().fit(n, m)
    grid = np.linspace(5, 95, 50)
    truth = 0.5 * grid + 0.004 * grid**2
    err_b = np.abs(np.asarray(b.predict(grid)) - truth).mean()
    err_l = np.abs(np.asarray(lin.predict(grid)) - truth).mean()
    assert err_b < err_l


def test_bucket_quantile_is_monotone_in_quantile():
    rng = np.random.default_rng(1)
    n = rng.uniform(1, 50, 2000)
    m = n + rng.normal(0, 3, 2000)
    lo = BucketN2M(n_buckets=10, quantile=0.25).fit(n, m)
    hi = BucketN2M(n_buckets=10, quantile=0.9).fit(n, m)
    grid = np.linspace(5, 45, 20)
    assert np.all(np.asarray(hi.predict(grid)) >= np.asarray(lo.predict(grid)) - 1e-6)


@settings(max_examples=25, deadline=None)
@given(
    gamma=st.floats(0.2, 2.0),
    delta=st.floats(-5.0, 5.0),
    scale=st.floats(0.5, 4.0),
)
def test_property_linear_fit_equivariance(gamma, delta, scale):
    """Scaling M scales gamma/delta identically (fit is linear in targets)."""
    n = np.linspace(1, 80, 200)
    m = gamma * n + delta
    base = LinearN2M().fit(n, m)
    scaled = LinearN2M().fit(n, scale * m)
    assert scaled.gamma == pytest.approx(scale * base.gamma, rel=1e-3, abs=1e-4)
    assert scaled.delta == pytest.approx(scale * base.delta, rel=1e-3, abs=1e-3)
