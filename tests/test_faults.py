"""PR 8: fault-tolerant collaborative serving.

The load-bearing pins:

* ZERO-FAULT PARITY — arming the fault machinery with an empty
  schedule changes nothing, bit for bit, in either the engine or the
  DES (the machinery must cost nothing when nothing fails);
* circuit-breaker state machine: CLOSED -k failures-> OPEN -cooldown->
  HALF_OPEN -probe success-> CLOSED (and probe failure -> OPEN again);
* failover strictly beats the no-retry baseline under an injected
  outage, losing zero requests;
* split-plan decode-leg failover re-homes the decode from the SHIPPED
  EncoderStates (exactness: any decode-capable tier resumes to the
  fused output, pinned at the executor level);
* estimator/calibrator hygiene: link state invalidates on breaker
  recovery, failed samples never reach the N->M / plane feedback;
* property (hypothesis shim): under arbitrary outage schedules every
  request is EITHER served or shed, never both, never neither.
"""

import dataclasses
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.calibration import OnlineCalibrator
from repro.core.faults import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    CircuitBreaker,
    FaultSchedule,
    LinkFault,
    RetryPolicy,
    Straggler,
    TierOutage,
)
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.simulator import SimTier, make_poisson_stream, simulate_des
from repro.core.tx_estimator import LinkModel, TxEstimator
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import TierFaultError, build_executor


# ------------------------------------------------------ fault schedule --
def test_schedule_queries():
    f = FaultSchedule(
        outages=(TierOutage(1, 10.0, 20.0),),
        link_faults=(LinkFault(2, 5.0, 15.0, rtt_factor=3.0,
                               bandwidth_factor=0.5),
                     LinkFault(2, 12.0, 14.0, blackhole=True)),
        stragglers=(Straggler(0, 0.0, 4.0, slowdown=2.5),))
    assert not f.empty and FaultSchedule().empty
    assert f.tier_down(1, 15.0) and not f.tier_down(1, 20.0)  # end-exclusive
    assert not f.tier_down(2, 15.0)
    assert f.link_blackhole(2, 13.0) and not f.link_blackhole(2, 11.0)
    assert f.link_factors(2, 10.0) == (3.0, 0.5)
    assert f.link_factors(2, 30.0) == (1.0, 1.0)
    assert f.slowdown(0, 2.0) == 2.5 and f.slowdown(0, 5.0) == 1.0
    ev = f.outage_events()
    assert [e[1] for e in ev if e[2] == 1] == ["down", "up"]
    assert ev == sorted(ev, key=lambda e: e[0])
    assert f.horizon_s() >= 20.0


def test_random_schedule_deterministic_and_protects_tiers():
    a = FaultSchedule.random(3, 600.0, seed=4, outage_rate_hz=1 / 60.0)
    b = FaultSchedule.random(3, 600.0, seed=4, outage_rate_hz=1 / 60.0)
    assert a == b
    assert all(o.tier != 0 for o in a.outages)   # protect_tiers=(0,)
    assert FaultSchedule.random(3, 600.0, seed=5) \
        != FaultSchedule.random(3, 600.0, seed=6) or True  # seeds may tie


# ----------------------------------------------------- circuit breaker --
def test_breaker_transitions():
    b = CircuitBreaker(failure_threshold=3, reset_timeout_s=1.0)
    assert b.state == CLOSED and b.allow(0.0)
    assert not b.record_failure(0.1) and not b.record_failure(0.2)
    assert b.record_failure(0.3)                 # third consecutive: opens
    assert b.state == OPEN and b.n_opens == 1
    assert not b.allow(0.5)                      # cooling down
    assert b.time_to_probe(0.5) == pytest.approx(0.8)
    assert b.allow(1.5)                          # cooldown passed: probe
    assert b.state == HALF_OPEN and b.n_probes == 1
    assert b.record_failure(1.6)                 # probe failed: re-open NOW
    assert b.state == OPEN and b.n_opens == 2
    assert b.allow(2.7)                          # second probe
    assert b.record_success()                    # True exactly on recovery
    assert b.state == CLOSED
    assert not b.record_success()                # steady state: no signal
    assert not b.record_failure(3.0)             # counter was reset
    assert b.state == CLOSED


def test_retry_policy_backoff_bounded_and_seeded():
    p = RetryPolicy(backoff_base_s=0.1, backoff_factor=2.0,
                    backoff_max_s=0.5, jitter_frac=0.1)
    r1 = np.random.default_rng(0)
    r2 = np.random.default_rng(0)
    seq = [p.backoff(a, r1) for a in range(6)]
    assert seq == [p.backoff(a, r2) for a in range(6)]   # deterministic
    for a, v in enumerate(seq):
        assert 0.0 < v <= 0.5 * 1.1 + 1e-12
    assert p.detect_s(False) == p.fail_fast_s
    assert p.detect_s(True) == p.timeout_s       # blackhole = full timeout


# --------------------------------------------- estimator / calibrator --
def test_tx_estimator_invalidate_bootstraps_next_sample():
    est = TxEstimator(init_rtt_s=0.05)
    est.observe(0.0, 0.2)
    est.observe(1.0, 0.2)
    assert est.rtt(1.0) == pytest.approx(0.2)
    est.invalidate()
    assert est.n_invalidations == 1
    assert est.rtt(1.0) == pytest.approx(0.2)    # estimate kept as guess
    est.observe(2.0, 0.01)                       # first post-recovery sample
    assert est.rtt(2.0) == pytest.approx(0.01)   # replaces wholesale
    # and the causal guard restarted too (old timestamps accepted again)


def test_link_model_invalidate_touches_both_directions():
    links = LinkModel(3)
    links.add_link(0, 1, TxEstimator(init_rtt_s=0.01))
    links.add_link(1, 2, TxEstimator(init_rtt_s=0.02))
    assert links.invalidate(1) == 4              # 0->1, 1->0, 1->2, 2->1
    assert links.invalidate(0) == 2


def test_calibrator_excludes_failed_samples():
    cal = OnlineCalibrator(1, interval=2, min_samples=3)
    assert not cal.record(0, 10.0, 9.0, 0.5, ok=False)
    assert cal.n_excluded == 1 and cal.n_recorded == 0
    assert not cal.record(0, 10.0, 9.0, 0.01)
    assert not cal.record(0, 12.0, 11.0, 1e9, ok=False)  # timeout artifact
    assert cal.record(0, 20.0, 18.0, 0.02)       # 2 good ones: refit due
    assert cal.n_excluded == 2 and cal.n_recorded == 2


def test_faulty_executor_wrapper():
    calls = []
    wrapped = build_executor(lambda t: calls.append(1) or (1, t),
                             kind="raw", faults={1})
    assert wrapped(np.zeros(2, np.int32))[0] == 1
    with pytest.raises(TierFaultError):
        wrapped(np.zeros(2, np.int32))
    assert wrapped(np.zeros(2, np.int32))[0] == 1
    assert wrapped.calls == {"n": 3, "faults": 1}
    assert len(calls) == 2                       # the crash pre-empted work


# ------------------------------------------------------ engine parity --
def _engine(**kw):
    edge = Tier(DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01),
                              0.0))
    cloud = Tier(DeviceProfile("c", LinearLatencyModel(4e-4, 1.6e-3, 2e-3),
                               0.0))
    profile = make_profile("cp2", seed=7)
    cloud = dataclasses.replace(
        cloud, rtt_fn=lambda t: float(profile.rtt_at(t)))
    return CollaborativeEngine(tiers=[edge, cloud],
                               n2m=LinearN2M(1.0, 0.0), seed=0, **kw)


def _drive(eng, k=300, rate_hz=20.0):
    rng = np.random.default_rng(3)
    return [eng.submit(np.zeros(int(rng.integers(2, 200)), np.int32),
                       now_s=i / rate_hz) for i in range(k)]


def test_engine_zero_fault_parity_is_bitwise():
    plain = _drive(_engine())
    armed = _drive(_engine(faults=FaultSchedule(), retry=RetryPolicy()))
    for a, b in zip(plain, armed):
        assert a.device == b.device
        assert a.latency_s == b.latency_s        # bit-for-bit
        assert a.m_out == b.m_out
        assert b.attempts == 1 and b.failed_tiers == ()


def test_engine_failover_beats_no_retry_under_outage():
    faults = FaultSchedule(outages=(TierOutage(1, 3.0, 9.0),))
    nr = _engine(faults=faults)
    _drive(nr)
    fo = _engine(faults=faults, retry=RetryPolicy())
    results = _drive(fo)
    s_nr, s_fo = nr.stats(), fo.stats()
    assert s_nr["fault_lost"] > 0 and s_nr["availability"] < 1.0
    assert s_fo["fault_lost"] == 0 and s_fo["availability"] == 1.0
    assert s_fo["availability"] > s_nr["availability"]
    assert s_fo["failovers"] == s_fo["retries"] > 0
    retried = [r for r in results if r.attempts > 1]
    assert retried and all(1 in r.failed_tiers for r in retried)
    assert all(r.device == 0 for r in retried)   # degraded to edge
    # detection + backoff is real latency, not hidden
    assert all(r.latency_s > 0 for r in retried)


def test_engine_all_tiers_dark_sheds_with_retry_after():
    faults = FaultSchedule(outages=(TierOutage(0, 0.0, 50.0),
                                    TierOutage(1, 0.0, 50.0)))
    eng = _engine(faults=faults, retry=RetryPolicy(max_retries=1))
    results = _drive(eng, k=40)
    assert all(r.shed for r in results)
    assert eng.stats()["availability"] == 0.0
    # a shed response tells the client when to come back (ROADMAP 5c)
    assert all(r.retry_after_s is not None and r.retry_after_s >= 0.0
               for r in results)


def test_engine_real_executor_crash_fails_over():
    crashing = build_executor(lambda t: (len(t), t), kind="raw",
                              faults={0})
    edge = Tier(DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01),
                              0.0), executor=crashing)
    cloud = Tier(DeviceProfile("c", LinearLatencyModel(4e-4, 1.6e-3, 2e-3),
                               0.0))
    cloud = dataclasses.replace(cloud, rtt_fn=lambda t: 5.0)
    eng = CollaborativeEngine(tiers=[edge, cloud],   # WAN: edge always wins
                              n2m=LinearN2M(1.0, 0.0),
                              seed=0, retry=RetryPolicy())
    r0 = eng.submit(np.zeros(4, np.int32), now_s=0.0)
    r1 = eng.submit(np.zeros(4, np.int32), now_s=1.0)
    assert r0.device == 1 and r0.attempts == 2 and r0.failed_tiers == (0,)
    assert r1.device == 0 and r1.attempts == 1   # executor healthy again
    assert crashing.calls["faults"] == 1         # call 1 never happened at 0


# --------------------------------------------------------- DES parity --
def _des_setup(seed=5):
    npu = DeviceProfile("npu", LinearLatencyModel(4e-4, 1.6e-3, 4e-3), 0.05)
    edge = DeviceProfile("edge", LinearLatencyModel(1.5e-4, 6e-4, 8e-3),
                         0.05)
    cloud = DeviceProfile("cloud", LinearLatencyModel(2e-5, 9e-5, 2e-3),
                          0.08)
    lan, wan = make_profile("cp2", seed=seed), make_profile("cp1", seed=seed)
    tiers = [SimTier("npu", npu, servers=1, queue_capacity=16),
             SimTier("edge", edge, servers=2, queue_capacity=64, link=lan),
             SimTier("cloud", cloud, servers=8, link=wan)]
    sched = MultiTierScheduler(
        [SchedTier("npu", dataclasses.replace(npu.model), None),
         SchedTier("edge", dataclasses.replace(edge.model),
                   TxEstimator(init_rtt_s=float(lan.rtt_at(0.0)))),
         SchedTier("cloud", dataclasses.replace(cloud.model),
                   TxEstimator(init_rtt_s=float(wan.rtt_at(0.0))))],
        LinearN2M(0.9, 2.0))
    return sched, tiers


def _des_stream(k=1500, rate=15.0, seed=2, slo_s=None):
    rng = np.random.default_rng(seed)
    n = rng.integers(2, 200, k).astype(np.float64)
    m = np.maximum(0.9 * n + rng.normal(0, 3, k), 1.0)
    return make_poisson_stream(n, m, m, rate_hz=rate, seed=seed, slo_s=slo_s)


_ARRAYS = ("tier", "t_start_s", "t_finish_s", "wait_s", "tx_s", "exec_s",
           "latency_s", "shed", "overflow")


def test_des_zero_fault_parity_is_bitwise():
    sched0, tiers0 = _des_setup()
    base = simulate_des(sched0, _des_stream(), tiers0, seed=0)
    sched1, tiers1 = _des_setup()
    armed = simulate_des(sched1, _des_stream(), tiers1, seed=0,
                         faults=FaultSchedule())
    for f in _ARRAYS:
        assert np.array_equal(getattr(base, f), getattr(armed, f),
                              equal_nan=True), f
    assert base.fault_stats is None and armed.fault_stats is not None
    assert np.all(armed.attempts == 1)


def test_des_failover_beats_no_retry_under_outage():
    faults = FaultSchedule(outages=(TierOutage(2, 10.0, 50.0),))
    s0, t0 = _des_setup()
    nr = simulate_des(s0, _des_stream(), t0, seed=0, faults=faults)
    s1, t1 = _des_setup()
    fo = simulate_des(s1, _des_stream(), t1, seed=0, faults=faults,
                      retry=RetryPolicy(), collect_events=True)
    assert nr.fault_stats["fault_lost"] > 0
    assert fo.fault_stats["fault_lost"] == 0
    assert fo.fault_stats["availability"] > nr.fault_stats["availability"]
    assert fo.fault_stats["retries"] > 0
    assert fo.fault_stats["breaker_opens"] >= 1
    assert nr.fault_stats["breaker_opens"] == 0   # baseline: no breakers
    # retried-and-served requests landed on a healthy tier
    served_retried = ~fo.shed & (fo.attempts > 1)
    assert served_retried.any()
    assert np.all(fo.tier[served_retried] != 2)
    kinds = {e[1] for e in fo.events}
    assert {"tier_down", "tier_up", "fault", "retry"} <= kinds
    s = fo.summary()
    for key in ("availability", "retries", "fault_lost", "goodput_rps"):
        assert key in s


def test_des_fault_run_is_deterministic():
    faults = FaultSchedule(outages=(TierOutage(2, 10.0, 50.0),),
                           link_faults=(LinkFault(1, 30.0, 40.0,
                                                  rtt_factor=5.0),))
    runs = []
    for _ in range(2):
        s, t = _des_setup()
        runs.append(simulate_des(s, _des_stream(), t, seed=0, faults=faults,
                                 retry=RetryPolicy()))
    for f in _ARRAYS:
        assert np.array_equal(getattr(runs[0], f), getattr(runs[1], f),
                              equal_nan=True), f


def test_des_degraded_link_prices_the_episode():
    """Non-blackhole degradation: served requests on the degraded link
    pay the inflated tx during the episode, and nothing is lost."""
    faults = FaultSchedule(link_faults=(LinkFault(2, 10.0, 60.0,
                                                  rtt_factor=4.0,
                                                  bandwidth_factor=0.25),))
    s0, t0 = _des_setup()
    base = simulate_des(s0, _des_stream(), t0, seed=0)
    s1, t1 = _des_setup()
    deg = simulate_des(s1, _des_stream(), t1, seed=0, faults=faults,
                       retry=RetryPolicy())
    assert deg.fault_stats["fault_lost"] == 0
    in_ep = (deg.t_start_s >= 10.0) & (deg.t_start_s < 60.0) \
        & (deg.tier == 2) & ~deg.shed
    if in_ep.any():
        assert np.nanmean(deg.tx_s[in_ep]) > np.nanmean(
            base.tx_s[(base.tier == 2) & ~base.shed])


def test_des_backpressure_replay_with_deadline():
    """ROADMAP 5c: a deadline shed under retry.replay_shed becomes a
    delayed re-submission carrying retry_after_s; replays are counted."""
    faults = FaultSchedule(outages=(TierOutage(2, 5.0, 40.0),))
    s0, t0 = _des_setup()
    stream = _des_stream(k=1500, rate=40.0, slo_s=0.6)
    r = simulate_des(s0, stream, t0, seed=0, faults=faults,
                     retry=RetryPolicy(), collect_events=True)
    assert r.retry_after_s is not None
    hinted = ~np.isnan(r.retry_after_s)
    assert np.all(r.retry_after_s[hinted] >= 0.0)
    if r.fault_stats["replays"] > 0:
        assert any(e[1] == "backpressure" for e in r.events)


# ------------------------------------- split decode-leg failover ------
@pytest.mark.slow
def test_split_decode_failover_exact_and_engine_rehomes():
    """The shipped EncoderStates are the recovery unit: ANY decode-
    capable tier resumes them to the fused output (executor-level
    exactness), and the engine re-homes a split plan's decode leg when
    its tier dies mid-flight (attempts/failed_tiers recorded)."""
    import jax

    from repro.core.latency_model import ActivationCostModel
    from repro.nmt import GRUSeq2Seq, RNNConfig

    model = GRUSeq2Seq(RNNConfig(vocab_src=64, vocab_tgt=64, embed=32,
                                 hidden=32, layers=2, max_decode_len=24))
    params = model.init(jax.random.PRNGKey(0))
    fused = model.make_translate_batched(params)
    enc, dec = build_executor(model, kind="split", params=params)

    rng = np.random.default_rng(3)
    toks = rng.integers(3, 64, 9).astype(np.int32)
    mask = np.ones((1, 9), np.float32)
    lens_f, toks_f = fused(toks[None, :], mask)
    # exactness: the SAME states decode identically wherever they land
    states = enc(toks)
    m1, out1 = dec(states)
    m2, out2 = dec(states)                        # "another tier" = same fn
    assert m1 == m2 == int(np.asarray(lens_f)[0])
    assert np.array_equal(out1, out2)
    assert np.array_equal(out1, np.asarray(toks_f)[0, :max(m1, 1)])

    # engine: kill the decode tier exactly while states are in flight
    dev = (3e-4, 5e-3, 2e-3)
    edge = (2e-5, 2.5e-3, 4e-3)
    cloud = (1e-5, 1e-4, 2e-3)
    links = LinkModel(3)
    links.add_link(1, 2, TxEstimator(init_rtt_s=4e-3, bandwidth_bps=1e9))
    tiers = [
        Tier(DeviceProfile("dev", LinearLatencyModel(*dev), 0.05),
             name="dev"),
        Tier(DeviceProfile("edge", LinearLatencyModel(*edge), 0.05),
             name="edge", rtt_fn=lambda t: 5e-3, bandwidth_bps=200e6,
             encode_executor=enc, decode_executor=dec),
        Tier(DeviceProfile("cloud", LinearLatencyModel(*cloud), 0.05),
             name="cloud", rtt_fn=lambda t: 90e-3, bandwidth_bps=20e6,
             decode_executor=dec),
    ]
    faults = FaultSchedule(outages=(TierOutage(2, 2.0, 8.0),))
    eng = CollaborativeEngine(
        n2m=LinearN2M(1.0, 0.0), tiers=tiers, seed=0,
        links=links, activation=ActivationCostModel(512, 4),
        inter_rtt_fns={(1, 2): lambda t: 4e-3}, allow_split=True,
        faults=faults, retry=RetryPolicy())
    rng = np.random.default_rng(11)
    for i in range(60):
        eng.submit(rng.integers(3, 64, int(rng.integers(8, 200)))
                   .astype(np.int32), now_s=float(i) * 0.2)
    assert eng.decode_failovers > 0
    # a re-homed decode leg may land back on the encode tier itself
    # (degenerate split(1, 1), not is_split) or on another decode-capable
    # tier; either way the failed tier is recorded and never the device
    rehomed = [r for r in eng.results
               if r.plan is not None and not r.shed and r.attempts > 1
               and r.failed_tiers == (2,)]
    assert len(rehomed) >= eng.decode_failovers
    for r in rehomed:
        assert r.device != 2
        assert r.plan.decode_tier == r.device
        assert r.m_out >= 1                      # decoded from the states


# ------------------------------------------------------- property -----
@settings(max_examples=12, deadline=None)
@given(start=st.floats(0.0, 40.0), dur=st.floats(0.5, 40.0),
       tier=st.integers(1, 2), use_retry=st.booleans(),
       blackhole=st.booleans())
def test_property_served_xor_shed(start, dur, tier, use_retry, blackhole):
    """No request is ever both served and shed, or neither, under any
    outage/blackhole window, with or without retries."""
    if blackhole:
        faults = FaultSchedule(link_faults=(LinkFault(tier, start,
                                                      start + dur,
                                                      blackhole=True),))
    else:
        faults = FaultSchedule(outages=(TierOutage(tier, start,
                                                   start + dur),))
    sched, tiers = _des_setup()
    r = simulate_des(sched, _des_stream(k=400), tiers, seed=0,
                     faults=faults,
                     retry=RetryPolicy() if use_retry else None)
    served = ~r.shed & (r.tier >= 0)
    assert np.all(served ^ r.shed)               # exactly one of the two
    assert np.all(np.isfinite(r.latency_s[served]))
    assert np.all(np.isnan(r.latency_s[r.shed]))
    assert np.all(r.attempts >= 1)
    st_ = r.fault_stats
    assert 0.0 <= st_["availability"] <= 1.0
    assert int(served.sum()) + int(r.shed.sum()) == 400
