"""N-tier scheduler + engine tests: the paper's Eq. (1) must fall out of
the generalized rule as the N=2 special case (bit-for-bit), and the
queue-aware machinery must behave sanely beyond it."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.latency_model import (
    DeviceProfile,
    LinearLatencyModel,
    bytes_for_tokens,
)
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import (
    CLOUD,
    EDGE,
    CNMTScheduler,
    MultiTierScheduler,
    OracleScheduler,
    SchedTier,
    StaticScheduler,
)
from repro.core.simulator import RequestStream, simulate
from repro.core.tx_estimator import TxEstimator
from repro.runtime.engine import CollaborativeEngine, Tier


def _pair(speedup=5.0):
    edge = DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.0)
    cloud = DeviceProfile("c", LinearLatencyModel(2e-3 / speedup,
                                                  8e-3 / speedup,
                                                  0.01 / speedup), 0.0)
    return edge, cloud


def _multi(edge, cloud, n2m, rtt, hedge=0.0):
    return MultiTierScheduler(
        [SchedTier("e", edge.model, None),
         SchedTier("c", cloud.model, TxEstimator(init_rtt_s=rtt))],
        n2m, hedge_margin_s=hedge)


# ------------------------------------------------ N=2 reduction to Eq. (1) --
@pytest.mark.property
@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 300),
    rtt=st.floats(1e-4, 1.0),
    speedup=st.floats(1.5, 20.0),
    gamma=st.floats(0.3, 1.5),
    hedge=st.sampled_from([0.0, 1e-3, 5e-2]),
)
def test_two_tier_decide_matches_cnmt(n, rtt, speedup, gamma, hedge):
    """Empty-queue 2-tier MultiTierScheduler == CNMTScheduler.decide,
    device AND predicted totals, for random planes/RTTs/margins."""
    edge, cloud = _pair(speedup)
    n2m = LinearN2M(gamma, 1.0)
    cnmt = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m,
                         hedge_margin_s=hedge)
    multi = _multi(edge, cloud, n2m, rtt, hedge)
    d_ref = cnmt.decide(n, 0.0, TxEstimator(init_rtt_s=rtt))
    d = multi.decide(n, 0.0)
    assert d.tier == d_ref.device
    assert d.t_pred[EDGE] == d_ref.t_edge_pred
    assert d.t_pred[CLOUD] == d_ref.t_cloud_pred
    assert d.m_hat == d_ref.m_hat


@pytest.mark.property
@settings(max_examples=25, deadline=None)
@given(
    speedup=st.floats(1.5, 20.0),
    rtt=st.floats(1e-4, 0.5),
    gamma=st.floats(0.3, 1.5),
    hedge=st.sampled_from([0.0, 2e-2]),
    seed=st.integers(0, 1000),
)
def test_two_tier_decide_batch_matches_cnmt(speedup, rtt, gamma, hedge, seed):
    edge, cloud = _pair(speedup)
    n2m = LinearN2M(gamma, 1.0)
    cnmt = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m,
                         hedge_margin_s=hedge)
    multi = _multi(edge, cloud, n2m, rtt, hedge)
    rng = np.random.default_rng(seed)
    ns = rng.integers(1, 300, size=64)
    rtts = np.full(64, rtt)
    assert np.array_equal(multi.decide_batch(ns, rtts),
                          cnmt.decide_batch(ns, rtts))


def test_decide_fast_agrees_with_decide_on_device():
    edge, cloud = _pair()
    multi = _multi(edge, cloud, LinearN2M(0.9, 1.0), 0.05)
    for n in (2, 20, 60, 150, 290):
        d = multi.decide(n, 0.0)
        df = multi.decide_fast(float(n), d.m_hat, 0.0)
        assert df.tier == d.tier
        assert df.t_pred[d.tier] == pytest.approx(d.t_pred[d.tier], rel=1e-5)


# -------------------------------------------------------- N-tier semantics --
def test_queue_delay_diverts_to_next_best_tier():
    edge, cloud = _pair()
    multi = _multi(edge, cloud, LinearN2M(1.0, 0.0), 0.001)
    n = 200  # long request: cloud wins with empty queues
    assert multi.decide(n, 0.0).tier == CLOUD
    # pile predicted backlog onto the cloud tier -> edge takes over
    assert multi.decide(n, 0.0, [0.0, 10.0]).tier == EDGE


def test_hedge_prefers_fastest_local_tier():
    edge, cloud = _pair()
    slow_local = DeviceProfile("l2", edge.model.scaled(0.5), 0.0)
    sched = MultiTierScheduler(
        [SchedTier("l2", slow_local.model, None),
         SchedTier("e", edge.model, None),
         SchedTier("c", cloud.model, TxEstimator(init_rtt_s=1e-4))],
        LinearN2M(1.0, 0.0), hedge_margin_s=1e9)
    d = sched.decide(100, 0.0)
    assert d.tier == 1          # fastest LOCAL, not the globally fastest
    assert d.t_pred[2] < d.t_pred[1]  # cloud was predicted faster


def test_three_tier_picks_argmin():
    edge, cloud = _pair()
    mid = DeviceProfile("m", edge.model.scaled(2.0), 0.0)
    sched = MultiTierScheduler(
        [SchedTier("e", edge.model, None),
         SchedTier("m", mid.model, TxEstimator(init_rtt_s=1e-4)),
         SchedTier("c", cloud.model, TxEstimator(init_rtt_s=1e-4))],
        LinearN2M(1.0, 0.0))
    for n in (1, 5, 20, 80, 300):
        d = sched.decide(n, 0.0)
        assert d.t_pred[d.tier] == min(d.t_pred)


def test_schedtier_annotations_resolve():
    """Regression: ``SchedTier.model`` was annotated with a class the
    module never imported — a latent NameError under
    ``typing.get_type_hints`` / dataclass introspection."""
    import typing

    from repro.core.latency_model import LinearLatencyModel

    hints = typing.get_type_hints(SchedTier)
    assert hints["model"] is LinearLatencyModel


def test_observe_rtt_feeds_only_that_tier():
    edge, cloud = _pair()
    sched = MultiTierScheduler(
        [SchedTier("e", edge.model, None),
         SchedTier("c1", cloud.model, TxEstimator(init_rtt_s=0.5)),
         SchedTier("c2", cloud.model, TxEstimator(init_rtt_s=0.5))],
        LinearN2M(1.0, 0.0))
    sched.observe_rtt(0, 0.0, 0.1)   # local tier: no-op
    sched.observe_rtt(1, 0.0, 0.01)
    assert sched.tiers[1].tx.n_samples == 1
    assert sched.tiers[2].tx.n_samples == 0
    assert sched.tiers[1].tx.rtt(0.0) < sched.tiers[2].tx.rtt(0.0)


# ------------------------------------------------- oracle lower bound prop --
@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    speedup=st.floats(1.2, 12.0),
    noise=st.floats(0.0, 0.1),
)
def test_oracle_lower_bounds_all_policies_on_random_streams(seed, speedup, noise):
    rng = np.random.default_rng(seed)
    k = 300
    n = rng.integers(1, 200, k).astype(np.float64)
    m = np.maximum(0.8 * n + rng.normal(0, 4, k), 1.0)
    stream = RequestStream(t_arrival_s=np.sort(rng.uniform(0, 3600.0, k)),
                           n=n, m_out=m, m_real=m)
    edge = DeviceProfile("e", LinearLatencyModel(2e-3, 8e-3, 0.01), noise)
    cloud = DeviceProfile("c", edge.model.scaled(speedup), noise)
    profile = make_profile("cp1" if seed % 2 else "cp2", seed=seed)
    n2m = LinearN2M().fit(n, m)
    oracle = simulate(OracleScheduler(), stream, profile, edge, cloud,
                      seed=seed)
    for pol in (StaticScheduler(EDGE), StaticScheduler(CLOUD),
                CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)):
        r = simulate(pol, stream, profile, edge, cloud, seed=seed)
        assert r.total_s >= oracle.total_s - 1e-9


def test_oracle_multi_tier_argmin():
    totals = np.array([[3.0, 1.0, 2.0],
                       [1.0, 2.0, 2.0],
                       [2.0, 3.0, 1.0]])
    assert np.array_equal(OracleScheduler.decide_batch_multi(totals),
                          [1, 0, 2])


# ------------------------------------- N=2 engine bit-for-bit regression ---
def test_engine_two_tier_reproduces_seed_semantics_bit_for_bit():
    """The seed CollaborativeEngine was CNMTScheduler + one TxEstimator +
    one shared rng; replay those semantics inline over a seeded 1k-request
    stream and demand identical devices, output lengths AND latencies."""
    edge_p = DeviceProfile("edge", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.05)
    cloud_p = DeviceProfile("cloud", LinearLatencyModel(4e-4, 1.6e-3, 0.002),
                            0.08)
    profile = make_profile("cp2", seed=3)
    rtt_fn = lambda t: float(profile.rtt_at(t))
    n2m = LinearN2M(0.9, 2.0)
    lens = np.random.default_rng(42).integers(2, 200, size=1000)

    sched = CNMTScheduler(edge=edge_p, cloud=cloud_p, n2m=n2m)
    tx = TxEstimator(init_rtt_s=float(rtt_fn(0.0)))
    rng = np.random.default_rng(0)
    ref = []
    for i, n in enumerate(lens):
        now = float(i)
        d = sched.decide(int(n), now, tx)
        prof = edge_p if d.device == EDGE else cloud_p
        t = float(prof.true_time(float(n), d.m_hat, rng))
        m_out = int(max(round(d.m_hat), 1))
        if d.device == EDGE:
            lat = t
        else:
            rtt = float(rtt_fn(now))
            payload = float(bytes_for_tokens(int(n) + m_out, 2))
            lat = t + rtt + payload * 8.0 / tx.bandwidth_bps
            tx.observe(now, rtt)
        ref.append((d.device, m_out, lat))

    eng = CollaborativeEngine(
        tiers=[Tier(edge_p, name="edge"),
               Tier(cloud_p, name="cloud", rtt_fn=rtt_fn)],
        n2m=n2m, seed=0)
    for i, n in enumerate(lens):
        r = eng.submit(np.zeros(int(n), np.int32), now_s=float(i))
        dev, m_out, lat = ref[i]
        assert r.device == dev
        assert r.m_out == m_out
        assert r.latency_s == lat          # bitwise: no tolerance
        assert r.wait_s == 0.0
    # both tiers exercised, and the link estimator saw every offload
    devs = np.array([r[0] for r in ref])
    assert 0.0 < devs.mean() < 1.0
    assert eng.tx.n_samples == int((devs == CLOUD).sum())


# ---------------------------------------------------- engine queue/refit ---
def test_engine_virtual_time_queue_delay():
    """Two simultaneous long requests on a 1-server edge: the second waits
    exactly the first's execution time."""
    edge_p = DeviceProfile("edge", LinearLatencyModel(1e-3, 1e-3, 0.05), 0.0)
    eng = CollaborativeEngine(tiers=[Tier(edge_p, name="edge")],
                              n2m=LinearN2M(1.0, 0.0), seed=0)
    a = eng.submit(np.zeros(10, np.int32), now_s=0.0)
    b = eng.submit(np.zeros(10, np.int32), now_s=0.0)
    assert a.wait_s == 0.0
    assert b.wait_s == pytest.approx(a.latency_s - a.wait_s)
    assert b.latency_s > a.latency_s


def test_engine_bounded_queue_reroutes():
    fast = DeviceProfile("fast", LinearLatencyModel(0.0, 0.0, 10.0), 0.0)
    slow = DeviceProfile("slow", LinearLatencyModel(0.0, 0.0, 20.0), 0.0)
    eng = CollaborativeEngine(
        tiers=[Tier(fast, name="fast", servers=1, queue_capacity=0),
               Tier(slow, name="slow", servers=1)],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    r1 = eng.submit(np.zeros(5, np.int32), now_s=0.0)   # takes the server
    r2 = eng.submit(np.zeros(5, np.int32), now_s=0.0)   # queue full -> slow
    assert r1.device == 0
    assert r2.device == 1


def test_engine_online_refit_corrects_bad_plane():
    """Start the scheduler with a wildly wrong edge plane; after the refit
    interval the observed completions pull it back to reality."""
    edge_p = DeviceProfile("edge", LinearLatencyModel(1e-3, 2e-3, 0.01), 0.02)
    wrong = DeviceProfile("edge", LinearLatencyModel(1.0, 1.0, 1.0), 0.02)
    eng = CollaborativeEngine(
        tiers=[Tier(dataclasses.replace(wrong, model=wrong.model))],
        n2m=LinearN2M(1.0, 0.0), seed=0, refit_interval=64)
    # ground truth executes on the REAL plane
    eng.tiers[0].profile = edge_p
    rng = np.random.default_rng(7)
    for i in range(200):
        eng.submit(np.zeros(int(rng.integers(2, 120)), np.int32),
                   now_s=float(i))
    refit = eng.scheduler.tiers[0].model
    assert eng.calibrator.n_refits >= 2
    assert refit.alpha_m == pytest.approx(2e-3, rel=0.5)
    assert refit.beta < 0.1
    # the tier's ground-truth profile object was never mutated
    assert eng.tiers[0].profile.model.alpha_m == 2e-3
