"""Discrete-event simulator invariants: event ordering, conservation,
per-tier FIFO, bounded queues, determinism, and zero-load equivalence
with the paper-faithful analytic replay."""

import dataclasses

import numpy as np
import pytest

from repro.core.calibration import OnlineCalibrator
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import CNMTScheduler, MultiTierScheduler, SchedTier
from repro.core.simulator import (
    RequestStream,
    SimTier,
    make_poisson_stream,
    simulate,
    simulate_des,
)
from repro.core.tx_estimator import TxEstimator


def _three_tier(seed=5, npu_cap=8):
    npu = DeviceProfile("npu", LinearLatencyModel(4e-4, 1.6e-3, 0.004), 0.05)
    edge = DeviceProfile("edge", LinearLatencyModel(1.5e-4, 6e-4, 0.008), 0.05)
    cloud = DeviceProfile("cloud", LinearLatencyModel(2e-5, 9e-5, 0.002), 0.08)
    lan, wan = make_profile("cp2", seed=seed), make_profile("cp1", seed=seed)
    tiers = [SimTier("npu", npu, servers=1, queue_capacity=npu_cap),
             SimTier("edge", edge, servers=2, queue_capacity=64, link=lan),
             SimTier("cloud", cloud, servers=8, link=wan)]
    sched = MultiTierScheduler(
        [SchedTier("npu", dataclasses.replace(npu.model), None),
         SchedTier("edge", dataclasses.replace(edge.model),
                   TxEstimator(init_rtt_s=float(lan.rtt_at(0.0)))),
         SchedTier("cloud", dataclasses.replace(cloud.model),
                   TxEstimator(init_rtt_s=float(wan.rtt_at(0.0))))],
        LinearN2M(0.9, 2.0))
    return sched, tiers


def _stream(k=2000, rate=50.0, seed=2):
    rng = np.random.default_rng(seed)
    n = rng.integers(2, 200, k).astype(np.float64)
    m = np.maximum(0.9 * n + rng.normal(0, 3, k), 1.0)
    return make_poisson_stream(n, m, m, rate_hz=rate, seed=seed)


def _loaded_run(rate=80.0, **kw):
    sched, tiers = _three_tier(**kw)
    stream = _stream(rate=rate)
    return stream, simulate_des(sched, stream, tiers, seed=0,
                                collect_events=True)


# ------------------------------------------------------------- invariants --
def test_event_times_nondecreasing():
    _, r = _loaded_run()
    times = [e[0] for e in r.events]
    assert all(b >= a for a, b in zip(times, times[1:]))


def test_conservation_every_arrival_finishes_exactly_once():
    stream, r = _loaded_run()
    k = len(stream)
    # one arrival + one finish event per request, no extras
    arrivals = [e[2] for e in r.events if e[1] == "arrival"]
    finishes = [e[2] for e in r.events if e[1] == "finish"]
    assert sorted(arrivals) == list(range(k))
    assert sorted(finishes) == list(range(k))
    assert np.all(r.tier >= 0) and np.all(r.tier < 3)
    assert np.all(r.t_start_s >= r.t_arrival_s - 1e-12)
    assert np.all(r.t_finish_s > r.t_start_s)
    assert np.all(np.isfinite(r.latency_s)) and np.all(r.latency_s > 0)
    assert np.allclose(r.latency_s, r.wait_s + r.exec_s + r.tx_s)


def test_fifo_within_each_tier():
    """Among requests served by one tier, start order == arrival order."""
    _, r = _loaded_run()
    assert r.wait_s.max() > 0, "load too low to exercise queues"
    for k in range(3):
        sel = np.where(r.tier == k)[0]
        order = sel[np.argsort(r.t_arrival_s[sel], kind="stable")]
        starts = r.t_start_s[order]
        assert np.all(np.diff(starts) >= -1e-12)


def test_server_capacity_never_exceeded():
    _, r = _loaded_run()
    caps = {0: 1, 1: 2, 2: 8}
    for k, servers in caps.items():
        sel = r.tier == k
        if not sel.any():
            continue
        events = sorted(
            [(t, 1) for t in r.t_start_s[sel]]
            + [(t, -1) for t in r.t_finish_s[sel]],
            key=lambda e: (e[0], e[1]))   # finish before start on ties
        load, peak = 0, 0
        for _, d in events:
            load += d
            peak = max(peak, load)
        assert peak <= servers, (k, peak, servers)


def test_bounded_queue_reroutes_under_burst():
    """A tiny NPU queue under heavy load forces rerouting: the NPU's
    waiting line never exceeds its capacity."""
    stream, r = _loaded_run(rate=500.0, npu_cap=2)
    sel = r.tier == 0
    # waiting count over time at tier 0: arrivals assigned - starts
    times = sorted([(t, +1) for t in r.t_arrival_s[sel]]
                   + [(t, -1) for t in r.t_start_s[sel]],
                   key=lambda e: (e[0], e[1]))
    q, peak = 0, 0
    for _, d in times:
        q += d
        peak = max(peak, q)
    # capacity 2 waiting + 1 in service; forced enqueues are counted
    assert peak <= 2 + 1 + int(r.overflow[0])


def test_des_deterministic_given_seed():
    sched1, tiers1 = _three_tier()
    sched2, tiers2 = _three_tier()
    stream = _stream(k=800)
    a = simulate_des(sched1, stream, tiers1, seed=9)
    b = simulate_des(sched2, stream, tiers2, seed=9)
    assert np.array_equal(a.tier, b.tier)
    assert np.array_equal(a.latency_s, b.latency_s)


# --------------------------------------------------- zero-load equivalence --
def test_zero_load_matches_analytic_replay_bitwise():
    """1s-spaced arrivals with ~0.15s max service: every request finds
    empty queues, so the DES must reproduce the analytic replay's
    decisions AND latencies exactly (same seed, same draws)."""
    edge = DeviceProfile("e", LinearLatencyModel(1.5e-4, 6e-4, 0.008), 0.03)
    cloud = DeviceProfile("c", LinearLatencyModel(3e-5, 1.2e-4, 0.0016), 0.03)
    n2m = LinearN2M(0.9, 2.0)
    profile = make_profile("cp2", seed=0)
    rng = np.random.default_rng(1)
    k = 2000
    n = rng.integers(2, 200, k).astype(np.float64)
    m = np.maximum(0.9 * n + rng.normal(0, 3, k), 1.0)
    stream = RequestStream(t_arrival_s=np.arange(k) * 1.0,
                           n=n, m_out=m, m_real=m)

    analytic = simulate(CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m),
                        stream, profile, edge, cloud, seed=0)
    multi = MultiTierScheduler(
        [SchedTier("e", edge.model, None),
         SchedTier("c", cloud.model,
                   TxEstimator(init_rtt_s=float(profile.rtt_at(0.0))))],
        n2m)
    des = simulate_des(multi, stream,
                       [SimTier("e", edge), SimTier("c", cloud, link=profile)],
                       seed=0)
    assert des.wait_s.max() == 0.0
    assert np.array_equal(analytic.device, des.tier)
    assert np.array_equal(analytic.latency_s, des.latency_s)
    assert 0.1 < analytic.offload_frac < 0.9   # both regimes exercised


# ----------------------------------------------- §II-C sample ordering -----
class _RecordingTx(TxEstimator):
    """TxEstimator that logs every observation timestamp it is offered."""

    def __post_init__(self):
        super().__post_init__()
        self.stamps = []

    def observe(self, timestamp_s, rtt_s):
        self.stamps.append(float(timestamp_s))
        super().observe(timestamp_s, rtt_s)


def test_rtt_samples_timestamped_at_completion_not_arrival():
    """Regression: the DES used to observe §II-C samples with the
    request's *arrival* time, so a short request overtaking a long one
    on a multi-server tier rewound the estimator's clock.  Samples must
    carry the completion time and arrive monotonically."""
    # local tier is hopeless -> both requests offload to the 2-server
    # remote tier; r0 is long (finishes last), r1 short (finishes first)
    local = DeviceProfile("l", LinearLatencyModel(0.0, 0.0, 100.0), 0.0)
    remote = DeviceProfile("r", LinearLatencyModel(0.1, 0.0, 0.0), 0.0)
    link = make_profile("cp2", seed=0)
    est = _RecordingTx(init_rtt_s=float(link.rtt_at(0.0)))
    sched = MultiTierScheduler(
        [SchedTier("l", local.model, None),
         SchedTier("r", remote.model, est)],
        LinearN2M(1.0, 0.0))
    stream = RequestStream(
        t_arrival_s=np.array([0.0, 1.0]),
        n=np.array([100.0, 1.0]),         # exec 10s vs 0.1s
        m_out=np.array([1.0, 1.0]), m_real=np.array([1.0, 1.0]))
    r = simulate_des(sched, stream,
                     [SimTier("l", local),
                      SimTier("r", remote, servers=2, link=link)],
                     seed=0)
    assert np.array_equal(r.tier, [1, 1])
    assert r.t_finish_s[1] < r.t_finish_s[0]      # out-of-order completion
    # completion-stamped, in completion order, never moving backwards
    assert est.stamps == [r.t_finish_s[1], r.t_finish_s[0]]
    assert est.stamps == sorted(est.stamps)
    assert est.n_stale == 0 and est.n_samples == 2


def test_rtt_estimator_last_update_matches_latest_completion():
    sched, tiers = _three_tier()
    r = simulate_des(sched, _stream(k=1500, rate=80.0), tiers, seed=0)
    for k in (1, 2):                      # the two remote tiers
        sel = r.tier == k
        if not sel.any():
            continue
        tx = sched.tiers[k].tx
        assert tx._last_update == pytest.approx(r.t_finish_s[sel].max())


# ------------------------------------------------------------ load/refit ---
def test_queue_pressure_shifts_load_to_deeper_tiers():
    """As the Poisson rate rises, the shallow capacity-limited tiers
    saturate and the cloud's share must grow."""
    fracs = []
    for rate in (5.0, 120.0):
        sched, tiers = _three_tier()
        r = simulate_des(sched, _stream(rate=rate), tiers, seed=0)
        fracs.append(r.tier_frac()["cloud"])
    assert fracs[1] > fracs[0]


def test_online_refit_corrects_overconfident_plane_des():
    """DES feedback loop: a scheduler whose edge plane is 20x too FAST
    floods that tier, collects real completions, and refits back to
    truth.  (The converse — a plane too slow — is a cold-start problem:
    the tier draws no traffic, hence no samples; the refit deliberately
    keeps the prior there.)"""
    sched, tiers = _three_tier()
    sched_wrong, tiers_w = _three_tier()
    wrong = sched_wrong.tiers[1].model
    wrong.alpha_n /= 20; wrong.alpha_m /= 20; wrong.beta /= 20
    stream = _stream(k=3000, rate=30.0)
    cal = OnlineCalibrator(3, interval=200)
    simulate_des(sched_wrong, stream, tiers_w, seed=0, calibrator=cal)
    assert cal.n_refits >= 5
    # after refitting, the believed edge plane is close to truth again
    truth = tiers[1].profile.model
    assert sched_wrong.tiers[1].model.alpha_m == pytest.approx(
        truth.alpha_m, rel=0.5)
    # ...and routing matches the well-calibrated run's shape again
    r_ref = simulate_des(sched, stream, tiers, seed=0)
    r_post = simulate_des(sched_wrong, _stream(k=1000, rate=30.0, seed=4),
                          tiers_w, seed=1)
    assert abs(r_post.tier_frac()["edge"]
               - r_ref.tier_frac()["edge"]) < 0.35
