"""Dependency-light `hypothesis` shim for the test suite.

Tier-1 must collect and pass with or without `hypothesis` installed
(`requirements-dev.txt` pins the real thing for dev machines/CI).  When
the real library is importable we re-export it untouched; otherwise we
fall back to a tiny seeded-random property runner that supports the
subset this repo's tests use:

* ``@given(name=strategy, ...)`` — draws ``max_examples`` example dicts
  from a per-test deterministic RNG (seeded from the test's qualname,
  so failures are reproducible run-to-run) and calls the test once per
  example, printing the falsifying example on failure;
* ``@settings(max_examples=..., deadline=...)`` — ``max_examples`` is
  honored, ``deadline`` ignored (the fallback has no shrinking/timing);
* ``st.integers / st.floats / st.sampled_from / st.lists /
  st.booleans / st.just / st.tuples``.

Import in tests as ``from _hypothesis_compat import given, settings, st``.
"""

from __future__ import annotations

try:  # prefer the real library when present
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn, label):
            self._draw = draw_fn
            self._label = label

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def __repr__(self):
            return self._label

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value),
                             f"integers({min_value}, {max_value})")

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value),
                             f"floats({min_value}, {max_value})")

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            if not seq:
                raise ValueError("sampled_from needs a non-empty sequence")
            return _Strategy(lambda r: seq[r.randrange(len(seq))],
                             f"sampled_from({seq!r})")

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5, "booleans()")

        @staticmethod
        def just(value):
            return _Strategy(lambda r: value, f"just({value!r})")

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(r):
                size = r.randint(min_size, max_size)
                return [elements.draw(r) for _ in range(size)]

            return _Strategy(draw, f"lists({elements!r})")

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda r: tuple(s.draw(r) for s in strategies),
                             f"tuples({strategies!r})")

    st = _Strategies()

    def settings(**cfg):
        """Record settings on the (possibly already-wrapped) test fn."""

        def deco(fn):
            merged = dict(getattr(fn, "_compat_settings", {}))
            merged.update(cfg)
            fn._compat_settings = merged
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_compat_settings", {})
                max_examples = int(cfg.get("max_examples", 20))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(max_examples):
                    example = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **example, **kwargs)
                    except Exception:
                        print(f"Falsifying example ({fn.__qualname__}): "
                              f"{example!r}")
                        raise

            wrapper._compat_settings = dict(getattr(fn, "_compat_settings", {}))
            # pytest must not mistake the drawn parameters for fixtures:
            # hide the wrapped signature (all params are supplied by draws).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
