"""Batched continuous-serving + deadline-aware admission (PR 2).

Covers: the sub-linear batch latency model in the DES and the engine,
batch-aware T_queue, SLO shedding / drain-time eviction, and the
``batch_size=1`` / no-deadline bit-for-bit reduction to the PR 1
semantics (the paper's Eq. (1) stays the degenerate case).
"""

import dataclasses

import numpy as np
import pytest

from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import CNMTScheduler, MultiTierScheduler, SchedTier
from repro.core.simulator import (
    RequestStream,
    SimTier,
    make_poisson_stream,
    simulate,
    simulate_des,
)
from repro.core.tx_estimator import TxEstimator
from repro.runtime.engine import CollaborativeEngine, Tier


def _flat_profile(beta: float, name: str = "t") -> DeviceProfile:
    """Length-independent deterministic service time (noise-free)."""
    return DeviceProfile(name, LinearLatencyModel(0.0, 0.0, beta), 0.0)


def _solo_sched(profile: DeviceProfile, *, batch_size: int = 1,
                per_seq_overhead_s: float = 0.0) -> MultiTierScheduler:
    return MultiTierScheduler(
        [SchedTier(profile.name, dataclasses.replace(profile.model), None,
                   batch_size=batch_size,
                   per_seq_overhead_s=per_seq_overhead_s)],
        LinearN2M(1.0, 0.0))


def _stream(arrivals, n=8.0, slo_s=None) -> RequestStream:
    arrivals = np.asarray(arrivals, np.float64)
    k = len(arrivals)
    n = np.broadcast_to(np.asarray(n, np.float64), (k,)).copy()
    return RequestStream(arrivals, n, n, n,
                         slo_s=None if slo_s is None
                         else np.asarray(slo_s, np.float64))


# --------------------------------------------- batch_size=1 reduction ------
def test_batch1_no_deadline_zero_load_matches_analytic_bitwise():
    """The acceptance invariant: tiers built through the *batched* code
    path with batch_size=1 and no deadlines must still reproduce the
    paper-faithful analytic replay decision- and latency-exact."""
    edge = DeviceProfile("e", LinearLatencyModel(1.5e-4, 6e-4, 0.008), 0.03)
    cloud = DeviceProfile("c", LinearLatencyModel(3e-5, 1.2e-4, 0.0016), 0.03)
    n2m = LinearN2M(0.9, 2.0)
    profile = make_profile("cp2", seed=0)
    rng = np.random.default_rng(1)
    k = 1500
    n = rng.integers(2, 200, k).astype(np.float64)
    m = np.maximum(0.9 * n + rng.normal(0, 3, k), 1.0)
    stream = RequestStream(t_arrival_s=np.arange(k) * 1.0,
                           n=n, m_out=m, m_real=m)

    analytic = simulate(CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m),
                        stream, profile, edge, cloud, seed=0)
    multi = MultiTierScheduler(
        [SchedTier("e", edge.model, None, batch_size=1,
                   per_seq_overhead_s=0.0),
         SchedTier("c", cloud.model,
                   TxEstimator(init_rtt_s=float(profile.rtt_at(0.0))),
                   batch_size=1, per_seq_overhead_s=0.0)],
        n2m)
    des = simulate_des(
        multi, stream,
        [SimTier("e", edge, batch_size=1, per_seq_overhead_s=0.0),
         SimTier("c", cloud, link=profile, batch_size=1,
                 per_seq_overhead_s=0.0)],
        seed=0)
    assert des.wait_s.max() == 0.0
    assert np.array_equal(analytic.device, des.tier)
    assert np.array_equal(analytic.latency_s, des.latency_s)
    assert des.summary()["shed"] == 0.0
    assert des.summary()["slo_attainment"] == 1.0


def test_infinite_deadlines_equal_no_deadlines_loaded():
    """slo_s = inf everywhere must take the exact no-deadline path even
    under load (deadline machinery fully disabled)."""
    prof = _flat_profile(0.05)
    rng = np.random.default_rng(3)
    arr = np.cumsum(rng.exponential(0.02, 400))
    a = simulate_des(_solo_sched(prof), _stream(arr),
                     [SimTier("t", prof, servers=2)], seed=0)
    b = simulate_des(_solo_sched(prof),
                     _stream(arr, slo_s=np.full(400, np.inf)),
                     [SimTier("t", prof, servers=2)], seed=0)
    assert np.array_equal(a.tier, b.tier)
    assert np.array_equal(a.latency_s, b.latency_s)
    assert b.summary()["slo_attainment"] == 1.0


# --------------------------------------------------- DES batch formula -----
def test_batch_members_share_start_finish_and_cost_formula():
    """r0 runs solo; r1..r3 queue behind it and must start together as
    one batch costing  max(solo) + per_seq_overhead * (b-1)."""
    prof = _flat_profile(0.1)
    tiers = [SimTier("t", prof, servers=1, batch_size=3,
                     per_seq_overhead_s=0.01)]
    r = simulate_des(_solo_sched(prof, batch_size=3,
                                 per_seq_overhead_s=0.01),
                     _stream([0.0, 0.01, 0.02, 0.03]), tiers, seed=0)
    assert r.t_start_s[0] == 0.0
    assert r.t_finish_s[0] == pytest.approx(0.1)
    # the three queued requests form one batch at the first finish
    assert np.all(r.t_start_s[1:] == r.t_finish_s[0])
    assert len(set(r.t_finish_s[1:])) == 1
    assert r.exec_s[1] == pytest.approx(0.1 + 0.01 * 2)
    assert r.t_finish_s[1] == pytest.approx(0.1 + 0.1 + 0.02)


def test_batching_sustains_higher_throughput_under_overload():
    """A saturated single-server tier drains an overload burst much
    faster with batch_size=8 than serially — the continuous-batching
    throughput lever the ROADMAP asks for."""
    prof = _flat_profile(0.01)
    rng = np.random.default_rng(7)
    k = 600
    n = rng.integers(4, 40, k).astype(np.float64)
    stream = make_poisson_stream(n, n, n, rate_hz=500.0, seed=7)

    def run(b):
        tiers = [SimTier("t", prof, servers=1, batch_size=b,
                         per_seq_overhead_s=0.001)]
        return simulate_des(_solo_sched(prof, batch_size=b,
                                        per_seq_overhead_s=0.001),
                            stream, tiers, seed=0)

    serial, batched = run(1), run(8)
    assert batched.throughput_rps() > 1.5 * serial.throughput_rps()
    assert batched.summary()["mean_wait_s"] < serial.summary()["mean_wait_s"]
    # every request still served exactly once
    assert batched.served.all() and serial.served.all()


def test_batch_drain_never_exceeds_server_or_batch_caps():
    prof = _flat_profile(0.02)
    rng = np.random.default_rng(11)
    k = 400
    n = rng.integers(4, 60, k).astype(np.float64)
    stream = make_poisson_stream(n, n, n, rate_hz=300.0, seed=11)
    tiers = [SimTier("t", prof, servers=2, batch_size=4,
                     per_seq_overhead_s=0.002)]
    r = simulate_des(_solo_sched(prof, batch_size=4,
                                 per_seq_overhead_s=0.002),
                     stream, tiers, seed=0)
    # batches are identified by identical (start, finish); each holds at
    # most batch_size members and at most `servers` overlap in time
    batches = {}
    for i in range(k):
        batches.setdefault((r.t_start_s[i], r.t_finish_s[i]), []).append(i)
    assert max(len(v) for v in batches.values()) <= 4
    assert any(len(v) > 1 for v in batches.values())
    events = sorted([(s, 1) for s, _ in batches]
                    + [(f, -1) for _, f in batches],
                    key=lambda e: (e[0], e[1]))
    load = peak = 0
    for _, d in events:
        load += d
        peak = max(peak, load)
    assert peak <= 2


def test_batch_aware_queue_delay():
    sched = MultiTierScheduler(
        [SchedTier("a", LinearLatencyModel(0, 0, 0.1), None),
         SchedTier("b", LinearLatencyModel(0, 0, 0.1), None, batch_size=4,
                   per_seq_overhead_s=0.0),
         SchedTier("c", LinearLatencyModel(0, 0, 0.1), None, batch_size=4,
                   per_seq_overhead_s=0.05)],
        LinearN2M(1.0, 0.0))
    backlog, in_sys, servers = 0.8, 8, 2
    q_serial = sched.queue_delay(0, backlog, in_sys, servers)
    q_free = sched.queue_delay(1, backlog, in_sys, servers)
    q_cost = sched.queue_delay(2, backlog, in_sys, servers)
    assert q_serial == backlog / servers
    assert q_free == pytest.approx(q_serial / 4)     # ideal 4x speedup
    assert q_free < q_cost < q_serial                # overhead in between
    # unbatched fast path is exact division (bit-for-bit PR-1 term)
    assert sched.queue_delay(0, 0.0, 0, servers) == 0.0


# ----------------------------------------------------- DES deadlines -------
def test_infeasible_deadline_is_shed_not_force_enqueued():
    prof = _flat_profile(0.1)
    tiers = [SimTier("t", prof, servers=1, queue_capacity=0)]
    r = simulate_des(_solo_sched(prof),
                     _stream([0.0, 0.001], slo_s=[0.15, 0.15]),
                     tiers, seed=0)
    assert not r.shed[0] and r.shed[1]
    assert r.tier[1] == -1 and np.isnan(r.latency_s[1])
    s = r.summary()
    assert s["shed"] == 1.0 and s["served"] == 1.0
    assert s["slo_attainment"] == 0.5
    assert s["overflow"] == 0.0          # no blind force-enqueue


def test_full_tier_feasible_deadline_still_force_enqueues():
    prof = _flat_profile(0.1)
    tiers = [SimTier("t", prof, servers=1, queue_capacity=0)]
    r = simulate_des(_solo_sched(prof),
                     _stream([0.0, 0.001], slo_s=[0.5, 0.5]),
                     tiers, seed=0)
    assert r.served.all()
    assert r.summary()["overflow"] == 1.0
    assert r.summary()["slo_attainment"] == 1.0


def test_no_deadline_keeps_pr1_force_enqueue():
    prof = _flat_profile(0.1)
    tiers = [SimTier("t", prof, servers=1, queue_capacity=0)]
    r = simulate_des(_solo_sched(prof), _stream([0.0, 0.001]), tiers, seed=0)
    assert r.served.all()
    assert r.summary()["overflow"] == 1.0


def test_deadline_reroutes_to_feasible_tier():
    fast = _flat_profile(0.01, "fast")
    slow = _flat_profile(0.05, "slow")
    sched = MultiTierScheduler(
        [SchedTier("fast", dataclasses.replace(fast.model), None),
         SchedTier("slow", dataclasses.replace(slow.model), None)],
        LinearN2M(1.0, 0.0))
    tiers = [SimTier("fast", fast, servers=1, queue_capacity=0),
             SimTier("slow", slow, servers=1)]
    r = simulate_des(sched, _stream([0.0, 0.001], slo_s=[0.5, 0.5]),
                     tiers, seed=0)
    assert r.tier[0] == 0 and r.tier[1] == 1      # rerouted, not shed
    assert r.served.all()
    assert r.summary()["slo_attainment"] == 1.0


def test_drain_evicts_requests_whose_deadline_already_expired():
    """A queued request whose deadline passes before a server frees is
    shed at drain time, letting later work start sooner."""
    prof = _flat_profile(0.1)
    tiers = [SimTier("t", prof, servers=1)]
    r = simulate_des(_solo_sched(prof),
                     _stream([0.0, 0.01, 0.02],
                             slo_s=[np.inf, 0.05, np.inf]),
                     tiers, seed=0)
    assert not r.shed[0] and r.shed[1] and not r.shed[2]
    assert r.tier[1] == 0                 # admitted, then evicted at drain
    assert r.t_start_s[2] == pytest.approx(0.1)   # r1's slot freed for r2
    assert r.summary()["slo_attainment"] == 0.0   # the only deadline missed


# ----------------------------------------------------- overhead fitting ----
def test_fit_batch_overhead_recovers_sublinear_model():
    from repro.core.calibration import fit_batch_overhead

    rng = np.random.default_rng(0)
    b = np.repeat([1, 2, 4, 8, 16], 3).astype(np.float64)
    t = 0.02 + 0.003 * (b - 1) + rng.normal(0, 1e-4, b.size)
    t1, o = fit_batch_overhead(b, t)
    assert t1 == pytest.approx(0.02, rel=0.05)
    assert o == pytest.approx(0.003, rel=0.05)
    # noise-driven negative slopes are clamped like the plane fits
    _, o0 = fit_batch_overhead(np.array([1.0, 2.0]), np.array([0.02, 0.019]))
    assert o0 == 0.0
    with pytest.raises(ValueError):
        fit_batch_overhead(np.array([4.0, 4.0]), np.array([0.1, 0.1]))


# -------------------------------------------------------- engine batching --
def _flat_tier(beta, **kw) -> Tier:
    return Tier(_flat_profile(beta), **kw)


def test_engine_batch_coalesces_in_virtual_time():
    eng = CollaborativeEngine(
        tiers=[_flat_tier(0.1, name="t", servers=1, batch_size=3,
                          per_seq_overhead_s=0.01)],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    toks = np.zeros(8, np.int32)
    r0 = eng.submit(toks, now_s=0.0)
    r1 = eng.submit(toks, now_s=0.0)
    r2 = eng.submit(toks, now_s=0.0)
    r3 = eng.submit(toks, now_s=0.0)
    assert r0.wait_s == 0.0 and r0.latency_s == pytest.approx(0.1)
    # r1 opens the queued batch; r2/r3 join it: same wait, growing cost
    assert r1.wait_s == r2.wait_s == r3.wait_s == pytest.approx(0.1)
    assert r1.latency_s == pytest.approx(0.1 + 0.1)
    assert r2.latency_s == pytest.approx(0.1 + 0.11)
    assert r3.latency_s == pytest.approx(0.1 + 0.12)
    # a 5th request exceeds batch_size=3 -> queues behind the batch
    r4 = eng.submit(toks, now_s=0.0)
    assert r4.wait_s == pytest.approx(0.1 + 0.12)


def test_engine_batch1_unchanged_by_batch_fields():
    """batch_size=1 engines must ignore the batching machinery entirely
    (PR-1 virtual-time bookkeeping, pinned elsewhere bit-for-bit)."""
    def run(**kw):
        eng = CollaborativeEngine(
            tiers=[_flat_tier(0.05, name="t", servers=2, **kw)],
            n2m=LinearN2M(1.0, 0.0), seed=0)
        return [eng.submit(np.zeros(4, np.int32), now_s=i * 0.01).latency_s
                for i in range(20)]
    assert run() == run(batch_size=1, per_seq_overhead_s=0.5)


def test_engine_sheds_on_infeasible_deadline_and_reports_slo():
    eng = CollaborativeEngine(
        tiers=[_flat_tier(10.0, name="t", servers=1, queue_capacity=0)],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    toks = np.zeros(4, np.int32)
    r0 = eng.submit(toks, now_s=0.0, deadline_s=20.0)   # served, meets SLO
    r1 = eng.submit(toks, now_s=0.0, deadline_s=0.5)    # full + infeasible
    assert not r0.shed and r0.slo_met is True
    assert r1.shed and r1.device == -1 and np.isnan(r1.latency_s)
    assert r1.slo_met is False
    s = eng.stats()
    assert s["shed"] == 1 and s["rejected"] == 0
    assert s["slo_attainment"] == pytest.approx(0.5)
    assert int(eng.shed_count.sum()) == 1


def test_engine_full_tier_feasible_deadline_forced_not_shed():
    eng = CollaborativeEngine(
        tiers=[_flat_tier(0.1, name="t", servers=1, queue_capacity=0)],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    toks = np.zeros(4, np.int32)
    eng.submit(toks, now_s=0.0, deadline_s=5.0)
    r1 = eng.submit(toks, now_s=0.0, deadline_s=5.0)
    assert not r1.shed
    s = eng.stats()
    assert s["shed"] == 0 and s["rejected"] == 1
    assert s["slo_attainment"] == 1.0
