"""Docs tree integrity: the pages ISSUE 9 ships exist, are linked from
README, and every relative link in README.md + docs/*.md resolves
(scripts/check_links.py — the same checker CI runs)."""

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT / "scripts") not in sys.path:
    sys.path.insert(0, str(_ROOT / "scripts"))

import check_links  # noqa: E402

_PAGES = ("docs/architecture.md", "docs/scheduler.md", "docs/benchmarks.md")


def test_docs_pages_exist_and_are_linked_from_readme():
    readme = (_ROOT / "README.md").read_text(encoding="utf-8")
    for page in _PAGES:
        assert (_ROOT / page).is_file(), page
        assert page in readme, f"README does not link {page}"


def test_all_relative_doc_links_resolve():
    assert list(check_links.broken_links(_ROOT)) == []


def test_checker_flags_broken_links(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[ok](docs/page.md) [bad](docs/missing.md) "
        "[ext](https://example.com) [anchor](#x)")
    (tmp_path / "docs" / "page.md").write_text(
        "[up](../README.md) [gone](nope.md#frag)")
    bad = sorted(str(t) for _, t in check_links.broken_links(tmp_path))
    assert bad == ["docs/missing.md", "nope.md#frag"]


def test_checker_cli_exit_codes(tmp_path):
    (tmp_path / "README.md").write_text("[bad](gone.md)")
    assert check_links.main(["check_links.py", str(tmp_path)]) == 1
    assert check_links.main(["check_links.py", str(_ROOT)]) == 0
