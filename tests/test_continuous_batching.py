"""Continuous in-flight batching (PR 6): parity pins + invariants.

Three layers, mirroring the feature's stack:

* ``ContinuousGenerationSession`` — bit-for-bit pins against the PR 3
  compiled-scan path: block mode (``refill=False``), continuous mode
  (eviction + prefill-into-live-batch), and the recurrent-mixer
  exact-width admission path must all reproduce the solo
  ``generate_with_lengths`` outputs row for row (on CPU the decode math
  is row-independent across batch compositions — the same invariant the
  PR 3 batched tests pin).
* ``CollaborativeEngine.serve_continuous`` — with admission pressure
  disabled (all arrivals at t=0, ample queue) the continuous engine must
  agree with PR 3 ``submit_batch`` per request; under bursty arrivals
  the slot table must never oversubscribe and every dropped request must
  carry a shed record.
* ``SimTier(continuous=True)`` — the DES twin: at zero load it must be
  bitwise identical to the PR-1 unbatched station (solo draws, no wait),
  and under load it must strictly beat block-to-completion on p95 (the
  benchmark's acceptance bar, pinned here at test scale).

Property-based invariants (seeded shim or real hypothesis): EDF across
deadline classes with FIFO inside each class, no drop without a shed
record, and slot-table conservation across random arrival/eviction
traces — run against a deterministic in-memory slot-table double so the
engine-level discipline is exercised thousands of steps in milliseconds.
"""

import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.simulator import (
    RequestStream,
    SimTier,
    make_poisson_stream,
    simulate_des,
)
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import (
    ContinuousGenerationSession,
    GenerationSession,
    build_executor,
)


# ------------------------------------------------------------ fixtures ----
@pytest.fixture(scope="module")
def lm_bundle():
    import jax

    from repro.configs import smoke_config
    from repro.models.model import LM

    cfg = smoke_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def solo_outputs(lm_bundle):
    """Per-prompt reference outputs from the PR 3 compiled-scan path."""
    cfg, model, params = lm_bundle
    sess = GenerationSession(model, params, max_len=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab_size,
                            size=int(rng.integers(2, 9))).astype(np.int32)
               for _ in range(9)]
    ref = []
    for p in prompts:
        lens, out = sess.generate_with_lengths(p[None, :], max_new=8)
        ref.append((int(lens[0]), np.asarray(out[0])))
    return prompts, ref


def _flat_tier_profile(beta: float = 0.01) -> DeviceProfile:
    return DeviceProfile("npu", LinearLatencyModel(0.0, 0.0, beta), 0.0)


def _assert_matches_solo(results, ref):
    for i, ((m_ref, out_ref), (m, toks)) in enumerate(zip(ref, results)):
        assert m == m_ref, f"row {i}: m {m} != {m_ref}"
        assert np.array_equal(toks[:m], out_ref[:m]), f"row {i} tokens"


# ----------------------------------------- session-level parity pins ------
def test_block_mode_matches_solo_scan_bitwise(lm_bundle, solo_outputs):
    """refill=False == PR 3 block-to-completion == solo scan outputs."""
    cfg, model, params = lm_bundle
    prompts, ref = solo_outputs
    sess = ContinuousGenerationSession(model, params, max_slots=4,
                                       max_len=48)
    _assert_matches_solo(
        [(m, t) for m, t in sess.serve(prompts, max_new=8, refill=False)],
        ref)


def test_continuous_refill_matches_solo_bitwise(lm_bundle, solo_outputs):
    """Eviction + prefill-into-live-batch never changes a row's tokens."""
    cfg, model, params = lm_bundle
    prompts, ref = solo_outputs
    sess = ContinuousGenerationSession(model, params, max_slots=4,
                                       max_len=48)
    res = sess.serve(prompts, max_new=8, refill=True)
    _assert_matches_solo(res, ref)
    # the run actually exercised mid-flight admission: more prefill
    # waves than the two block waves ceil(9/4) would need requires
    # refill into a live table at least once
    assert sess.peak_live == 4
    assert sess.n_prefills >= 2


def test_prefill_into_live_batch_is_exact(lm_bundle, solo_outputs):
    """Drive admit/step by hand: a row admitted into a HALF-LIVE table
    (other rows mid-decode) still reproduces its solo output."""
    cfg, model, params = lm_bundle
    prompts, ref = solo_outputs
    sess = ContinuousGenerationSession(model, params, max_slots=3,
                                       max_len=48)
    sess.admit(prompts[:2], max_new=8, req_ids=[0, 1])
    done = {}
    for _ in range(3):                       # decode a few steps
        for rid, m, toks in sess.step()[1]:
            done[rid] = (m, toks)
    sess.admit([prompts[2]], max_new=8, req_ids=[2])   # into live batch
    while sess.live_count:
        for rid, m, toks in sess.step()[1]:
            done[rid] = (m, toks)
    _assert_matches_solo([done[i] for i in range(3)], ref[:3])


def test_recurrent_plan_exact_width_admission(lm_bundle):
    """rwkv6 plans admit in exact-width groups; outputs == solo."""
    import jax

    from repro.configs import smoke_config
    from repro.models.model import LM

    cfg = smoke_config("rwkv6-3b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(3, cfg.vocab_size,
                            size=int(rng.integers(2, 7))).astype(np.int32)
               for _ in range(5)]
    sess = GenerationSession(model, params, max_len=48)
    ref = []
    for p in prompts:
        lens, out = sess.generate_with_lengths(p[None, :], max_new=6)
        ref.append((int(lens[0]), np.asarray(out[0])))
    cont = ContinuousGenerationSession(model, params, max_slots=3,
                                       max_len=48)
    assert not cont.supports_ragged
    _assert_matches_solo(cont.serve(prompts, max_new=6, refill=True), ref)


def test_session_reset_keeps_outputs_stable(lm_bundle, solo_outputs):
    cfg, model, params = lm_bundle
    prompts, ref = solo_outputs
    sess = ContinuousGenerationSession(model, params, max_slots=4,
                                       max_len=48)
    _assert_matches_solo(sess.serve(prompts, max_new=8), ref)
    sess.reset()
    assert sess.live_count == 0 and sess.n_steps == 0
    _assert_matches_solo(sess.serve(prompts, max_new=8), ref)


def test_admit_rejects_oversubscription_and_oversize(lm_bundle):
    cfg, model, params = lm_bundle
    sess = ContinuousGenerationSession(model, params, max_slots=2,
                                       max_len=32)
    p = np.arange(3, 9, dtype=np.int32)
    with pytest.raises(ValueError, match="free slots"):
        sess.admit([p, p, p], max_new=4)
    with pytest.raises(ValueError, match="capacity"):
        sess.admit([np.arange(3, 33, dtype=np.int32)], max_new=8)
    assert sess.live_count == 0            # failed admits leave no residue


def test_encoder_decoder_plans_are_rejected():
    class _Cfg:
        is_encoder_decoder = True

    class _Model:
        cfg = _Cfg()

    with pytest.raises(ValueError, match="decoder-only"):
        ContinuousGenerationSession(_Model(), None)


# ------------------------------------------- engine-level parity pins -----
def test_engine_continuous_matches_submit_batch(lm_bundle, solo_outputs):
    """Admission pressure disabled (one tier, ample queue, simultaneous
    arrivals): serve_continuous must agree with the PR 3 submit_batch
    path per request — same m_out, nothing shed, same tier."""
    cfg, model, params = lm_bundle
    prompts, _ = solo_outputs
    prof = _flat_tier_profile()

    cont = ContinuousGenerationSession(model, params, max_slots=4,
                                       max_len=48)
    eng_c = CollaborativeEngine(
        n2m=LinearN2M(1.0, 0.0),
        tiers=[Tier(prof, name="npu", servers=1, queue_capacity=64,
                    batch_size=4, continuous_session=cont)], seed=0)
    res_c = eng_c.serve_continuous(prompts, max_new=8)

    sess = GenerationSession(model, params, max_len=48)
    bexec = build_executor(sess, kind="batched", max_new=8,
                                       vocab_clip=cfg.vocab_size)
    eng_b = CollaborativeEngine(
        n2m=LinearN2M(1.0, 0.0),
        tiers=[Tier(prof, name="npu", servers=1, queue_capacity=64,
                    batch_size=4, batched_executor=bexec)], seed=0)
    res_b = eng_b.submit_batch(prompts, now_s=0.0)

    assert [r.m_out for r in res_c] == [r.m_out for r in res_b]
    assert [r.device for r in res_c] == [r.device for r in res_b]
    assert not any(r.shed for r in res_c)
    assert not any(r.shed for r in res_b)


def test_engine_block_and_refill_same_outputs(lm_bundle, solo_outputs):
    """refill only changes WHEN rows run, never what they compute."""
    cfg, model, params = lm_bundle
    prompts, ref = solo_outputs
    prof = _flat_tier_profile()
    arrivals = np.linspace(0.0, 0.01, len(prompts))
    outs = {}
    for refill in (False, True):
        sess = ContinuousGenerationSession(model, params, max_slots=4,
                                           max_len=48)
        eng = CollaborativeEngine(
            n2m=LinearN2M(1.0, 0.0),
            tiers=[Tier(prof, name="npu", servers=1, queue_capacity=64,
                        batch_size=4, continuous_session=sess)], seed=0)
        res = eng.serve_continuous(prompts, arrival_s=arrivals,
                                   max_new=8, refill=refill)
        outs[refill] = [r.m_out for r in res]
    assert outs[False] == outs[True] == [m for m, _ in ref]


def test_engine_burst_never_oversubscribes_and_sheds_with_record(
        lm_bundle):
    """Bursty simultaneous arrivals against a 2-slot table with a
    1-deep queue: the slot table never exceeds max_slots and every
    dropped request comes back as an explicit shed record."""
    cfg, model, params = lm_bundle
    rng = np.random.default_rng(3)
    burst = [rng.integers(3, cfg.vocab_size, size=5).astype(np.int32)
             for _ in range(10)]
    sess = ContinuousGenerationSession(model, params, max_slots=2,
                                       max_len=32)
    eng = CollaborativeEngine(
        n2m=LinearN2M(1.0, 0.0),
        tiers=[Tier(_flat_tier_profile(), name="npu", servers=1,
                    queue_capacity=1, batch_size=2,
                    continuous_session=sess)], seed=0)
    res = eng.serve_continuous(burst, arrival_s=[0.0] * 10,
                               deadline_s=1e-6, max_new=6)
    assert sess.peak_live <= 2
    assert all(r is not None for r in res)
    n_served = sum(not r.shed for r in res)
    n_shed = sum(r.shed for r in res)
    assert n_served + n_shed == 10
    assert n_shed > 0                      # the burst had to shed
    for r in res:
        if r.shed:
            assert r.device == -1 and np.isnan(r.latency_s)


# --------------------------------------------------- DES parity pins ------
def _solo_sched(profile, *, batch_size=1, o=0.0):
    return MultiTierScheduler(
        [SchedTier(profile.name, dataclasses.replace(profile.model), None,
                   batch_size=batch_size, per_seq_overhead_s=o)],
        LinearN2M(1.0, 0.0))


def test_sim_continuous_zero_load_matches_unbatched_bitwise():
    """Zero load: the continuous station must reproduce the PR-1
    unbatched station bitwise (solo draws, zero wait) — the analytic
    latency, since the batch-size-1 path is pinned to it elsewhere."""
    prof = DeviceProfile("t", LinearLatencyModel(1e-4, 2e-3, 1e-3), 0.02)
    rng = np.random.default_rng(5)
    k = 300
    n = rng.integers(2, 60, k).astype(np.float64)
    stream = RequestStream(np.arange(k) * 1.0, n, n, n)
    plain = simulate_des(_solo_sched(prof), stream,
                         [SimTier("t", prof)], seed=0)
    cont = simulate_des(_solo_sched(prof, batch_size=8, o=1e-3), stream,
                        [SimTier("t", prof, batch_size=8,
                                 per_seq_overhead_s=1e-3,
                                 continuous=True)], seed=0)
    assert cont.wait_s.max() == 0.0
    assert np.array_equal(plain.latency_s, cont.latency_s)
    assert np.array_equal(plain.tier, cont.tier)


def test_sim_continuous_charges_overhead_per_live_slot():
    """Two overlapping requests: the second starts while the first is
    live, so it pays exactly one per-slot overhead; the first pays none."""
    prof = DeviceProfile("t", LinearLatencyModel(0.0, 0.0, 0.1), 0.0)
    stream = RequestStream(np.array([0.0, 0.01]),
                           np.full(2, 8.0), np.full(2, 8.0),
                           np.full(2, 8.0))
    r = simulate_des(_solo_sched(prof, batch_size=4, o=0.01), stream,
                     [SimTier("t", prof, batch_size=4,
                              per_seq_overhead_s=0.01, continuous=True)],
                     seed=0)
    assert r.exec_s[0] == pytest.approx(0.1)
    assert r.exec_s[1] == pytest.approx(0.11)
    assert r.wait_s.max() == 0.0           # both found a free slot


def test_sim_continuous_beats_block_under_load():
    """The benchmark's acceptance bar at test scale: heterogeneous
    service + saturating Poisson load -> continuous strictly improves
    p95 AND SLO attainment over block-to-completion."""
    prof = DeviceProfile("t", LinearLatencyModel(2e-5, 2e-3, 1e-3), 0.05)
    rng = np.random.default_rng(7)
    k = 800
    n = rng.integers(2, 60, k).astype(np.float64)
    stream = make_poisson_stream(n, n, n, rate_hz=80.0, seed=7, slo_s=0.1)
    kw = dict(servers=1, queue_capacity=256, batch_size=8,
              per_seq_overhead_s=1e-3)
    block = simulate_des(_solo_sched(prof, batch_size=8, o=1e-3), stream,
                         [SimTier("t", prof, **kw)], seed=0)
    cont = simulate_des(_solo_sched(prof, batch_size=8, o=1e-3), stream,
                        [SimTier("t", prof, continuous=True, **kw)],
                        seed=0)
    assert cont.p95_latency_s() < block.p95_latency_s()
    assert cont.slo_attainment() > block.slo_attainment()


def test_sim_continuous_rejects_token_budget():
    with pytest.raises(ValueError, match="per-slot"):
        SimTier("t", _flat_tier_profile(), batch_size=4,
                continuous=True, max_batch_tokens=64)


# ------------------------------------------ property-based invariants -----
class _FakeSlotSession:
    """Deterministic in-memory slot table implementing the protocol
    ``serve_continuous`` drives (admit/step/live_count/free_slots/...).

    A request's decode length is derived from its first prompt token, so
    random traces produce staggered evictions without any model math.
    Slot conservation (live + free == max_slots) is asserted on every
    mutation — any engine bug that oversubscribes trips it immediately.
    """

    def __init__(self, max_slots=4, max_len=64):
        class _Cfg:
            vocab_size = 1 << 30
            is_encoder_decoder = False

        class _Model:
            cfg = _Cfg()

        self.model = _Model()
        self.max_slots = max_slots
        self.max_len = max_len
        self._rows = {}                    # slot -> [req_id, steps_left]
        self.admit_log = []                # req ids in admission order
        self.n_steps = 0
        self.n_prefills = 0
        self.peak_live = 0

    def _check(self):
        assert 0 <= self.live_count <= self.max_slots
        assert self.live_count + self.free_slots == self.max_slots

    @property
    def live_count(self):
        return len(self._rows)

    @property
    def free_slots(self):
        return self.max_slots - len(self._rows)

    def admit(self, prompts, *, max_new, req_ids=None):
        assert len(prompts) <= self.free_slots, "slot oversubscription"
        free = [s for s in range(self.max_slots) if s not in self._rows]
        for j, (p, rid) in enumerate(zip(prompts, req_ids)):
            steps = int(np.asarray(p).reshape(-1)[0]) % max_new + 1
            self._rows[free[j]] = [rid, steps]
            self.admit_log.append(rid)
        self.n_prefills += 1
        self.peak_live = max(self.peak_live, self.live_count)
        self._check()
        return free[:len(prompts)]

    def step(self):
        finished = []
        for s, row in list(self._rows.items()):
            row[1] -= 1
            if row[1] <= 0:
                finished.append((row[0], 1, np.array([1], np.int32)))
                del self._rows[s]
        self.n_steps += 1
        self._check()
        return [], finished


def _fake_engine(max_slots=3, queue_capacity=None):
    sess = _FakeSlotSession(max_slots=max_slots)
    eng = CollaborativeEngine(
        n2m=LinearN2M(1.0, 0.0),
        tiers=[Tier(_flat_tier_profile(), name="npu", servers=1,
                    queue_capacity=queue_capacity, batch_size=max_slots,
                    continuous_session=sess)], seed=0)
    return sess, eng


@pytest.mark.property
@settings(max_examples=25)
@given(tokens=st.lists(st.integers(1, 9), min_size=2, max_size=14),
       classes=st.lists(st.sampled_from([0.5, 2.0, -1.0]), min_size=2,
                        max_size=14),
       slots=st.integers(1, 3))
def test_admission_is_edf_with_fifo_within_class(tokens, classes, slots):
    """All requests arrive together; the wait queue must drain earliest
    deadline first, FIFO among equal deadlines (None = last class)."""
    k = min(len(tokens), len(classes))
    tokens, classes = tokens[:k], classes[:k]
    deadlines = [None if c < 0 else c for c in classes]
    sess, eng = _fake_engine(max_slots=slots)
    prompts = [np.array([t, t], np.int32) for t in tokens]
    res = eng.serve_continuous(prompts, deadline_s=deadlines, max_new=8)
    assert not any(r.shed for r in res)
    # the first admission wave fills the empty table from the already-
    # sorted queue, so the WHOLE admit log must be the EDF/FIFO order
    key = [(np.inf if d is None else d, i) for i, d in enumerate(deadlines)]
    expected = [i for _, i in sorted(zip(key, range(k)))]
    assert sess.admit_log == expected


@pytest.mark.property
@settings(max_examples=25)
@given(tokens=st.lists(st.integers(1, 9), min_size=1, max_size=16),
       gaps=st.lists(st.floats(0.0, 0.02), min_size=1, max_size=16),
       cap=st.integers(0, 2))
def test_no_drop_without_shed_record(tokens, gaps, cap):
    """Every request either completes or comes back as an explicit shed
    record — nothing vanishes, whatever the queue bound or deadlines."""
    k = min(len(tokens), len(gaps))
    sess, eng = _fake_engine(max_slots=2, queue_capacity=cap)
    prompts = [np.array([t, t], np.int32) for t in tokens[:k]]
    res = eng.serve_continuous(prompts,
                               arrival_s=list(np.cumsum(gaps[:k])),
                               deadline_s=1e-9, max_new=8)
    assert all(r is not None for r in res)
    served = [r for r in res if not r.shed]
    shed = [r for r in res if r.shed]
    assert len(served) + len(shed) == k
    for r in served:
        assert r.m_out >= 1 and np.isfinite(r.latency_s)
    for r in shed:
        assert r.device == -1 and np.isnan(r.latency_s)


@pytest.mark.property
@settings(max_examples=25)
@given(tokens=st.lists(st.integers(1, 9), min_size=1, max_size=20),
       gaps=st.lists(st.floats(0.0, 0.05), min_size=1, max_size=20),
       slots=st.integers(1, 4))
def test_slot_table_conservation_over_random_traces(tokens, gaps, slots):
    """live + free == max_slots across arbitrary arrival/eviction traces
    (asserted inside the fake on every mutation) and the table never
    exceeds its capacity at any point."""
    k = min(len(tokens), len(gaps))
    sess, eng = _fake_engine(max_slots=slots)
    prompts = [np.array([t, t], np.int32) for t in tokens[:k]]
    res = eng.serve_continuous(prompts,
                               arrival_s=list(np.cumsum(gaps[:k])),
                               max_new=8)
    assert sess.peak_live <= slots
    assert sess.live_count == 0            # drained at the end
    assert sorted(sess.admit_log) == list(range(k))
    assert sum(not r.shed for r in res) == k
