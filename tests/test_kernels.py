"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Per instructions each kernel is swept over shapes/dtypes and
assert_allclose'd against the ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey


# ------------------------------------------------------- flash attention --
@pytest.mark.parametrize("b,s,h,hkv,d", [
    (1, 64, 4, 4, 32),      # MHA
    (2, 128, 8, 2, 64),     # GQA rep=4
    (1, 256, 4, 1, 64),     # MQA
    (2, 96, 4, 2, 32),      # non-multiple S (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, s, h, hkv, d, dtype):
    ks = jax.random.split(KEY(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_length_masking_matches_ref(causal):
    """Per-sequence valid-key prefixes (ragged padded batches) — the
    batched Marian encoder/teacher path contract, padded rows included."""
    b, s, h, d = 3, 96, 4, 32
    ks = jax.random.split(KEY(11), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    lens = jnp.asarray([96, 40, 1], jnp.int32)
    out = ops.flash_attention(q, k, v, lens, causal=causal, block_q=32,
                              block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, lengths=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_length_equals_full_is_identity():
    """lengths = T must agree with the no-lengths call bit-for-bit."""
    ks = jax.random.split(KEY(12), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                            interpret=True)
    b = ops.flash_attention(q, k, v, jnp.full((2,), 64, jnp.int32),
                            causal=True, block_q=32, block_k=32,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flash_attention_length_one_attends_single_key():
    """length=1, non-causal: every query row reduces to v[:, 0]."""
    ks = jax.random.split(KEY(13), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    out = ops.flash_attention(q, k, v, jnp.asarray([1], jnp.int32),
                              causal=False, block_q=32, block_k=32,
                              interpret=True)
    want = jnp.broadcast_to(v[:, 0][:, None], out.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    out = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([32, 64, 160]),
    h=st.sampled_from([2, 4]),
    rep=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 64]),
)
def test_flash_attention_property_sweep(s, h, rep, d):
    hkv = h
    hq = h * rep
    ks = jax.random.split(KEY(s * h * d), 3)
    q = jax.random.normal(ks[0], (1, s, hq, d))
    k = jax.random.normal(ks[1], (1, s, hkv, d))
    v = jax.random.normal(ks[2], (1, s, hkv, d))
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32,
                              interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------- flash decode --
@pytest.mark.parametrize("b,s,h,hkv,d", [
    (2, 128, 4, 4, 32),
    (3, 256, 8, 2, 64),
    (1, 512, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(b, s, h, hkv, d, dtype):
    ks = jax.random.split(KEY(2), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    lengths = jnp.asarray([s // 4, s // 2, s][:b], jnp.int32)
    out = ops.flash_decode(q, kc, vc, lengths, block_s=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_zero_length_is_masked():
    """length=1 attends only to slot 0 regardless of cache contents."""
    b, s, h, d = 1, 64, 2, 32
    ks = jax.random.split(KEY(3), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, s, h, d))
    vc = jax.random.normal(ks[2], (b, s, h, d))
    out = ops.flash_decode(q, kc, vc, jnp.asarray([1], jnp.int32),
                           block_s=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vc[:, 0]),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ rwkv6 --
@pytest.mark.parametrize("b,s,h,p,chunk", [
    (1, 32, 2, 16, 32),     # single chunk
    (2, 64, 2, 32, 32),     # two chunks (state carry)
    (1, 128, 4, 64, 32),    # production head dim
    (2, 96, 1, 16, 32),     # three chunks
])
def test_rwkv6_wkv_matches_recurrence(b, s, h, p, chunk):
    ks = jax.random.split(KEY(4), 5)
    r = jax.random.normal(ks[0], (b, s, h, p))
    k = jax.random.normal(ks[1], (b, s, h, p))
    v = jax.random.normal(ks[2], (b, s, h, p))
    log_w = -jnp.clip(jnp.exp(jax.random.normal(ks[3], (b, s, h, p))),
                      1e-4, 2.5)
    u = jax.random.normal(ks[4], (h, p)) * 0.5
    y, s_t = ops.rwkv6_wkv(r, k, v, log_w, u, chunk=chunk, interpret=True)
    y_ref, s_ref = ref.rwkv6_ref(r, k, v, log_w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_wkv_initial_state():
    b, s, h, p = 1, 32, 2, 16
    ks = jax.random.split(KEY(5), 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    log_w = -jnp.clip(jnp.exp(jax.random.normal(ks[3], (b, s, h, p))),
                      1e-4, 2.5)
    u = jax.random.normal(ks[4], (h, p))
    s0 = jax.random.normal(ks[5], (b, h, p, p))
    y, s_t = ops.rwkv6_wkv(r, k, v, log_w, u, s0, interpret=True)
    y_ref, s_ref = ref.rwkv6_ref(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    s=st.sampled_from([32, 64]),
    p=st.sampled_from([16, 32]),
    seed=st.integers(0, 100),
)
def test_rwkv6_property_sweep(s, p, seed):
    b, h = 1, 2
    ks = jax.random.split(KEY(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, p)) for i in range(3))
    log_w = -jnp.clip(jnp.exp(jax.random.normal(ks[3], (b, s, h, p))),
                      1e-4, 2.5)
    u = jax.random.normal(ks[4], (h, p)) * 0.3
    y, _ = ops.rwkv6_wkv(r, k, v, log_w, u, interpret=True)
    y_ref, _ = ref.rwkv6_ref(r, k, v, log_w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-4)


# -------------------------------------------------------------------- ssd --
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 64),     # one chunk
    (2, 128, 2, 32, 16, 64),   # two chunks
    (1, 256, 4, 64, 64, 64),   # production dims
    (1, 192, 1, 16, 8, 64),    # three chunks
])
def test_ssd_scan_matches_recurrence(b, s, h, p, n, chunk):
    ks = jax.random.split(KEY(6), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    b_in = jax.random.normal(ks[2], (b, s, h, n))
    c_in = jax.random.normal(ks[3], (b, s, h, n))
    y, s_t = ops.ssd_scan(x, dt, a_log, b_in, c_in, chunk=chunk,
                          interpret=True)
    y_ref, s_ref = ref.ssd_ref(x, dt, a_log, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_scan_initial_state():
    b, s, h, p, n = 1, 64, 2, 16, 8
    ks = jax.random.split(KEY(7), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.zeros((h,))
    b_in = jax.random.normal(ks[2], (b, s, h, n))
    c_in = jax.random.normal(ks[3], (b, s, h, n))
    s0 = jax.random.normal(ks[4], (b, h, p, n))
    y, s_t = ops.ssd_scan(x, dt, a_log, b_in, c_in, s0, interpret=True)
    y_ref, s_ref = ref.ssd_ref(x, dt, a_log, b_in, c_in, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=8, deadline=None)
@given(
    s=st.sampled_from([64, 128]),
    n=st.sampled_from([8, 32]),
    seed=st.integers(0, 100),
)
def test_ssd_property_sweep(s, n, seed):
    b, h, p = 1, 2, 16
    ks = jax.random.split(KEY(seed + 1000), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(0.5, 4.0, h))
    b_in = jax.random.normal(ks[2], (b, s, h, n))
    c_in = jax.random.normal(ks[3], (b, s, h, n))
    y, _ = ops.ssd_scan(x, dt, a_log, b_in, c_in, chunk=64, interpret=True)
    y_ref, _ = ref.ssd_ref(x, dt, a_log, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-4)
