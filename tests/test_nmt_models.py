"""Tests for the paper-faithful seq2seq models (BiLSTM / GRU / Marian)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.tokenizer import BOS_ID, EOS_ID
from repro.models.registry import resolve as registry_resolve
from repro.nmt import (
    BiLSTMSeq2Seq,
    GRUSeq2Seq,
    MarianTransformer,
    RNNConfig,
    TransformerConfig,
    PAPER_MODELS,
)

V = 64


def _models():
    return [
        ("bilstm", BiLSTMSeq2Seq(RNNConfig(vocab_src=V, vocab_tgt=V, embed=32,
                                           hidden=32, layers=2,
                                           max_decode_len=24))),
        ("gru", GRUSeq2Seq(RNNConfig(vocab_src=V, vocab_tgt=V, embed=32,
                                     hidden=32, layers=1, max_decode_len=24))),
        ("marian", MarianTransformer(TransformerConfig(
            vocab_src=V, vocab_tgt=V, d_model=32, heads=4, d_ff=64,
            enc_layers=2, dec_layers=2, max_decode_len=24, max_src_len=64))),
    ]


@pytest.mark.parametrize("name,model", _models())
def test_translate_produces_tokens(name, model):
    params = model.init(jax.random.PRNGKey(0))
    translate = model.make_translate(params)
    src = np.array([5, 6, 7, 8, EOS_ID], np.int32)
    m_out, tokens = translate(src)
    assert 0 <= m_out <= 24
    assert tokens.shape == (m_out,)
    assert np.all(np.asarray(tokens) >= 0) and np.all(np.asarray(tokens) < V)


@pytest.mark.parametrize("name,model", _models())
def test_teacher_forward_shapes_and_finite(name, model):
    params = model.init(jax.random.PRNGKey(1))
    B, N, M = 3, 7, 5
    rng = np.random.default_rng(0)
    batch = {
        "src": rng.integers(4, V, (B, N)).astype(np.int32),
        "src_mask": np.ones((B, N), np.float32),
        "tgt_in": rng.integers(4, V, (B, M)).astype(np.int32),
        "tgt_out": rng.integers(4, V, (B, M)).astype(np.int32),
        "tgt_mask": np.ones((B, M), np.float32),
    }
    logits = model.forward_teacher(params, batch["src"], batch["src_mask"],
                                   batch["tgt_in"])
    assert logits.shape == (B, M, V)
    assert bool(jnp.isfinite(logits).all())
    loss = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name,model", _models())
def test_loss_decreases_with_sgd(name, model):
    """A few SGD steps on a fixed batch reduce the loss (trainability)."""
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(1)
    B, N, M = 4, 6, 6
    batch = {
        "src": rng.integers(4, V, (B, N)).astype(np.int32),
        "src_mask": np.ones((B, N), np.float32),
        "tgt_in": rng.integers(4, V, (B, M)).astype(np.int32),
        "tgt_out": rng.integers(4, V, (B, M)).astype(np.int32),
        "tgt_mask": np.ones((B, M), np.float32),
    }
    loss_fn = jax.jit(lambda p: model.loss(p, batch))
    grad_fn = jax.jit(jax.grad(lambda p: model.loss(p, batch)))
    l0 = float(loss_fn(params))
    for _ in range(15):
        g = grad_fn(params)
        params = jax.tree.map(lambda p, gi: p - 0.5 * gi, params, g)
    l1 = float(loss_fn(params))
    assert l1 < l0 - 0.1


def test_marian_cache_decode_matches_teacher_forward():
    """Incremental KV-cache decode == parallel causally-masked forward."""
    model = MarianTransformer(TransformerConfig(
        vocab_src=V, vocab_tgt=V, d_model=32, heads=4, d_ff=64,
        enc_layers=2, dec_layers=2, max_decode_len=16, max_src_len=32))
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(2)
    src = rng.integers(4, V, (9,)).astype(np.int32)
    tgt = rng.integers(4, V, (6,)).astype(np.int32)

    # parallel path
    logits_par = model.forward_teacher(
        params, src[None], np.ones((1, 9), np.float32), tgt[None])[0]

    # incremental path
    enc_outs, mask = model.encode(params, src)
    state = model.init_cache(params, enc_outs, mask)
    logits_inc = []
    for t in tgt:
        state, lg = model.decode_step(params, state, jnp.asarray(t))
        logits_inc.append(lg)
    logits_inc = jnp.stack(logits_inc)
    np.testing.assert_allclose(np.asarray(logits_par), np.asarray(logits_inc),
                               rtol=2e-4, atol=2e-4)


def test_gru_context_is_fixed_size():
    model = GRUSeq2Seq(RNNConfig(vocab_src=V, vocab_tgt=V, embed=16,
                                 hidden=24, layers=1))
    params = model.init(jax.random.PRNGKey(0))
    for n in (3, 11, 29):
        ctx = model.encode(params, np.arange(4, 4 + n, dtype=np.int32))
        assert ctx.shape == (24,)


def test_registry_builds_all_three():
    for ds, family in [("de-en", BiLSTMSeq2Seq), ("fr-en", GRUSeq2Seq),
                       ("en-zh", MarianTransformer)]:
        r = registry_resolve(f"cnmt:{ds}", scale=0.1, vocab=128)
        model, pair = r.model, r.pair
        assert isinstance(model, family)
        assert pair == ds
        params = model.init(jax.random.PRNGKey(0))
        translate = model.make_translate(params)
        m, toks = translate(np.array([5, 9, 11, EOS_ID], np.int32))
        assert m >= 0
