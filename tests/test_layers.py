"""Layer-level correctness: chunked/cached paths vs step-by-step oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.config import (
    LayerGroup,
    MLAConfig,
    ModelConfig,
    RWKVConfig,
    SSMConfig,
)
from repro.models.layers import attention as att
from repro.models.layers import mamba2 as mb
from repro.models.layers import rwkv6 as rk
from repro.models.layers.basic import apply_rope, rmsnorm, rmsnorm_params


def _attn_cfg(**kw):
    base = dict(
        name="t", arch_type="dense", d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        layer_plan=(LayerGroup(mixer="attn", ffn="dense", count=1),),
    )
    base.update(kw)
    return ModelConfig(**base).validate()


# ------------------------------------------------------------- attention --
def test_attn_full_matches_ref():
    cfg = _attn_cfg()
    p = att.gqa_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    y, (k, v) = att.attn_full(p, cfg, x)
    # recompute with oracle on the produced q,k,v
    positions = jnp.broadcast_to(jnp.arange(12)[None, :], (2, 12))
    q, k2, v2 = att._qkv(p, cfg, x, positions)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k2), rtol=1e-6)
    o = ref.attention_ref(q, k2, v2, causal=True)
    y_ref = att.linear(p["o"], o.reshape(2, 12, -1))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_attn_decode_matches_full():
    """Incremental decode over a prefix == full forward at each position."""
    cfg = _attn_cfg(qk_norm=True)
    p = att.gqa_params(jax.random.PRNGKey(0), cfg)
    s = 10
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model))
    y_full, _ = att.attn_full(p, cfg, x)

    ck = jnp.zeros((2, s, cfg.num_kv_heads, cfg.head_dim))
    cv = jnp.zeros_like(ck)
    ys = []
    for t in range(s):
        y_t, ck, cv = att.attn_decode(p, cfg, x[:, t:t + 1], ck, cv,
                                      jnp.full((2,), t, jnp.int32))
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_inc),
                               rtol=3e-5, atol=3e-5)


def test_attn_sliding_window_full_vs_decode():
    cfg = _attn_cfg(sliding_window=4)
    p = att.gqa_params(jax.random.PRNGKey(2), cfg)
    s = 12
    x = jax.random.normal(jax.random.PRNGKey(3), (1, s, cfg.d_model))
    y_full, _ = att.attn_full(p, cfg, x, window=4)
    ck = jnp.zeros((1, s, cfg.num_kv_heads, cfg.head_dim))
    cv = jnp.zeros_like(ck)
    ys = []
    for t in range(s):
        y_t, ck, cv = att.attn_decode(p, cfg, x[:, t:t + 1], ck, cv,
                                      jnp.full((1,), t, jnp.int32), window=4)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=3e-5, atol=3e-5)


def test_attn_ring_cache_matches_window_decode():
    """Ring cache (capacity == window) == linear cache with window mask."""
    cfg = _attn_cfg(sliding_window=4)
    p = att.gqa_params(jax.random.PRNGKey(2), cfg)
    s = 11
    x = jax.random.normal(jax.random.PRNGKey(3), (1, s, cfg.d_model))
    # linear cache with window masking
    ck = jnp.zeros((1, s, cfg.num_kv_heads, cfg.head_dim))
    cv = jnp.zeros_like(ck)
    # ring cache sized to the window
    rk_ = jnp.zeros((1, 4, cfg.num_kv_heads, cfg.head_dim))
    rv_ = jnp.zeros_like(rk_)
    for t in range(s):
        pos = jnp.full((1,), t, jnp.int32)
        y_lin, ck, cv = att.attn_decode(p, cfg, x[:, t:t + 1], ck, cv, pos,
                                        window=4)
        y_ring, rk_, rv_ = att.attn_decode(p, cfg, x[:, t:t + 1], rk_, rv_,
                                           pos, ring=True)
        np.testing.assert_allclose(np.asarray(y_lin), np.asarray(y_ring),
                                   rtol=3e-5, atol=3e-5)


def _mla_cfg():
    return ModelConfig(
        name="t", arch_type="moe", d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        layer_plan=(LayerGroup(mixer="mla", ffn="dense", count=1),),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    ).validate()


def test_mla_decode_absorbed_matches_full():
    """The absorbed compressed-latent decode == the expanded full form."""
    cfg = _mla_cfg()
    p = att.mla_params(jax.random.PRNGKey(0), cfg)
    s = 9
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model))
    y_full, (ckv, kpe) = att.mla_full(p, cfg, x)

    c_ckv = jnp.zeros((2, s, cfg.mla.kv_lora_rank))
    c_kpe = jnp.zeros((2, s, cfg.mla.qk_rope_head_dim))
    ys = []
    for t in range(s):
        y_t, c_ckv, c_kpe = att.mla_decode(p, cfg, x[:, t:t + 1], c_ckv,
                                           c_kpe, jnp.full((2,), t, jnp.int32))
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_inc),
                               rtol=5e-5, atol=5e-5)
    # the cache really is the compressed latent
    assert c_ckv.shape[-1] == cfg.mla.kv_lora_rank


# ---------------------------------------------------------------- mamba2 --
def _ssm_cfg(chunk=8):
    return ModelConfig(
        name="t", arch_type="ssm", d_model=32, vocab_size=64,
        layer_plan=(LayerGroup(mixer="mamba2", ffn="none", count=1),),
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, conv_width=4,
                      chunk=chunk),
    ).validate()


def test_mamba2_chunked_matches_recurrence_oracle():
    """The chunked SSD inside mamba2_full == ref.ssd_ref step recurrence."""
    cfg = _ssm_cfg(chunk=8)
    s_len = 24
    b, h, p_, n = 2, 4, 16, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s_len, h, p_))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s_len, h)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    b_in = jax.random.normal(ks[2], (b, s_len, h, n))
    c_in = jax.random.normal(ks[3], (b, s_len, h, n))
    y_ref, s_ref = ref.ssd_ref(x, dt, a_log, b_in, c_in)

    # drive the model's chunked path with the same inputs by monkey-level
    # re-implementation: reuse mamba2_full's inner `chunked` via a direct
    # call path (reconstructed here to the same algebra).
    from repro.models.layers.mamba2 import mamba2_full  # noqa
    # Instead of poking internals, test equivalence through the public
    # one-step decode: run ssd chunked via full layer vs decode chain below.
    assert y_ref.shape == (b, s_len, h, p_)
    assert s_ref.shape == (b, h, p_, n)


def test_mamba2_layer_full_matches_decode_chain():
    """mamba2_full over S tokens == S x mamba2_decode (same params/state)."""
    cfg = _ssm_cfg(chunk=8)
    p = mb.mamba2_params(jax.random.PRNGKey(0), cfg)
    s_len = 16
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s_len, cfg.d_model)) * 0.5
    y_full, st_full = mb.mamba2_full(p, cfg, x)

    st = mb.init_mamba_state(cfg, 2)
    ys = []
    for t in range(s_len):
        y_t, st = mb.mamba2_decode(p, cfg, x[:, t:t + 1], st)
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_inc),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full.ssm), np.asarray(st.ssm),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full.conv), np.asarray(st.conv),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------- rwkv6 --
def _rwkv_cfg():
    return ModelConfig(
        name="t", arch_type="ssm", d_model=64, vocab_size=64,
        layer_plan=(LayerGroup(mixer="rwkv6", ffn="rwkv_cm", count=1),),
        rwkv=RWKVConfig(head_dim=16, decay_lora=8),
    ).validate()


def test_rwkv6_layer_full_matches_decode_chain():
    cfg = _rwkv_cfg()
    p = rk.rwkv6_params(jax.random.PRNGKey(0), cfg)
    s_len = 32   # one chunk boundary exactly
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s_len, cfg.d_model)) * 0.5
    st0 = rk.init_rwkv_state(cfg, 2)
    y_full, st_full = rk.rwkv6_full(p, cfg, x, st0)

    st = rk.init_rwkv_state(cfg, 2)
    ys = []
    for t in range(s_len):
        y_t, st = rk.rwkv6_decode(p, cfg, x[:, t:t + 1], st)
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_inc),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_full.wkv), np.asarray(st.wkv),
                               rtol=3e-4, atol=3e-4)


def test_rwkv6_multi_chunk_state_carry():
    """64 tokens = 2 chunks: inter-chunk state propagation is exercised."""
    cfg = _rwkv_cfg()
    p = rk.rwkv6_params(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model)) * 0.5
    st0 = rk.init_rwkv_state(cfg, 1)
    y_full, _ = rk.rwkv6_full(p, cfg, x, st0)
    st = rk.init_rwkv_state(cfg, 1)
    ys = []
    for t in range(64):
        y_t, st = rk.rwkv6_decode(p, cfg, x[:, t:t + 1], st)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=5e-4, atol=5e-4)


def test_rwkv6_channel_mix_shift():
    cfg = _rwkv_cfg()
    p = rk.channel_mix_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    st = rk.init_rwkv_state(cfg, 2)
    y_full, st_f = rk.channel_mix_full(p, cfg, x, st)
    st2 = rk.init_rwkv_state(cfg, 2)
    ys = []
    for t in range(6):
        y_t, st2 = rk.channel_mix_decode(p, cfg, x[:, t:t + 1], st2)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-5, atol=1e-5)
