"""Sharded-execution integration tests.

Runs REAL pjit execution (not just lowering) on small host-device meshes
in subprocesses (the device count must be set before jax initializes, so
each case gets a fresh interpreter).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_executes():
    """One real AdamW step of a smoke arch on a 2x4 mesh."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models.model import LM
        from repro.sharding.policy import (make_policy, train_state_specs,
                                           batch_specs, to_shardings)
        from repro.training.train_loop import init_train_state, make_train_step

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config("qwen3-8b")
        model = LM(cfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        pol = make_policy(mesh, batch_size=4)
        st_sh = to_shardings(mesh, train_state_specs(
            pol, jax.eval_shape(lambda: state)))
        state = jax.device_put(state, st_sh)
        rng = np.random.default_rng(0)
        toks = rng.integers(1, cfg.vocab_size, (4, 16)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks),
                 "targets": jnp.asarray(np.roll(toks, -1, 1))}
        b_sh = to_shardings(mesh, batch_specs(
            pol, jax.eval_shape(lambda: batch)))
        batch = jax.device_put(batch, b_sh)
        step = jax.jit(make_train_step(model), in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None))
        losses = []
        for _ in range(3):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("LOSSES", losses)
    """)
    assert "LOSSES" in out


def test_sharded_decode_matches_single_device():
    """Sharded serve_step == single-device decode_step numerically."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models.model import LM
        from repro.sharding.policy import (make_policy, param_specs,
                                           decode_state_specs, to_shardings)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config("qwen3-8b")
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 8)), jnp.int32)

        # reference: single-device
        _, st_ref = model.prefill(params, toks, max_len=16)
        tok = jnp.full((4, 1), 7, jnp.int32)
        logits_ref, _ = model.decode_step(params, st_ref, tok)

        # sharded
        pol = make_policy(mesh, batch_size=4)
        p_sh = to_shardings(mesh, param_specs(
            pol, jax.eval_shape(lambda: params)))
        params_s = jax.device_put(params, p_sh)
        _, st = jax.jit(lambda p, t: model.prefill(p, t, max_len=16))(
            params_s, toks)
        st_specs = to_shardings(mesh, decode_state_specs(
            pol, jax.eval_shape(lambda: st)))
        st = jax.device_put(st, st_specs)
        logits_s, _ = jax.jit(model.decode_step)(params_s, st, tok)
        np.testing.assert_allclose(np.asarray(logits_ref),
                                   np.asarray(logits_s),
                                   rtol=2e-4, atol=2e-4)
        print("MATCH")
    """)
    assert "MATCH" in out


def test_shard_map_flash_decode_matches_reference():
    """The §Perf decode optimization is numerically exact on a real mesh."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.config import LayerGroup, ModelConfig
        from repro.models.layers import attention as att

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = ModelConfig(
            name="t", arch_type="dense", d_model=64, vocab_size=128,
            num_heads=8, num_kv_heads=4, head_dim=16, d_ff=128,
            layer_plan=(LayerGroup(mixer="attn", ffn="dense", count=1),),
        ).validate()
        p = att.gqa_params(jax.random.PRNGKey(0), cfg)
        b, s_max = 4, 32
        x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model))
        ck = jax.random.normal(jax.random.PRNGKey(2),
                               (b, s_max, 4, 16)) * 0.3
        cv = jax.random.normal(jax.random.PRNGKey(3),
                               (b, s_max, 4, 16)) * 0.3
        pos = jnp.asarray([5, 11, 17, 29], jnp.int32)

        y_ref, ck_ref, cv_ref = att.attn_decode(p, cfg, x, ck, cv, pos)

        ck_s = jax.device_put(ck, NamedSharding(
            mesh, P("data", "model", None, None)))
        cv_s = jax.device_put(cv, NamedSharding(
            mesh, P("data", "model", None, None)))
        y_sm, ck_sm, cv_sm = jax.jit(
            lambda *a: att.attn_decode_seq_sharded(
                p, cfg, *a, mesh=mesh, seq_axis="model",
                batch_axes=("data",))
        )(x, ck_s, cv_s, pos)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(np.asarray(ck_ref), np.asarray(ck_sm),
                                   rtol=1e-6, atol=1e-6)
        print("MATCH")
    """)
    assert "MATCH" in out


def test_moe_sharded_forward_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models.model import LM
        from repro.sharding.policy import make_policy, param_specs, to_shardings

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config("qwen3-moe-30b-a3b")
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 16)), jnp.int32)
        ref = model.train_logits(params, toks)["logits"]

        pol = make_policy(mesh, batch_size=4)
        p_sh = to_shardings(mesh, param_specs(
            pol, jax.eval_shape(lambda: params)))
        params_s = jax.device_put(params, p_sh)
        out = jax.jit(lambda p, t: model.train_logits(p, t)["logits"])(
            params_s, toks)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=3e-4, atol=3e-4)
        print("MATCH")
    """)
    assert "MATCH" in out
