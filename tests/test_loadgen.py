"""Load-generation harness (benchmarks/loadgen.py) + arrival processes.

Pins the ISSUE-9 acceptance contracts:

* arrival generators are deterministic — same seed, bit-identical trace;
* the bursty thinning sampler actually tracks its diurnal rate;
* trace save/load round-trips float64 arrival times exactly, and
  ``make_trace_stream`` emits them verbatim;
* the engine's loadgen hooks (per-request ``tag``, ``on_complete``)
  fire exactly once per request and default to strict no-ops;
* the closed loop never exceeds its concurrency;
* the trace-replay scenario issues EXACTLY the trace file's times;
* every scenario x mix smoke-runs against the real engine with a DES
  twin alongside.
"""

import json
import sys
from pathlib import Path

import numpy as np
import pytest

_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))

from benchmarks import loadgen  # noqa: E402
from repro.core.arrivals import (  # noqa: E402
    bursty_arrivals,
    diurnal_rate,
    load_trace,
    poisson_arrivals,
    save_trace,
)
from repro.core.latency_model import DeviceProfile, LinearLatencyModel  # noqa: E402
from repro.core.length_regressor import LinearN2M  # noqa: E402
from repro.core.simulator import make_trace_stream  # noqa: E402
from repro.runtime.engine import CollaborativeEngine, Tier  # noqa: E402


# ------------------------------------------------------ arrival processes --
def test_poisson_arrivals_deterministic_and_increasing():
    a = poisson_arrivals(5.0, 200, seed=3)
    b = poisson_arrivals(5.0, 200, seed=3)
    assert np.array_equal(a, b)          # same seed -> bit-identical
    assert not np.array_equal(a, poisson_arrivals(5.0, 200, seed=4))
    assert np.all(np.diff(a) > 0)
    # mean gap ~ 1/rate (loose: 200 samples)
    assert abs(float(np.diff(a).mean()) - 0.2) < 0.05
    assert poisson_arrivals(5.0, 3, seed=0, t0=10.0)[0] > 10.0


def test_poisson_arrivals_validation():
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 10)
    with pytest.raises(ValueError):
        poisson_arrivals(1.0, -1)


def test_bursty_arrivals_deterministic():
    a = bursty_arrivals(300, base_rate_hz=5.0, seed=7)
    b = bursty_arrivals(300, base_rate_hz=5.0, seed=7)
    assert np.array_equal(a, b)
    assert np.all(np.diff(a) > 0)
    with pytest.raises(ValueError):
        bursty_arrivals(10, base_rate_hz=5.0, peak_factor=0.5)


def test_diurnal_rate_trough_and_peak():
    assert diurnal_rate(0.0, 2.0, 4.0, 60.0) == pytest.approx(2.0)
    assert diurnal_rate(30.0, 2.0, 4.0, 60.0) == pytest.approx(8.0)
    assert diurnal_rate(60.0, 2.0, 4.0, 60.0) == pytest.approx(2.0)


def test_bursty_sampler_tracks_the_diurnal_modulation():
    period = 40.0
    arr = bursty_arrivals(2000, base_rate_hz=5.0, peak_factor=4.0,
                          period_s=period, seed=1)
    phase = np.mod(arr, period) / period
    peak_half = int(((phase > 0.25) & (phase < 0.75)).sum())
    trough_half = len(arr) - peak_half
    # peak rate is 4x the trough rate; the split should be lopsided
    assert peak_half > 1.8 * trough_half


# ------------------------------------------------------------- trace I/O --
def test_trace_roundtrip_is_exact(tmp_path):
    arr = poisson_arrivals(11.0, 257, seed=13)
    p = tmp_path / "trace.json"
    save_trace(p, arr, meta={"rate_hz": 11.0})
    back = load_trace(p)
    assert back.dtype == np.float64
    assert np.array_equal(back, arr)     # bit-for-bit through JSON

    stream = make_trace_stream(back, np.ones(257), np.ones(257))
    assert np.array_equal(stream.t_arrival_s, arr)


def test_trace_validation(tmp_path):
    with pytest.raises(ValueError):
        save_trace(tmp_path / "x.json", [[0.0, 1.0]])      # not 1-D
    with pytest.raises(ValueError):
        save_trace(tmp_path / "x.json", [2.0, 1.0])        # decreasing
    (tmp_path / "junk.json").write_text('{"nope": 1}')
    with pytest.raises(ValueError):
        load_trace(tmp_path / "junk.json")
    with pytest.raises(ValueError):
        make_trace_stream([0.0, 1.0], [1, 2, 3], [1, 2, 3])  # len mismatch
    with pytest.raises(ValueError):
        make_trace_stream([1.0, 0.5], [1, 2], [1, 2])      # decreasing


# ------------------------------------------------------------ engine hooks --
def _tiny_engine():
    prof = DeviceProfile("t", LinearLatencyModel(1e-4, 1e-4, 1e-3), 0.05)
    return CollaborativeEngine(n2m=LinearN2M(0.9, 2.0),
                               tiers=[Tier(prof)], seed=0)


def test_engine_tag_and_on_complete_hook():
    eng = _tiny_engine()
    seen = []
    eng.on_complete = seen.append
    res = eng.submit(np.zeros(5, np.int32), now_s=0.0, tag="poisson/chat")
    assert res.tag == "poisson/chat"
    assert seen == [res]                 # fired exactly once, with the result
    batch = eng.submit_batch([np.zeros(3, np.int32)] * 2, now_s=1.0,
                             tag="b")
    assert [r.tag for r in batch] == ["b", "b"]
    assert seen[1:] == batch


def test_engine_hooks_default_to_noop():
    eng = _tiny_engine()
    res = eng.submit(np.zeros(5, np.int32), now_s=0.0)
    assert res.tag is None and eng.on_complete is None


# -------------------------------------------------------------- scenarios --
def test_closed_loop_concurrency_invariant():
    mix = loadgen.MIXES["chat"]
    qsl = loadgen.QuerySampleLibrary(mix, 60)
    sut = loadgen.EngineSUT(mix)
    issued = loadgen.run_closed_loop(sut, qsl, concurrency=3,
                                     think_s=0.005, tag="closed/chat")
    assert np.all(np.diff(issued) >= 0)
    assert len(sut.records) == 60
    assert loadgen.max_in_flight(sut.records) <= 3


def test_trace_replay_issues_exactly_the_file(tmp_path):
    path = tmp_path / "trace.json"
    arr, p, own = loadgen._trace_arrivals(50, 12.0, str(path))
    assert not own and p == str(path)
    mix = loadgen.MIXES["doc"]
    qsl = loadgen.QuerySampleLibrary(mix, 50)
    sut = loadgen.EngineSUT(mix)
    issued = loadgen.run_open_loop(sut, qsl, arr, tag="trace/doc")
    assert np.array_equal(issued, load_trace(path))   # bit-for-bit
    assert np.array_equal(np.asarray([r["issue_s"] for r in sut.records]),
                          load_trace(path))


@pytest.mark.slow
def test_loadgen_smoke_all_scenarios(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SMOKE", "1")
    out = tmp_path / "BENCH_loadgen.json"
    rows, csv = loadgen.run(n_requests=40, verbose=False, check=True,
                            out_json=str(out))
    assert set(rows) == {(s, m) for s in loadgen.SCENARIOS
                         for m in ("chat", "doc")}
    for (s, m), row in rows.items():
        assert row["engine"]["served"] > 0
        assert 0.0 <= row["engine"]["slo_attainment"] <= 1.0
        assert row["des_twin"]["served"] > 0
        assert "p95_latency_s" in row["drift"]
    payload = json.loads(out.read_text())
    tags = {(e["scenario"], e["mix"]) for e in payload["scenarios"]}
    assert tags == set(rows)
    assert len(csv) == len(rows)


def test_loadgen_run_is_deterministic():
    kw = dict(n_requests=25, verbose=False, check=True,
              mixes=("chat",), scenarios=("poisson", "closed"))
    r1, _ = loadgen.run(**kw)
    r2, _ = loadgen.run(**kw)
    assert r1 == r2
