"""Compiled batched decode fast path (PR 3).

Covers: bit-for-bit parity of the scan-compiled ``batched_greedy_decode``
against the per-sequence host loop for all three paper models (ragged
prefix-padded batches included), the EOS done-masking semantics of the
scan, the Pallas attention backend vs the XLA reference on the Marian
batched paths, the rewritten GenerationSession (scan vs host loop,
post-EOS masking, per-sequence lengths, ragged prompts, shape buckets),
and the engine's real batched execution (``submit_batch`` +
``build_executor(kind="batched")``).
"""

import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.models.model import LM
from repro.nmt import (
    BiLSTMSeq2Seq,
    GRUSeq2Seq,
    MarianTransformer,
    RNNConfig,
    TransformerConfig,
    batched_greedy_decode,
)
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import (
    GenerationSession,
    build_executor,
)

V = 64


def _models():
    return [
        ("bilstm", BiLSTMSeq2Seq(RNNConfig(vocab_src=V, vocab_tgt=V, embed=32,
                                           hidden=32, layers=2,
                                           max_decode_len=20))),
        ("gru", GRUSeq2Seq(RNNConfig(vocab_src=V, vocab_tgt=V, embed=32,
                                     hidden=32, layers=1, max_decode_len=20))),
        ("marian", MarianTransformer(TransformerConfig(
            vocab_src=V, vocab_tgt=V, d_model=32, heads=4, d_ff=64,
            enc_layers=2, dec_layers=2, max_decode_len=20, max_src_len=64))),
    ]


def _ragged_batch(rng, lens, vocab=V):
    b, n = len(lens), max(lens)
    src = np.zeros((b, n), np.int32)
    mask = np.zeros((b, n), np.float32)
    for i, L in enumerate(lens):
        src[i, :L] = rng.integers(4, vocab, L)
        mask[i, :L] = 1.0
    return src, mask


# ------------------------------------------------ scan vs host, per model --
@pytest.mark.parametrize("name,model", _models())
@pytest.mark.parametrize("forced_len", [None, 9])
def test_batched_scan_matches_host_loop_bitwise(name, model, forced_len):
    """The acceptance invariant: the ONE-dispatch scan path must emit
    exactly the tokens the per-sequence host loop emits, row by row,
    including on ragged prefix-padded batches."""
    params = model.init(jax.random.PRNGKey(0))
    src, mask = _ragged_batch(np.random.default_rng(0), [5, 9, 3, 7])
    l_fast, t_fast = model.make_translate_batched(params)(
        src, mask, forced_len=forced_len)
    l_fast, t_fast = np.asarray(l_fast), np.asarray(t_fast)
    l_host, t_host = model.make_translate_batched(params, compiled=False)(
        src, mask, forced_len=forced_len)
    assert np.array_equal(l_fast, l_host)
    for i in range(src.shape[0]):
        m = int(l_fast[i])
        if forced_len is None:
            assert np.array_equal(t_fast[i, :m], t_host[i, :m])
            assert np.all(t_fast[i, m + 1:] == PAD_ID)   # post-EOS masked
        else:
            assert m == forced_len
            assert np.array_equal(t_fast[i, :forced_len],
                                  t_host[i, :forced_len])


def test_batched_scan_matches_per_sequence_translate():
    """Row i of the batch == translate() of the trimmed row alone."""
    name, model = _models()[2]
    params = model.init(jax.random.PRNGKey(1))
    src, mask = _ragged_batch(np.random.default_rng(1), [4, 8])
    lens, toks = model.make_translate_batched(params)(src, mask)
    translate = model.make_translate(params)
    for i, L in enumerate([4, 8]):
        m, t = translate(src[i, :L])
        assert int(lens[i]) == m
        assert np.array_equal(np.asarray(toks)[i, :m], np.asarray(t))


# --------------------------------------------------- EOS masking semantics --
def test_batched_greedy_decode_eos_masking_controlled():
    """Deterministic fake decoder: row i emits tokens 10,11,... then EOS
    at its own stop step — lengths and PAD masking must be exact."""
    stops = jnp.asarray([2, 0, 5, 100], jnp.int32)   # 100 = never stops
    b = stops.shape[0]

    def fake_step(state, tok):
        i = state["i"]
        nxt = jnp.where(i >= stops, EOS_ID, 10 + i)
        logits = jax.nn.one_hot(nxt, V) * 10.0
        return {"i": i + 1}, logits

    lens, toks = batched_greedy_decode(fake_step,
                                       {"i": jnp.zeros((b,), jnp.int32)},
                                       b, max_len=8)
    lens, toks = np.asarray(lens), np.asarray(toks)
    assert lens.tolist() == [2, 0, 5, 8]
    assert toks[0].tolist() == [10, 11] + [PAD_ID] * 6
    assert np.all(toks[1] == PAD_ID)
    assert toks[3].tolist() == [10, 11, 12, 13, 14, 15, 16, 17]
    # forced_len ignores EOS entirely
    lens_f, toks_f = batched_greedy_decode(
        fake_step, {"i": jnp.zeros((b,), jnp.int32)}, b, max_len=8,
        forced_len=4)
    assert np.asarray(lens_f).tolist() == [4, 4, 4, 4]
    assert np.asarray(toks_f)[1].tolist() == [EOS_ID] * 4


# ------------------------------------------------------- pallas attention --
def test_marian_pallas_backend_matches_xla():
    cfg = TransformerConfig(vocab_src=V, vocab_tgt=V, d_model=32, heads=4,
                            d_ff=64, enc_layers=2, dec_layers=2,
                            max_decode_len=8, max_src_len=32)
    mx = MarianTransformer(cfg, attn_impl="xla")
    mp = MarianTransformer(cfg, attn_impl="pallas")
    params = mx.init(jax.random.PRNGKey(2))
    src, mask = _ragged_batch(np.random.default_rng(2), [5, 9])
    lx, tx = mx.make_translate_batched(params)(src, mask, forced_len=6)
    lp, tp = mp.make_translate_batched(params)(src, mask, forced_len=6)
    assert np.array_equal(np.asarray(tx), np.asarray(tp))
    assert np.array_equal(np.asarray(lx), np.asarray(lp))
    # teacher-forced (training) path parity, masked rows included
    tgt = np.random.default_rng(3).integers(4, V, (2, 5)).astype(np.int32)
    ox = mx.forward_teacher(params, src, mask, tgt)
    op = mp.forward_teacher(params, src, mask, tgt)
    np.testing.assert_allclose(np.asarray(ox), np.asarray(op),
                               rtol=2e-4, atol=2e-4)


def test_marian_attn_impl_validated():
    cfg = TransformerConfig(vocab_src=V, vocab_tgt=V)
    with pytest.raises(ValueError):
        MarianTransformer(cfg, attn_impl="cuda")


# --------------------------------------------------- GenerationSession -----
@pytest.fixture(scope="module")
def lm_session():
    cfg = smoke_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_session_scan_matches_host_loop(lm_session):
    cfg, model, params = lm_session
    scan = GenerationSession(model, params, max_len=32)
    host = GenerationSession(model, params, max_len=32, host_loop=True,
                             bucket_shapes=False)
    toks = np.random.default_rng(0).integers(
        4, cfg.vocab_size, (2, 8)).astype(np.int32)
    l1, o1 = scan.generate_with_lengths(toks, max_new=6)
    l2, o2 = host.generate_with_lengths(toks, max_new=6)
    assert np.array_equal(l1, l2)
    assert np.array_equal(o1, o2)
    out = scan.generate(toks, max_new=6)
    assert out.shape[0] == 2 and 1 <= out.shape[1] <= 6


def test_session_post_eos_positions_are_pad(lm_session):
    """Wherever a row contains EOS, everything after it must be PAD and
    the reported length must count only the pre-EOS tokens."""
    cfg, model, params = lm_session
    sess = GenerationSession(model, params, max_len=32)
    toks = np.random.default_rng(1).integers(
        4, cfg.vocab_size, (4, 6)).astype(np.int32)
    lens, out = sess.generate_with_lengths(toks, max_new=8)
    for i in range(out.shape[0]):
        row = out[i]
        eos = np.flatnonzero(row == EOS_ID)
        if eos.size:
            assert lens[i] == eos[0]
            assert np.all(row[eos[0] + 1:] == PAD_ID)
        else:
            assert lens[i] == np.sum(row != PAD_ID)


def test_session_ragged_prompt_matches_trimmed_solo(lm_session):
    cfg, model, params = lm_session
    sess = GenerationSession(model, params, max_len=32)
    rng = np.random.default_rng(2)
    full = rng.integers(4, cfg.vocab_size, (2, 9)).astype(np.int32)
    padded = full.copy()
    padded[1, 5:] = PAD_ID
    lens, out = sess.generate_with_lengths(padded, max_new=6,
                                           lengths=[9, 5])
    l_solo, o_solo = sess.generate_with_lengths(full[1:2, :5], max_new=6)
    assert lens[1] == l_solo[0]
    assert np.array_equal(out[1], o_solo[0])


def test_session_bucket_warns_once_per_shape(lm_session, caplog):
    cfg, model, params = lm_session
    sess = GenerationSession(model, params, max_len=32)
    toks = np.random.default_rng(3).integers(
        4, cfg.vocab_size, (3, 7)).astype(np.int32)
    with caplog.at_level(logging.WARNING, logger="repro.runtime.serving"):
        sess.generate_with_lengths(toks, max_new=4)
        n_first = sum("compiling new shape" in r.message
                      for r in caplog.records)
        sess.generate_with_lengths(toks[:, :5], max_new=4)  # same buckets
        n_second = sum("compiling new shape" in r.message
                       for r in caplog.records)
    assert n_first == 1 and n_second == 1
    # (3,7) and (3,5) both bucket to (4,8): one compiled shape
    assert sess._compiled_shapes == {(4, 8, 4)}


def test_session_capacity_and_ragged_guard(lm_session):
    cfg, model, params = lm_session
    sess = GenerationSession(model, params, max_len=16)
    toks = np.zeros((1, 12), np.int32)
    with pytest.raises(ValueError):
        sess.generate(toks, max_new=8)       # 12 + 8 > 16


# ---------------------------------------------------- batched executors ----
def test_batched_executor_matches_per_sequence_executor(lm_session):
    cfg, model, params = lm_session
    sess = GenerationSession(model, params, max_len=32)
    solo = build_executor(sess, kind="solo", max_new=6,
                          vocab_clip=cfg.vocab_size)
    batched = build_executor(sess, kind="batched", max_new=6,
                             vocab_clip=cfg.vocab_size)
    rng = np.random.default_rng(4)
    lens = [4, 7, 7, 5]
    block = np.full((4, 7), PAD_ID, np.int32)
    for i, L in enumerate(lens):
        block[i, :L] = rng.integers(4, cfg.vocab_size, L)
    outs = batched(block, lens)
    assert len(outs) == 4
    for i, L in enumerate(lens):
        m_b, t_b = outs[i]
        m_s, t_s = solo(block[i, :L])
        assert m_b == m_s
        assert np.array_equal(np.asarray(t_b), np.asarray(t_s))


def test_batched_executor_derives_lengths_from_trailing_pads(lm_session):
    cfg, model, params = lm_session
    sess = GenerationSession(model, params, max_len=32)
    batched = build_executor(sess, kind="batched", max_new=6,
                             vocab_clip=cfg.vocab_size)
    rng = np.random.default_rng(5)
    block = np.full((2, 8), PAD_ID, np.int32)
    block[0, :8] = rng.integers(4, cfg.vocab_size, 8)
    block[1, :3] = rng.integers(4, cfg.vocab_size, 3)
    auto = batched(block)
    explicit = batched(block, [8, 3])
    for (ma, ta), (me, te) in zip(auto, explicit):
        assert ma == me and np.array_equal(np.asarray(ta), np.asarray(te))


def test_batched_executor_recurrent_plan_runs_uniform_subgroups():
    """Plans with recurrent mixers (no ragged right-padding) must still
    serve ragged blocks — one uniform trimmed sub-batch per length —
    instead of raising."""
    cfg = smoke_config("rwkv6-3b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sess = GenerationSession(model, params, max_len=32)
    assert not sess.supports_ragged
    batched = build_executor(sess, kind="batched", max_new=4,
                             vocab_clip=cfg.vocab_size)
    solo = build_executor(sess, kind="solo", max_new=4,
                          vocab_clip=cfg.vocab_size)
    rng = np.random.default_rng(6)
    lens = [6, 3, 6]
    block = np.full((3, 6), PAD_ID, np.int32)
    for i, L in enumerate(lens):
        block[i, :L] = rng.integers(4, cfg.vocab_size, L)
    outs = batched(block, lens)
    for i, L in enumerate(lens):
        m_b, t_b = outs[i]
        m_s, t_s = solo(block[i, :L])
        assert m_b == m_s
        assert np.array_equal(np.asarray(t_b), np.asarray(t_s))


# --------------------------------------------------- engine submit_batch ---
def _flat_tier(beta, **kw):
    return Tier(DeviceProfile("t", LinearLatencyModel(0.0, 0.0, beta), 0.0),
                **kw)


def test_submit_batch_books_real_batches_into_occupancy():
    """4 concurrent requests on a batch_size=2 single-server tier: two
    real blocks; the second waits exactly the first's measured exec."""
    calls = []

    def bx(block, lens):
        calls.append(np.asarray(block).shape)
        return [(3, np.array([7, 7, EOS_ID]))] * len(lens)

    eng = CollaborativeEngine(
        tiers=[_flat_tier(0.1, name="t", servers=1, batch_size=2,
                          batched_executor=bx)],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    reqs = [np.full((4,), 5, np.int32)] * 4
    res = eng.submit_batch(reqs, now_s=0.0)
    assert len(calls) == 2 and all(s[0] == 2 for s in calls)
    assert [r.m_out for r in res] == [3, 3, 3, 3]
    waits = sorted(r.wait_s for r in res)
    assert waits[0] == waits[1] == 0.0
    assert waits[2] == waits[3] > 0.0
    # the queued block's wait equals the first block's booked service
    first_service = min(r.latency_s for r in res)
    assert waits[2] == pytest.approx(first_service)


def test_submit_batch_without_batched_executor_falls_back_per_request():
    ran = []

    def solo(tokens):
        ran.append(len(tokens))
        return 2, np.array([9, EOS_ID])

    eng = CollaborativeEngine(
        tiers=[_flat_tier(0.1, name="t", servers=2, executor=solo)],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    res = eng.submit_batch([np.full((3,), 5, np.int32),
                            np.full((6,), 5, np.int32)], now_s=0.0)
    assert ran == [3, 6]
    assert [r.m_out for r in res] == [2, 2]
    assert [r.n for r in res] == [3, 6]


def test_submit_batch_sheds_on_infeasible_deadline():
    """With the single server already booked (full, capacity 0) and a
    predicted execution far past the deadline, the whole slot is shed —
    and the batched executor is never invoked for it."""
    calls = []

    def bx(block, lens):
        calls.append(len(lens))
        time.sleep(0.002)                 # make the booked window real
        return [(1, np.array([5]))] * len(lens)

    eng = CollaborativeEngine(
        tiers=[_flat_tier(10.0, name="t", servers=1, queue_capacity=0,
                          batch_size=2, batched_executor=bx)],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    eng.submit_batch([np.full((4,), 5, np.int32)], now_s=0.0)
    res = eng.submit_batch([np.full((4,), 5, np.int32)] * 2,
                           now_s=1e-4, deadline_s=0.5)
    assert calls == [1]                   # only the occupying request ran
    assert all(r.shed for r in res)
    assert eng.stats()["shed"] == 2


def test_submit_batch_respects_bounded_queue_capacity():
    """A concurrent slot must not oversubscribe a bounded queue: with one
    batch-slot free and queue_capacity=1, an 8-request slot on a lone
    tier keeps admitting (forced, counted as rejections) but the pending
    count is charged — mirroring what sequential submits enforce."""
    fast = _flat_tier(0.01, name="fast", servers=1, batch_size=2,
                      queue_capacity=1,
                      batched_executor=lambda b, l:
                      [(1, np.array([5]))] * len(l))
    slow = _flat_tier(5.0, name="slow", servers=1,
                      batched_executor=lambda b, l:
                      [(1, np.array([5]))] * len(l))
    eng = CollaborativeEngine(tiers=[fast, slow],
                              n2m=LinearN2M(1.0, 0.0), seed=0)
    res = eng.submit_batch([np.full((4,), 5, np.int32)] * 8, now_s=0.0)
    by_tier = {0: 0, 1: 0}
    for r in res:
        by_tier[r.device] += 1
    # 2 batch slots + 1 queue slot on the fast tier; the rest re-route
    assert by_tier[0] == 3
    assert by_tier[1] == 5


def test_submit_batch_partially_free_servers_not_overadmitted():
    """servers=2 with ONE busy and queue_capacity=0: a 2-request slot has
    exactly one free slot — the second member must re-route exactly as a
    sequential second submit would, not squat on the busy server."""
    def mk():
        fast = _flat_tier(0.1, name="fast", servers=2, queue_capacity=0,
                          batched_executor=lambda b, l:
                          [(1, np.array([5]))] * len(l))
        slow = _flat_tier(5.0, name="slow", servers=4,
                          batched_executor=lambda b, l:
                          [(1, np.array([5]))] * len(l))
        return CollaborativeEngine(tiers=[fast, slow],
                                   n2m=LinearN2M(1.0, 0.0), seed=0)

    seq = mk()
    seq.submit(np.full((4,), 5, np.int32), now_s=0.0)   # occupies server 1
    seq_routes = [seq.submit(np.full((4,), 5, np.int32), now_s=0.05).device
                  for _ in range(2)]

    par = mk()
    par.submit(np.full((4,), 5, np.int32), now_s=0.0)
    par_routes = [r.device for r in par.submit_batch(
        [np.full((4,), 5, np.int32)] * 2, now_s=0.05)]
    assert sorted(par_routes) == sorted(seq_routes) == [0, 1]


def test_submit_batch_preserves_request_order_and_ids():
    eng = CollaborativeEngine(
        tiers=[_flat_tier(0.01, name="t", servers=1, batch_size=4,
                          batched_executor=lambda b, l:
                          [(int(x), np.arange(int(x))) for x in l])],
        n2m=LinearN2M(1.0, 0.0), seed=0)
    lens = [6, 2, 9, 4]
    reqs = [np.full((L,), 5, np.int32) for L in lens]
    res = eng.submit_batch(reqs, now_s=0.0)
    # results in request order; m_out echoes each request's own length
    # (ids are assigned in drain order — length-sorted — but each result
    # lands at its request's position)
    assert [r.n for r in res] == lens
    assert [r.m_out for r in res] == lens
    assert sorted(r.req_id for r in res) == list(range(4))
