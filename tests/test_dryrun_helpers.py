"""Unit tests for the dry-run analysis machinery (no 512-device init:
these only exercise the pure-text HLO parsing and the policy rules)."""

import numpy as np
import pytest

# NOTE: importing repro.launch.dryrun would set XLA_FLAGS for THIS
# process; these tests import the parsing helpers via a small shim that
# strips the env side effect first.
import os

_saved = os.environ.get("XLA_FLAGS")
from repro.launch import dryrun as dr  # noqa: E402
if _saved is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _saved


HLO = """
HloModule test

%body_1 (p: (s32[], bf16[8,16])) -> (s32[], bf16[8,16]) {
  %ag = bf16[8,16]{1,0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], bf16[8,16]) tuple(%i, %ag)
}

%cond_1 (p: (s32[], bf16[8,16])) -> pred[] {
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: bf16[8,16]) -> bf16[8,16] {
  %ar = f32[4,4]{1,0} all-reduce(%a), to_apply=%sum
  %w = (s32[], bf16[8,16]) while(%init), condition=%cond_1, body=%body_1
  ROOT %out = bf16[8,16] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert dr._shape_bytes("bf16[8,16]") == 8 * 16 * 2
    assert dr._shape_bytes("f32[4,4]") == 64
    assert dr._shape_bytes("(bf16[2,2], f32[2])") == 8 + 8
    assert dr._shape_bytes("u32[]") == 4


def test_split_computations():
    comps = dr._split_computations(HLO)
    assert "body_1" in comps and "cond_1" in comps and "main" in comps
    assert any("all-gather" in l for l in comps["body_1"])


def test_trip_count_from_condition():
    comps = dr._split_computations(HLO)
    assert dr._trip_count(comps["cond_1"]) == 12


def test_collective_stats_scales_while_bodies():
    stats = dr.collective_stats(HLO)
    # all-gather inside the 12-trip while body: 8*16*2 bytes * 12
    assert stats["all-gather"]["bytes"] == 8 * 16 * 2 * 12
    assert stats["all-gather"]["count"] == 12
    # all-reduce in ENTRY counted once
    assert stats["all-reduce"]["bytes"] == 64
    assert stats["total_bytes"] == 8 * 16 * 2 * 12 + 64


def test_roofline_terms_dominance():
    rec = {
        "chips": 256,
        "analytic": {"flops": 256 * 197e12, "hbm_bytes": 256 * 819e9 * 2},
        "collectives": {"total_bytes": 50e9},
        "cost": {"flops": 1.0},
        "model_flops": 256 * 197e12 * 0.5,
    }
    rl = dr.roofline_terms(rec)
    assert rl["compute_s"] == pytest.approx(1.0)
    assert rl["memory_s"] == pytest.approx(2.0)
    assert rl["collective_s"] == pytest.approx(1.0)
    assert rl["dominant"] == "memory"
    assert rl["useful_flops_ratio"] == pytest.approx(0.5)
