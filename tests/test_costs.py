"""Validate the analytic cost model against XLA cost_analysis.

XLA counts scan bodies once, so the comparison uses configs whose layer
groups have count=1 (nothing to undercount except the internal chunk
scans, which these shapes keep to one chunk).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.config import LayerGroup, ModelConfig
from repro.models.costs import forward_flops, kv_bytes_per_token, step_cost
from repro.models.model import LM


def _one_layer(cfg):
    plan = tuple(dataclasses.replace(g, count=1) for g in cfg.layer_plan[:1])
    return dataclasses.replace(cfg, layer_plan=plan)


def _xla_flops(fn, *args):
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


@pytest.mark.parametrize("arch", ["qwen3-8b", "qwen3-moe-30b-a3b",
                                  "deepseek-v3-671b"])
def test_forward_flops_matches_xla_on_unrolled(arch):
    cfg = _one_layer(smoke_config(arch))
    cfg = dataclasses.replace(cfg, mtp_depth=0)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 64
    toks = jnp.zeros((b, s), jnp.int32)

    def fwd(p, t):
        return model.train_logits(p, t)["logits"]

    xla = _xla_flops(fwd, params, toks)
    ours = forward_flops(cfg, tokens=b * s, context=s, decode=False, batch=b)
    # within 2x both ways (XLA counts softmax/mask flops we skip; we count
    # causal halving it doesn't) — the roofline needs magnitude, not ulps
    assert 0.5 < ours / xla < 2.0, f"{arch}: ours={ours:.3g} xla={xla:.3g}"


def test_train_step_flops_about_4x_forward():
    cfg = _one_layer(smoke_config("qwen3-8b"))
    sc_t = step_cost(cfg, kind="train", batch=2, seq=64)
    fwd = forward_flops(cfg, tokens=128, context=64, decode=False, batch=2)
    assert 3.5 * fwd < sc_t.flops < 4.5 * fwd + 30 * cfg.param_counts()["total"]


def test_decode_cost_scales_with_context():
    cfg = smoke_config("qwen3-8b")
    c1 = step_cost(cfg, kind="decode", batch=8, seq=1024)
    c2 = step_cost(cfg, kind="decode", batch=8, seq=4096)
    assert c2.hbm_bytes > c1.hbm_bytes          # KV cache read grows
    assert c2.flops > c1.flops                  # attention grows
    # params dominate small-model decode bytes; cache read adds on top
    assert c2.hbm_bytes - c1.hbm_bytes == pytest.approx(
        8 * (4096 - 1024) * kv_bytes_per_token(cfg), rel=0.01)


def test_sliding_window_caps_decode_cost():
    from repro.configs import get_config
    full = get_config("qwen3-8b")
    swa = get_config("qwen3-8b", shape="long_500k")
    c_full_hypothetical = step_cost(full, kind="decode", batch=1, seq=524288)
    c_swa = step_cost(swa, kind="decode", batch=1, seq=524288)
    assert c_swa.hbm_bytes < 0.2 * c_full_hypothetical.hbm_bytes


def test_mla_kv_bytes_much_smaller_than_gqa():
    from repro.configs import get_config
    ds = get_config("deepseek-v3-671b")
    q32 = get_config("qwen3-32b")
    # per layer per token: MLA latent (512+64)*2 vs GQA 2*8*128*2
    mla_per_layer = kv_bytes_per_token(ds) / ds.num_layers
    gqa_per_layer = kv_bytes_per_token(q32) / q32.num_layers
    assert mla_per_layer < 0.4 * gqa_per_layer


def test_rwkv_has_no_kv_growth():
    from repro.configs import get_config
    assert kv_bytes_per_token(get_config("rwkv6-3b")) == 0.0
