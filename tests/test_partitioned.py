"""PR 7: PlacementPlan partitioned placement — scheduler, links, NMT
split parity, engine, and two-leg DES.

The load-bearing pins:

* with splits disabled, the plan scheduler is BIT-FOR-BIT the scalar
  scheduler (``decide_plan`` ≡ ``decide``, fast variants too), and the
  two-leg DES is bit-for-bit the single-leg DES;
* a degenerate split ``split(k, k)`` prices exactly like ``whole(k)``;
* ``encode() -> EncoderStates -> decode_from_states()`` reproduces the
  fused translate exactly on all three paper models;
* ε-greedy exploration recovers a mis-calibrated tier the argmin alone
  would never probe again.
"""

import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.latency_model import (ActivationCostModel, DeviceProfile,
                                      LinearLatencyModel)
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import ConnectionProfile
from repro.core.scheduler import (MultiTierScheduler, PlacementPlan,
                                  SchedTier)
from repro.core.simulator import RequestStream, SimTier, simulate_des
from repro.core.tx_estimator import LinkModel, TxEstimator
from repro.runtime.engine import CollaborativeEngine, Tier

_DEV = (3e-4, 5e-3, 2e-3)
_EDGE = (2e-5, 2.5e-3, 4e-3)
_CLOUD = (1e-5, 1e-4, 2e-3)


def _links(backbone_bps=1e9):
    links = LinkModel(3)
    links.add_link(1, 2, TxEstimator(init_rtt_s=4e-3,
                                     bandwidth_bps=backbone_bps))
    return links


def _sched(*, allow_split=False, links=None, activation=None, **kw):
    tiers = [
        SchedTier("dev", LinearLatencyModel(*_DEV), None),
        SchedTier("edge", LinearLatencyModel(*_EDGE),
                  TxEstimator(init_rtt_s=5e-3, bandwidth_bps=200e6)),
        SchedTier("cloud", LinearLatencyModel(*_CLOUD),
                  TxEstimator(init_rtt_s=90e-3, bandwidth_bps=20e6)),
    ]
    n2m = LinearN2M().fit(np.arange(1.0, 300.0), np.arange(1.0, 300.0))
    return MultiTierScheduler(tiers, n2m, links=links,
                              activation=activation,
                              allow_split=allow_split, **kw)


def _split_sched(**kw):
    return _sched(allow_split=True, links=_links(),
                  activation=ActivationCostModel(512, 4), **kw)


# ------------------------------------------------------- PlacementPlan --
def test_placement_plan_identities():
    assert PlacementPlan.whole(2) == PlacementPlan.split(2, 2)
    assert not PlacementPlan.whole(1).is_split
    assert PlacementPlan.split(1, 2).is_split
    assert PlacementPlan.split(1, 2) != PlacementPlan.split(2, 1)


def test_degenerate_split_prices_as_whole():
    s = _split_sched()
    for n in (4.0, 64.0, 200.0):
        d = s.decide_fast(n, n, 0.0)
        for k in range(3):
            assert s.plan_cost_fast(PlacementPlan.split(k, k), n, n, 0.0) \
                == d.t_pred[k]


# ------------------------------------------------- one-way tx + links --
def test_tx_time_one_way_halves_rtt_only():
    tx = TxEstimator(init_rtt_s=0.080, bandwidth_bps=1e8)
    ser = 1e6 * 8.0 / 1e8
    assert tx.tx_time(0.0, 1e6) == pytest.approx(0.080 + ser)
    assert tx.tx_time(0.0, 1e6, one_way=True) == pytest.approx(0.040 + ser)


def test_link_model_direct_self_and_unreachable():
    links = LinkModel(3)
    links.add_link(0, 1, TxEstimator(init_rtt_s=0.010, bandwidth_bps=1e8))
    assert links.tx_time(0, 0, 0.0, 1e6) == 0.0
    assert links.tx_time(0, 1, 0.0, 0.0) == pytest.approx(0.010)
    assert links.tx_time(1, 0, 0.0, 0.0) == pytest.approx(0.010)  # symmetric
    assert not links.has_path(0, 2)
    assert links.tx_time(0, 2, 0.0, 1.0) == np.inf


def test_link_model_composes_multi_hop():
    links = LinkModel(3)
    links.add_link(0, 1, TxEstimator(init_rtt_s=0.010, bandwidth_bps=1e8))
    links.add_link(1, 2, TxEstimator(init_rtt_s=0.020, bandwidth_bps=2e8))
    # 0 -> 2 has no direct link: composes both hops, each paying its own
    # RTT and re-serialization
    expect = (0.010 + 1e6 * 8 / 1e8) + (0.020 + 1e6 * 8 / 2e8)
    assert links.tx_time(0, 2, 0.0, 1e6) == pytest.approx(expect)
    assert links.has_path(0, 2)


def test_link_model_observe_feeds_direct_estimator():
    links = LinkModel(2)
    links.add_link(0, 1, TxEstimator(init_rtt_s=0.050, bandwidth_bps=1e8,
                                     mode="last"))
    links.observe(0, 1, 1.0, 0.004)
    assert links.link(0, 1).rtt(2.0) == pytest.approx(0.004)
    # the symmetric reverse estimator is an independent copy
    assert links.link(1, 0).rtt(2.0) == pytest.approx(0.050)


def test_link_model_rejects_bad_pairs():
    links = LinkModel(2)
    with pytest.raises(ValueError):
        links.add_link(0, 0, TxEstimator())
    with pytest.raises(ValueError):
        links.add_link(0, 5, TxEstimator())


# --------------------------------------- splits-disabled equivalence --
@pytest.mark.property
@settings(max_examples=40, deadline=None)
@given(n=st.integers(min_value=1, max_value=300),
       now=st.floats(min_value=0.0, max_value=100.0),
       q0=st.floats(min_value=0.0, max_value=0.5),
       q2=st.floats(min_value=0.0, max_value=0.5))
def test_plan_scheduler_disabled_equals_scalar(n, now, q0, q2):
    """decide_plan(_fast) with splits disabled ≡ decide(_fast), exactly."""
    qd = [q0, 0.0, q2]
    a, b = _sched(), _sched()
    d0 = a.decide(float(n), now, qd)
    d1 = b.decide_plan(float(n), now, qd)
    assert d1.tier == d0.tier
    assert d1.t_pred == d0.t_pred            # bit-for-bit
    assert d1.m_hat == d0.m_hat
    assert d1.plan == PlacementPlan.whole(d0.tier)
    f0 = a.decide_fast(float(n), float(n), now, qd)
    f1 = b.decide_plan_fast(float(n), float(n), now, qd)
    assert f1.tier == f0.tier
    assert f1.t_pred == f0.t_pred


def test_split_requires_links_and_activation():
    # links without activation (and vice versa) never split
    s = _sched(allow_split=True, links=_links())
    assert not s._split_ready()
    s = _sched(allow_split=True,
               activation=ActivationCostModel(512, 4))
    assert not s._split_ready()
    assert _split_sched()._split_ready()


def test_split_plan_chosen_in_the_classic_regime():
    """Cheap edge encoder + fast cloud decoder behind a slow client WAN
    with a fat backbone: encode-at-edge/decode-in-cloud must win."""
    d = _split_sched().decide_plan_fast(128.0, 128.0, 0.0)
    assert d.plan == PlacementPlan.split(1, 2)
    assert d.tier == 2                       # reported tier = decode leg
    # and the split's predicted cost is strictly below every whole plan
    s = _split_sched()
    split_cost = s.plan_cost_fast(PlacementPlan.split(1, 2), 128.0, 128.0,
                                  0.0)
    assert all(split_cost < t for t in d.t_pred)


def test_activation_payload_prices_the_split():
    """A fatter activation payload must make the same split cost more."""
    thin = _sched(allow_split=True, links=_links(1e7),
                  activation=ActivationCostModel(64, 2))
    fat = _sched(allow_split=True, links=_links(1e7),
                 activation=ActivationCostModel(2048, 4))
    p = PlacementPlan.split(1, 2)
    assert fat.plan_cost_fast(p, 128.0, 128.0, 0.0) \
        > thin.plan_cost_fast(p, 128.0, 128.0, 0.0)


# ------------------------------------------------------------ ε-greedy --
def test_explore_eps_zero_is_inert():
    """eps=0 must not touch the RNG or the staleness counters."""
    s = _sched()
    state_before = s._explore_rng.bit_generator.state
    for n in (8.0, 64.0, 190.0):
        s.decide_fast(n, n, 0.0)
    assert s._explore_rng.bit_generator.state == state_before
    assert s._since_pick == [0, 0, 0]
    assert s.n_explored == 0


def test_explore_eps_probes_stale_tiers():
    s = _sched(explore_eps=0.3, explore_seed=1)
    picks = [s.decide_fast(64.0, 64.0, 0.0).tier for _ in range(100)]
    assert s.n_explored > 0
    assert len(set(picks)) > 1               # stale tiers were probed


# ------------------------------------------------------- NMT parity ----
def _models():
    from repro.nmt import (BiLSTMSeq2Seq, GRUSeq2Seq, MarianTransformer,
                           RNNConfig, TransformerConfig)
    rnn = RNNConfig(vocab_src=64, vocab_tgt=64, embed=32, hidden=32,
                    layers=2, max_decode_len=24)
    tf = TransformerConfig(vocab_src=64, vocab_tgt=64, d_model=32, heads=4,
                           d_ff=64, enc_layers=2, dec_layers=2,
                           max_decode_len=24)
    return [GRUSeq2Seq(rnn), BiLSTMSeq2Seq(rnn), MarianTransformer(tf)]


@pytest.mark.slow
@pytest.mark.parametrize("model", _models(),
                         ids=lambda m: type(m).__name__)
def test_split_decode_matches_fused_exactly(model):
    import jax

    params = model.init(jax.random.PRNGKey(0))
    fused = model.make_translate_batched(params)
    encode = model.make_encode_states(params)
    decode = model.make_decode_from_states(params)

    rng = np.random.default_rng(3)
    lens = [10, 7, 4]
    n_max = max(lens)
    src = np.zeros((len(lens), n_max), np.int32)
    mask = np.zeros((len(lens), n_max), np.float32)
    for b, ln in enumerate(lens):
        src[b, :ln] = rng.integers(3, 64, ln)
        mask[b, :ln] = 1.0

    for forced in (None, 6):
        lens_f, toks_f = fused(src, mask, forced_len=forced) \
            if forced is not None else fused(src, mask)
        states = encode(src, mask)
        assert states.payload_bytes() > 0
        assert states.batch == len(lens)
        lens_s, toks_s = decode(states, forced_len=forced) \
            if forced is not None else decode(states)
        assert np.array_equal(np.asarray(lens_f), np.asarray(lens_s))
        assert np.array_equal(np.asarray(toks_f), np.asarray(toks_s))


def test_encoder_states_is_a_pytree():
    import jax
    import jax.numpy as jnp

    from repro.nmt.common import EncoderStates

    st_ = EncoderStates(data=(jnp.ones((2, 3, 4)),),
                        src_lens=jnp.array([3, 2]))
    leaves = jax.tree_util.tree_leaves(st_)
    assert len(leaves) == 2
    out = jax.jit(lambda s: s)(st_)          # passes through jit intact
    assert isinstance(out, EncoderStates)
    assert out.payload_bytes() == 2 * 3 * 4 * 4 + 2 * st_.src_lens.dtype.itemsize


# ------------------------------------------------------------- engine --
def _engine_tiers():
    return [
        Tier(DeviceProfile("dev", LinearLatencyModel(*_DEV), 0.05),
             name="dev"),
        Tier(DeviceProfile("edge", LinearLatencyModel(*_EDGE), 0.05),
             name="edge", rtt_fn=lambda t: 5e-3, bandwidth_bps=200e6),
        Tier(DeviceProfile("cloud", LinearLatencyModel(*_CLOUD), 0.05),
             name="cloud", rtt_fn=lambda t: 90e-3, bandwidth_bps=20e6),
    ]


def _run_engine(**kw):
    eng = CollaborativeEngine(n2m=LinearN2M(1.0, 0.0),
                              tiers=_engine_tiers(), seed=0, **kw)
    rng = np.random.default_rng(11)
    for i in range(60):
        eng.submit(np.ones(int(rng.integers(8, 200)), np.int32),
                   now_s=float(i) * 0.2)
    return eng


def test_engine_split_disabled_is_bitwise_vanilla():
    base = _run_engine()
    capable = _run_engine(links=_links(),
                          activation=ActivationCostModel(512, 4),
                          inter_rtt_fns={(1, 2): lambda t: 4e-3},
                          allow_split=False)
    for a, b in zip(base.results, capable.results):
        assert a.device == b.device
        assert a.latency_s == b.latency_s    # bit-for-bit
        assert a.m_out == b.m_out
    assert capable.split_count == 0


def test_engine_executes_split_plans():
    eng = _run_engine(links=_links(),
                      activation=ActivationCostModel(512, 4),
                      inter_rtt_fns={(1, 2): lambda t: 4e-3},
                      allow_split=True)
    split = [r for r in eng.results if r.plan is not None and r.plan.is_split]
    assert eng.stats()["split"] == eng.split_count == len(split) > 0
    for r in split:
        assert r.plan == PlacementPlan.split(1, 2)
        assert r.device == 2                 # device = decode tier
        assert r.latency_s > 0


def test_engine_explore_recovers_miscalibrated_tier():
    """A tier believed awful (but actually fast) is dead to the argmin;
    ε-greedy probes feed the calibrator real samples and win it back."""
    slow = DeviceProfile("slow", LinearLatencyModel(1e-4, 5e-3, 1e-3), 0.02)
    fast = DeviceProfile("fast", LinearLatencyModel(1e-5, 1e-4, 1e-3), 0.02)
    believed_awful = DeviceProfile("fast", LinearLatencyModel(1.0, 1.0, 1.0),
                                   0.02)

    def run(eps):
        eng = CollaborativeEngine(
            n2m=LinearN2M(1.0, 0.0),
            tiers=[Tier(dataclasses.replace(slow, model=slow.model)),
                   Tier(dataclasses.replace(believed_awful,
                                            model=believed_awful.model))],
            seed=0, refit_interval=32, explore_eps=eps)
        eng.tiers[1].profile = fast          # ground truth executes fast
        rng = np.random.default_rng(5)
        for i in range(300):
            eng.submit(np.zeros(int(rng.integers(8, 120)), np.int32),
                       now_s=float(i))
        late = [r.device for r in eng.results[-100:]]
        return eng, np.mean(np.asarray(late) == 1)

    eng_greedy, frac_greedy = run(0.0)
    eng_explore, frac_explore = run(0.25)
    # pure argmin never probes the believed-awful tier, so it never learns
    assert frac_greedy == 0.0
    # exploration feeds the refit real samples; the tier wins the traffic
    assert eng_explore.scheduler.n_explored > 0
    assert frac_explore > 0.5
    assert eng_explore.scheduler.tiers[1].model.alpha_m < 1e-2


# ------------------------------------------------------------- DES -----
def _const_profile(rtt_s, bw):
    return ConnectionProfile(name="c", times_s=np.array([0.0, 3600.0]),
                             rtt_s=np.array([rtt_s, rtt_s]),
                             bandwidth_bps=bw)


def _stream(n_req=150, seed=0):
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(0.05, n_req))
    ns = rng.integers(8, 200, n_req).astype(np.float64)
    return RequestStream(t_arrival_s=arr, n=ns, m_out=ns.copy(),
                         m_real=ns.copy())


def _sim_tiers():
    return [
        SimTier("dev", DeviceProfile("dev", LinearLatencyModel(*_DEV),
                                     0.05)),
        SimTier("edge", DeviceProfile("edge", LinearLatencyModel(*_EDGE),
                                      0.05),
                link=_const_profile(5e-3, 200e6)),
        SimTier("cloud", DeviceProfile("cloud", LinearLatencyModel(*_CLOUD),
                                       0.05),
                link=_const_profile(90e-3, 20e6)),
    ]


def test_des_split_disabled_is_bitwise_identical():
    """The two-leg DES with splits unavailable — by scheduler config or
    by missing inter_links — is the single-leg DES, bit for bit."""
    stream = _stream()
    base = simulate_des(_sched(), stream, _sim_tiers(), seed=7)
    no_inter = simulate_des(_split_sched(), stream, _sim_tiers(), seed=7)
    off = simulate_des(_sched(allow_split=False, links=_links(),
                              activation=ActivationCostModel(512, 4)),
                       stream, _sim_tiers(), seed=7,
                       inter_links={(1, 2): _const_profile(4e-3, 1e9)})
    for r in (no_inter, off):
        assert np.array_equal(base.tier, r.tier)
        assert np.array_equal(base.latency_s, r.latency_s, equal_nan=True)
        assert np.array_equal(base.wait_s, r.wait_s)
        assert np.array_equal(base.exec_s, r.exec_s)
        assert np.array_equal(base.tx_s, r.tx_s)
        assert np.array_equal(base.t_finish_s, r.t_finish_s)


def test_des_two_leg_service():
    """Split-enabled DES: splits actually happen, each pays both legs,
    and latency = wait + exec + tx holds for every served request."""
    stream = _stream()
    res = simulate_des(_split_sched(), stream, _sim_tiers(), seed=7,
                       inter_links={(1, 2): _const_profile(4e-3, 1e9)},
                       collect_events=True)
    xfers = [e for e in res.events if e[1] == "xfer"]
    assert len(xfers) > 0
    ok = res.served & (res.tier >= 0)
    resid = res.latency_s[ok] - (res.wait_s[ok] + res.exec_s[ok]
                                 + res.tx_s[ok])
    assert np.max(np.abs(resid)) < 1e-9
    assert np.all(res.wait_s[ok] >= -1e-12)
    assert np.all(res.latency_s[ok] > 0)
    # split requests report the decode tier and their exec covers both
    # legs (strictly above the decode leg's floor of 1e-6)
    split_ids = {e[2] for e in xfers}
    for i in split_ids:
        assert res.tier[i] == 2
        assert res.exec_s[i] > 0


def test_des_split_beats_whole_in_the_classic_regime():
    rng = np.random.default_rng(1)
    n_req = 200
    arr = np.cumsum(rng.exponential(0.2, n_req))
    ns = rng.integers(64, 192, n_req).astype(np.float64)
    stream = RequestStream(t_arrival_s=arr, n=ns, m_out=ns.copy(),
                           m_real=ns.copy())
    base = simulate_des(_sched(), stream, _sim_tiers(), seed=3)
    part = simulate_des(_split_sched(), stream, _sim_tiers(), seed=3,
                        inter_links={(1, 2): _const_profile(4e-3, 1e9)})
    assert np.nanmean(part.latency_s) < np.nanmean(base.latency_s)
