"""Training substrate + serving runtime tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.scheduler import CLOUD, EDGE
from repro.models.model import LM
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import GenerationSession
from repro.training.checkpoint import (
    checkpoint_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.training.train_loop import init_train_state, make_train_step


# --------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=0.1, cfg=cfg)
    assert float(loss(params)) < 1e-3
    assert int(opt.step) == 200


def test_weight_decay_only_on_matrices():
    params = {"w": jnp.ones((2, 2)), "g": jnp.ones((2,))}
    opt = adamw_init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5)
    p2, _ = adamw_update(params, zero_g, opt, lr=0.1, cfg=cfg)
    assert float(jnp.abs(p2["w"] - 1.0).max()) > 1e-3   # decayed
    assert float(jnp.abs(p2["g"] - 1.0).max()) < 1e-6   # exempt


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    norm2 = float(jnp.linalg.norm(clipped["a"]))
    assert norm2 == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    sched = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.array(0))) == 0.0
    assert float(sched(jnp.array(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(sched(jnp.array(100))) == pytest.approx(1e-4, rel=1e-3)
    assert float(sched(jnp.array(55))) < 1e-3


# -------------------------------------------------------------- train loop
@pytest.mark.parametrize("arch", ["qwen3-8b", "qwen3-moe-30b-a3b",
                                  "rwkv6-3b", "deepseek-v3-671b"])
def test_train_step_reduces_loss(arch):
    cfg = smoke_config(arch)
    model = LM(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (2, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "targets": jnp.asarray(np.roll(toks, -1, 1))}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cfg = smoke_config("zamba2-1.2b")
    model = LM(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, state, step=7)
    like = init_train_state(model, jax.random.PRNGKey(1))  # different values
    restored = load_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint_step(path) == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.ones((2, 2))})
    with pytest.raises((ValueError, KeyError)):
        load_checkpoint(path, {"w": jnp.ones((3, 3))})


# ----------------------------------------------------------------- serving
def test_generation_session_runs():
    cfg = smoke_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sess = GenerationSession(model, params, max_len=32)
    toks = np.random.default_rng(0).integers(4, cfg.vocab_size, (2, 8))
    out = sess.generate(toks.astype(np.int32), max_new=6)
    assert out.shape[0] == 2 and 1 <= out.shape[1] <= 6
    assert out.dtype in (np.int32, np.int64)


# ------------------------------------------------------------------ engine
def _engine(rtt=0.05, speedup=5.0):
    edge = Tier(DeviceProfile("edge", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.0))
    cloud = Tier(DeviceProfile(
        "cloud", LinearLatencyModel(2e-3 / speedup, 8e-3 / speedup,
                                    0.01 / speedup), 0.0),
        rtt_fn=lambda t: rtt)
    return CollaborativeEngine(tiers=[edge, cloud],
                               n2m=LinearN2M(1.0, 0.0), seed=0)


def test_engine_routes_short_edge_long_cloud():
    eng = _engine()
    rng = np.random.default_rng(0)
    short = eng.submit(rng.integers(4, 100, (3,)), now_s=0.0)
    long = eng.submit(rng.integers(4, 100, (250,)), now_s=1.0)
    assert short.device == EDGE
    assert long.device == CLOUD
    # offloaded request refreshed the tx estimate
    assert eng.tx.n_samples >= 1
    s = eng.stats()
    assert s["requests"] == 2
    assert 0.0 < s["offload_frac"] < 1.0


def test_engine_with_real_edge_executor():
    """Mixed setup: real executor at the edge, modelled cloud."""
    calls = []

    def fake_translate(tokens):
        calls.append(len(tokens))
        return max(1, len(tokens) - 1), np.arange(max(1, len(tokens) - 1))

    edge = Tier(DeviceProfile("edge", LinearLatencyModel(1e-4, 1e-4, 1e-4), 0.0),
                executor=fake_translate)
    cloud = Tier(DeviceProfile("cloud", LinearLatencyModel(1e-5, 1e-5, 1e-5), 0.0),
                 rtt_fn=lambda t: 10.0)      # huge RTT
    eng = CollaborativeEngine(tiers=[edge, cloud], n2m=LinearN2M(1.0, 0.0),
                              seed=0)
    r = eng.submit(np.arange(5), now_s=0.0)
    assert r.device == EDGE          # RTT makes cloud hopeless
    assert calls == [5]
    assert r.m_out == 4
