"""Integration/property tests for the §III request-stream simulator."""

import numpy as np
import pytest

from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.profiles import make_profile
from repro.core.scheduler import (
    CLOUD,
    EDGE,
    CNMTScheduler,
    NaiveScheduler,
    OracleScheduler,
    StaticScheduler,
)
from repro.core.simulator import make_stream, simulate, table1_row
from repro.data.synthetic import make_corpus


def _setup(pair="de-en", k=4000, seed=0, speedup=5.0, noise=0.03):
    corpus = make_corpus(pair, k + 2000, seed=seed)
    fit, eval_ = corpus.split(2000)
    edge = DeviceProfile("e", LinearLatencyModel(1.5e-3, 6e-3, 0.008), noise)
    cloud = DeviceProfile("c", LinearLatencyModel(1.5e-3 / speedup, 6e-3 / speedup, 0.008 / speedup), noise)
    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    n2m = LinearN2M().fit(nf, mf)
    profile = make_profile("cp2", seed=seed)
    stream = make_stream(eval_.n, eval_.m_out, eval_.m_real,
                         duration_s=profile.times_s[-1], seed=seed)
    return stream, profile, edge, cloud, n2m, fit


def test_every_request_served_once_per_policy():
    stream, profile, edge, cloud, n2m, fit = _setup()
    for pol in (StaticScheduler(EDGE), StaticScheduler(CLOUD), OracleScheduler(),
                CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)):
        r = simulate(pol, stream, profile, edge, cloud, seed=0)
        assert r.device.shape == (len(stream),)
        assert np.all((r.device == EDGE) | (r.device == CLOUD))
        assert np.all(r.latency_s > 0)
        assert r.total_s == pytest.approx(r.latency_s.sum())


def test_oracle_lower_bounds_every_policy():
    """The oracle picks the per-request min -> no policy can beat it."""
    stream, profile, edge, cloud, n2m, fit = _setup()
    oracle = simulate(OracleScheduler(), stream, profile, edge, cloud, seed=0)
    for pol in (StaticScheduler(EDGE), StaticScheduler(CLOUD),
                CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m),
                NaiveScheduler(edge, cloud, fit.n, fit.m_real)):
        r = simulate(pol, stream, profile, edge, cloud, seed=0)
        assert r.total_s >= oracle.total_s - 1e-9


def test_oracle_equals_min_of_static_per_request():
    stream, profile, edge, cloud, *_ = _setup(k=500)
    gw = simulate(StaticScheduler(EDGE), stream, profile, edge, cloud, seed=0)
    sv = simulate(StaticScheduler(CLOUD), stream, profile, edge, cloud, seed=0)
    orc = simulate(OracleScheduler(), stream, profile, edge, cloud, seed=0)
    assert np.allclose(orc.latency_s, np.minimum(gw.latency_s, sv.latency_s))


def test_cnmt_beats_both_statics_and_naive_structurally():
    """The paper's headline: C-NMT < min(GW, Server) and <= Naive.

    Uses a low-noise setup where the planes are well-separated, so the
    result is forced by the mechanism rather than luck.
    """
    stream, profile, edge, cloud, n2m, fit = _setup(k=6000, noise=0.02)
    cnmt = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
    naive = NaiveScheduler(edge, cloud, fit.n, fit.m_real)
    row = table1_row(dataset="de-en", stream=stream, profile=profile,
                     edge=edge, cloud=cloud, cnmt=cnmt, naive=naive, seed=0)
    assert row["c-nmt"]["vs_gw"] < 0
    assert row["c-nmt"]["vs_server"] < 0
    assert row["c-nmt"]["vs_oracle"] >= -1e-6
    assert row["c-nmt"]["vs_oracle"] < 15.0          # paper: 0.11 .. 9.83
    assert row["c-nmt"]["total_s"] <= row["naive"]["total_s"] * 1.02


def test_cnmt_adapts_to_rtt_regime():
    """With CP1 (slow net) C-NMT offloads less than with CP2 (fast net)."""
    stream, _, edge, cloud, n2m, fit = _setup(k=3000)
    cnmt = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
    r1 = simulate(cnmt, stream, make_profile("cp1", seed=0), edge, cloud, seed=0)
    r2 = simulate(cnmt, stream, make_profile("cp2", seed=0), edge, cloud, seed=0)
    assert r1.offload_frac < r2.offload_frac


def test_all_edge_when_cloud_hopeless():
    stream, profile, edge, _, n2m, fit = _setup(k=300)
    # cloud slower than edge AND behind a network -> never offload
    slow_cloud = DeviceProfile("c", edge.model.scaled(0.5), 0.0)
    cnmt = CNMTScheduler(edge=edge, cloud=slow_cloud, n2m=n2m)
    r = simulate(cnmt, stream, profile, edge, slow_cloud, seed=0)
    assert r.offload_frac == 0.0


def test_simulation_deterministic_given_seed():
    stream, profile, edge, cloud, n2m, _ = _setup(k=500)
    cnmt = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
    a = simulate(cnmt, stream, profile, edge, cloud, seed=7)
    b = simulate(cnmt, stream, profile, edge, cloud, seed=7)
    assert a.total_s == b.total_s
    assert np.array_equal(a.device, b.device)


def test_table1_decisions_respond_to_link_bandwidth():
    """Regression for the hardcoded-100 Mbps link: the same trace at a
    much lower configured bandwidth must change C-NMT's decisions (the
    payload serialization term now flows from the profile into both the
    default TxEstimator and the true T_tx)."""
    stream, _, edge, cloud, n2m, fit = _setup(k=2000)
    cnmt = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
    fast = simulate(cnmt, stream, make_profile("cp2", seed=0), edge, cloud,
                    seed=0)
    slow = simulate(cnmt, stream,
                    make_profile("cp2", seed=0, bandwidth_bps=5e4),
                    edge, cloud, seed=0)
    assert not np.array_equal(fast.device, slow.device)
    # a slow link makes offloading pay a real serialization cost
    assert slow.offload_frac < fast.offload_frac
    # and offloaded requests got strictly slower, all else equal
    both = (fast.device == CLOUD) & (slow.device == CLOUD)
    if both.any():
        assert np.all(slow.latency_s[both] >= fast.latency_s[both])


def test_profiles_cp1_slower_than_cp2():
    cp1 = make_profile("cp1", seed=0)
    cp2 = make_profile("cp2", seed=0)
    assert cp1.mean_rtt > 1.5 * cp2.mean_rtt
    assert cp1.rtt_s.min() > 0
    # wrap-around lookup
    assert cp1.rtt_at(cp1.times_s[-1] + 10.0) == pytest.approx(cp1.rtt_at(10.0))


def test_stream_covers_trace_window():
    corpus = make_corpus("fr-en", 1000, seed=0)
    stream = make_stream(corpus.n, corpus.m_out, corpus.m_real,
                         duration_s=3600.0, seed=0)
    assert stream.t_arrival_s.min() >= 0
    assert stream.t_arrival_s.max() <= 3600.0
    assert np.all(np.diff(stream.t_arrival_s) > 0)
