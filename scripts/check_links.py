#!/usr/bin/env python3
"""Docs link checker: every relative markdown link in README.md and
docs/*.md must resolve to an existing file or directory.

Checks inline links ``[text](target)`` (images included).  External
schemes (http/https/mailto) and pure in-page anchors (``#...``) are
skipped; a ``target#fragment`` is checked against the file part only.
Exit status 0 when everything resolves, 1 otherwise (one line per
broken link) — run as a CI step and from tests/test_docs_links.py.

Usage: python scripts/check_links.py [repo_root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) with no nested parens in the target; ! prefix = image
_LINK = re.compile(r"!?\[[^\]]*\]\(([^()\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")


def doc_files(root: Path):
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.is_file()]


def broken_links(root: Path):
    """Yield (file, target) for every relative link that does not resolve."""
    for md in doc_files(root):
        for target in _LINK.findall(md.read_text(encoding="utf-8")):
            if target.startswith(_SKIP) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                yield md.relative_to(root), target


def main(argv) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 \
        else Path(__file__).resolve().parent.parent
    bad = list(broken_links(root))
    for md, target in bad:
        print(f"BROKEN {md}: ({target})")
    n_files = len(doc_files(root))
    if bad:
        print(f"link check FAILED: {len(bad)} broken link(s) "
              f"across {n_files} file(s)")
        return 1
    print(f"link check OK: {n_files} file(s), all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
