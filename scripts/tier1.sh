#!/usr/bin/env bash
# Tier-1 verification — the exact command ROADMAP.md specifies, so local
# runs and CI invoke the suite identically.  Extra args pass through to
# pytest (e.g. `scripts/tier1.sh -m "not slow"`).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
