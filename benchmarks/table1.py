"""Paper Table I reproduction: execution-time variation (%) of Naive and
C-NMT vs the GW / Server / Oracle baselines, for 3 dataset-model pairs x
2 connection profiles, 100k requests each.

The T_exe planes are FITTED ON REAL MEASUREMENTS of the three paper
models implemented in JAX on this CPU (BiLSTM / GRU / Marian-style
transformer, reduced scale — linearity is scale-free); the cloud tier is
the measured plane sped up by the Jetson/Titan-like factor; the network
replays synthetic RIPE-Atlas-like traces (CP1 slow, CP2 fast).

Validation targets (paper §III): C-NMT beats both static mappings on
every row, lands within ~0.1-10% of the Oracle (worst for the
transformer), and never loses to Naive by more than noise.
"""

from __future__ import annotations

import time

from benchmarks.common import (
    build_experiment,
    calibrate_dataset,
    run_table1_cell,
)

DATASETS = ("de-en", "fr-en", "en-zh")
PROFILES = ("cp1", "cp2")


def run(n_requests: int = 100_000, verbose: bool = True):
    rows = {}
    csv = []
    for ds in DATASETS:
        t0 = time.perf_counter()
        edge, cloud, n, m, t = calibrate_dataset(ds)
        cal_s = time.perf_counter() - t0
        exp = build_experiment(ds, n_requests=n_requests, edge=edge,
                               cloud=cloud)
        # report fit quality in the measured (unscaled) time unit
        from repro.core.latency_model import LinearLatencyModel
        fit_r2 = LinearLatencyModel().fit(n, m, t).r2(n, m, t)
        rows[ds] = {"cal_s": cal_s, "texe_r2": fit_r2,
                    "gamma": exp["n2m"].gamma, "delta": exp["n2m"].delta}
        for cp in PROFILES:
            t0 = time.perf_counter()
            cell = run_table1_cell(ds, cp, edge=edge, cloud=cloud, exp=exp)
            rows[ds][cp] = cell
            sim_us = (time.perf_counter() - t0) / n_requests * 1e6
            for pol in ("naive", "c-nmt"):
                r = cell[pol]
                csv.append(
                    f"table1_{ds}_{cp}_{pol},{sim_us:.2f},"
                    f"vs_gw={r['vs_gw']:+.2f}%"
                    f"|vs_server={r['vs_server']:+.2f}%"
                    f"|vs_oracle={r['vs_oracle']:+.2f}%")
    if verbose:
        print("\n=== Table I (execution-time variation %, negative = faster) ===")
        hdr = (f"{'dataset':8s} {'policy':7s} | "
               + " | ".join(f"{cp}: vs_GW vs_Server vs_Oracle"
                            for cp in PROFILES))
        print(hdr)
        for ds in DATASETS:
            for pol in ("naive", "c-nmt"):
                cells = []
                for cp in PROFILES:
                    r = rows[ds][cp][pol]
                    cells.append(f"{r['vs_gw']:+7.2f} {r['vs_server']:+8.2f} "
                                 f"{r['vs_oracle']:+8.2f}")
                print(f"{ds:8s} {pol:7s} | " + " | ".join(cells))
            print(f"{'':8s} fit: T_exe R^2={rows[ds]['texe_r2']:.3f} "
                  f"gamma={rows[ds]['gamma']:.3f} "
                  f"delta={rows[ds]['delta']:.2f}")
    return rows, csv


if __name__ == "__main__":
    run()
