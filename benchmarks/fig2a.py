"""Paper Fig. 2a: total translation time is LINEAR in the output length M.

Measures the real Marian-style transformer (JAX, this CPU) across input
lengths, groups wall-clock by produced M, fits T = a*M + b and reports
R^2/MSE — the paper reports R^2 = 0.99 (Jetson) / 0.85 (Titan).
Also validates the RNN case where T depends on N AND M.
"""

from __future__ import annotations

import numpy as np

from repro.core.latency_model import LinearLatencyModel
from benchmarks.common import calibrate_dataset


def run(verbose: bool = True):
    out = {}
    csv = []
    for ds, model_kind in (("en-zh", "transformer"), ("de-en", "bilstm")):
        edge, cloud, n, m, t = calibrate_dataset(ds, reps=3)
        # linear fit in M alone (Fig. 2a plots T vs M)
        a = np.stack([m, np.ones_like(m)], 1)
        coef, *_ = np.linalg.lstsq(a, t, rcond=None)
        pred = a @ coef
        ss_res = ((t - pred) ** 2).sum()
        ss_tot = ((t - t.mean()) ** 2).sum()
        r2_m = 1 - ss_res / max(ss_tot, 1e-12)
        # full plane fit (Eq. 2 form)
        plane = LinearLatencyModel().fit(n, m, t)
        r2_plane = plane.r2(n, m, t)
        out[ds] = {"r2_vs_M": float(r2_m), "r2_plane": float(r2_plane),
                   "slope_ms_per_token": float(coef[0] * 1e3),
                   "alpha_n": plane.alpha_n, "alpha_m": plane.alpha_m}
        csv.append(f"fig2a_{ds}_{model_kind},{coef[0]*1e6:.1f},"
                   f"r2_M={r2_m:.3f}|r2_plane={r2_plane:.3f}")
        if verbose:
            print(f"[fig2a] {ds} ({model_kind}): T vs M R^2={r2_m:.3f} "
                  f"plane R^2={r2_plane:.3f} "
                  f"slope={coef[0]*1e3:.2f} ms/token "
                  f"alpha_N={plane.alpha_n*1e3:.3f} ms "
                  f"alpha_M={plane.alpha_m*1e3:.3f} ms")
    return out, csv


if __name__ == "__main__":
    run()
