"""Big-model tier characterization: Fig. 2a / Table 1 re-run on the
``models/model.py`` stack, per-architecture N→M regressors, and the
mixer-kernel throughput gate.

Four serving workloads, one per architecture family:

* ``qwen3-8b``          — decoder-only chat (GQA attention);
* ``rwkv6-3b``          — linear-attention RNN (rwkv6 mixers);
* ``zamba2-1.2b``       — mamba2-hybrid (SSD mixers + shared attention);
* ``whisper-large-v3``  — encoder-decoder transcription (audio frames in).

Per architecture this benchmark

1. measures REAL ``GenerationSession`` wall-clock over an (N, M) grid —
   the compiled scan decode runs exactly ``max_new`` steps, so M is
   forced the same way the paper forces output length in Fig. 2a — and
   fits the ``T_exe = alpha_n*N + alpha_m*M + beta`` plane (Table 1's
   characterization step);
2. fits the per-architecture ``LinearN2M`` length regressor
   (M̂ = gamma*N + delta) from that workload's (N, M) corpus — chat
   expands, transcription compresses — and reports gamma/delta/R²;
3. hands BOTH to a :class:`~repro.core.scheduler.MultiTierScheduler`
   (edge = rwkv6 plane, cloud = this arch's plane behind a WAN link) and
   replays a length sweep through ``decide`` to report the offload
   fraction the fitted models induce.

MIXER GATE — the kernel regression tripwire.  For the recurrent plans
(rwkv6, mamba2-hybrid) the chunked kernel formulation (what
``kernels/rwkv6_wkv.py`` / ``kernels/ssd_scan.py`` implement, routed via
``LM(mixer_impl="pallas")``) must beat the per-token sequential XLA path
(a ``lax.scan`` of ``decode_step`` over the prompt) in prefill
tokens/sec at batch >= 8, or this benchmark HARD-FAILS (RuntimeError).
On TPU the real Pallas kernels are timed; on CPU, where Pallas interpret
mode is a debugging emulator (orders of magnitude off), the gate times
the XLA lowering of the SAME chunked formulation — bit-for-bit
parity-pinned to the kernels by tests/test_kernels.py and
tests/test_bigmodel_serving.py — and records ``emulated_kernels: true``
in the JSON.

Artifacts: ``name,us_per_call,derived`` CSV lines for the bench
trajectory plus ``BENCH_bigmodel.json`` (schema in docs/benchmarks.md).

Run: PYTHONPATH=src python benchmarks/bigmodel.py [--smoke]
     [--json BENCH_bigmodel.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core.latency_model import LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.tx_estimator import TxEstimator
from repro.models.registry import resolve
from repro.runtime.serving import GenerationSession

# workload -> (arch, synthetic N->M law (gamma, delta, noise)) used to
# draw the per-arch length corpus: chat expands, transcription of a
# fixed audio window compresses toward a caption
ARCHS = (
    ("qwen3-8b", "chat-dense", (1.5, 6.0, 3.0)),
    ("rwkv6-3b", "rwkv6", (1.2, 3.0, 2.0)),
    ("zamba2-1.2b", "mamba2-hybrid", (1.3, 4.0, 2.5)),
    ("whisper-large-v3", "transcription", (0.35, 8.0, 1.5)),
)
GATE_ARCHS = ("rwkv6-3b", "zamba2-1.2b")


def _time_best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ------------------------------------------------ characterization -----
def _measure_grid(arch: str, n_grid, m_grid, reps: int):
    """(N, M, t_s) samples: real generate calls at forced output length
    (the compiled scan always runs max_new steps)."""
    r = resolve(arch)
    params = r.model.init(jax.random.PRNGKey(0))
    cap = max(n_grid) + max(m_grid) + 2
    sess = GenerationSession(r.model, params, max_len=cap)
    rng = np.random.default_rng(0)
    enc = r.cfg.encoder
    frames = (None if enc is None else
              rng.standard_normal((1, enc.max_frames, r.cfg.d_model))
              .astype(np.float32))
    rows = []
    for n in n_grid:
        toks = rng.integers(4, r.cfg.vocab_size, (1, n)).astype(np.int32)
        for m in m_grid:
            kw = {} if frames is None else {"frames": frames}
            sess.generate_with_lengths(toks, max_new=m, **kw)   # compile
            t = _time_best(
                lambda: sess.generate_with_lengths(toks, max_new=m, **kw),
                reps)
            rows.append({"n": int(n), "m": int(m), "t_s": t})
    return rows


def _fit_plane(rows) -> LinearLatencyModel:
    return LinearLatencyModel().fit(
        np.array([r["n"] for r in rows], np.float64),
        np.array([r["m"] for r in rows], np.float64),
        np.array([r["t_s"] for r in rows], np.float64))


def _fit_n2m(law, n_samples: int, seed: int):
    """Per-arch length corpus (synthetic law + noise) -> fitted LinearN2M."""
    gamma, delta, noise = law
    rng = np.random.default_rng(seed)
    n = rng.integers(4, 256, n_samples).astype(np.float64)
    m = np.maximum(gamma * n + delta + rng.normal(0.0, noise, n_samples), 1.0)
    est = LinearN2M().fit(n, m)
    return est, {"gamma": est.gamma, "delta": est.delta,
                 "r2": est.r2(n, m)}, (n, m)


def _offload_frac(edge_plane, cloud_plane, n2m, n_corpus, *,
                  speedup: float = 6.0, rtt_s: float = 0.06) -> float:
    """The fitted plane + regressor consumed by MultiTierScheduler: how
    often Eq. (1) offloads this workload to a ``speedup``x cloud behind
    ``rtt_s`` of WAN."""
    import dataclasses

    fast = dataclasses.replace(cloud_plane,
                               alpha_n=cloud_plane.alpha_n / speedup,
                               alpha_m=cloud_plane.alpha_m / speedup,
                               beta=cloud_plane.beta / speedup)
    tx = TxEstimator(bandwidth_bps=100e6)
    tx.observe(0.0, rtt_s)
    sched = MultiTierScheduler(
        [SchedTier("edge", edge_plane),
         SchedTier("cloud", fast, tx=tx)], n2m)
    picks = [sched.decide(int(n), 0.0).tier for n in n_corpus]
    return float(np.mean([p == 1 for p in picks]))


# ------------------------------------------------------- mixer gate ----
def _stepwise_prefill(model, params, tokens):
    """Per-token sequential XLA prefill: lax.scan of decode_step over the
    prompt — the O(S) recurrence the chunked kernels replace."""
    import jax.numpy as jnp

    b, s = tokens.shape
    state = model.init_decode_state(params, b, max_len=s + 1)

    def body(st, tok):
        logits, st2 = model.decode_step(params, st, tok[:, None])
        return st2, logits

    state, logits = jax.lax.scan(body, state, jnp.asarray(tokens).T)
    return logits[-1]


def _gate_cell(arch: str, batch: int, seq: int, reps: int, impl: str):
    r = resolve(arch, mixer_impl=impl)
    params = r.model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(4, r.cfg.vocab_size, (batch, seq)).astype(np.int32)

    chunked = jax.jit(lambda p, t: r.model.prefill(p, t, max_len=seq + 1)[0])
    stepwise = jax.jit(lambda p, t: _stepwise_prefill(r.model, p, t))
    np.asarray(chunked(params, toks))        # compile both
    np.asarray(stepwise(params, toks))
    t_chunk = _time_best(lambda: np.asarray(chunked(params, toks)), reps)
    t_step = _time_best(lambda: np.asarray(stepwise(params, toks)), reps)
    n_tok = batch * seq
    return {"arch": arch, "batch": batch, "seq": seq,
            "chunked_tok_s": n_tok / t_chunk,
            "stepwise_tok_s": n_tok / t_step,
            "speedup": t_step / t_chunk}


# ------------------------------------------------------------- driver --
def run(n_grid=(8, 16, 32), m_grid=(8, 16, 32), reps: int = 3,
        n2m_samples: int = 2000, gate_batch: int = 8, gate_seq: int = 128,
        verbose: bool = True, out_json: str | None = None):
    backend = jax.default_backend()
    emulated = backend != "tpu"
    impl = "xla" if emulated else "pallas"

    archs_out = {}
    csv = []
    edge_plane = None
    n2m_by_arch = {}
    for idx, (arch, workload, law) in enumerate(ARCHS):
        rows = _measure_grid(arch, n_grid, m_grid, reps)
        plane = _fit_plane(rows)
        est, n2m_stats, (n_corpus, _) = _fit_n2m(law, n2m_samples, seed=idx)
        n2m_by_arch[arch] = (est, n2m_stats, n_corpus)
        if arch == "rwkv6-3b":
            edge_plane = plane
        archs_out[arch] = {
            "workload": workload,
            "rows": rows,
            "plane": {"alpha_n": plane.alpha_n, "alpha_m": plane.alpha_m,
                      "beta": plane.beta},
            "n2m": n2m_stats,
        }
        if verbose:
            mean_us = float(np.mean([r["t_s"] for r in rows])) * 1e6
            print(f"[bigmodel] {arch:18s} ({workload}): "
                  f"aN={plane.alpha_n*1e3:.3f}ms aM={plane.alpha_m*1e3:.3f}ms "
                  f"b={plane.beta*1e3:.1f}ms  "
                  f"n2m gamma={n2m_stats['gamma']:.3f} "
                  f"delta={n2m_stats['delta']:.2f} r2={n2m_stats['r2']:.3f}  "
                  f"(mean cell {mean_us/1e3:.1f}ms)")

    # per-arch regressor + plane consumed by the N-tier rule
    for arch, workload, _ in ARCHS:
        est, n2m_stats, n_corpus = n2m_by_arch[arch]
        plane = LinearLatencyModel(**archs_out[arch]["plane"])
        frac = _offload_frac(edge_plane, plane, est, n_corpus[:200])
        archs_out[arch]["offload_frac"] = frac
        mean_t = float(np.mean([r["t_s"] for r in archs_out[arch]["rows"]]))
        csv.append(f"bigmodel_{arch},{mean_t*1e6:.1f},"
                   f"gamma={n2m_stats['gamma']:.2f}|r2={n2m_stats['r2']:.3f}"
                   f"|offload={frac*100:.0f}%")
        if verbose:
            print(f"[bigmodel] {arch:18s} scheduler offload "
                  f"{frac*100:.0f}% of the {workload} stream")

    # ---- mixer gate (hard-fails on kernel-formulation regression) ----
    gate_rows = [
        _gate_cell(arch, gate_batch, gate_seq, reps, impl)
        for arch in GATE_ARCHS
    ]
    gate_pass = all(r["speedup"] > 1.0 for r in gate_rows)
    for row in gate_rows:
        csv.append(
            f"bigmodel_gate_{row['arch']},"
            f"{row['batch']*row['seq']/row['chunked_tok_s']*1e6:.1f},"
            f"chunked={row['chunked_tok_s']:.0f}tok_s"
            f"|stepwise={row['stepwise_tok_s']:.0f}tok_s"
            f"|speedup={row['speedup']:.2f}x")
        if verbose:
            print(f"[bigmodel] gate {row['arch']:12s} B={row['batch']} "
                  f"S={row['seq']}: chunked {row['chunked_tok_s']:8.0f} tok/s"
                  f"  stepwise {row['stepwise_tok_s']:8.0f} tok/s  "
                  f"speedup {row['speedup']:.2f}x")

    out = {
        "backend": backend,
        "emulated_kernels": emulated,
        "impl_timed": "pallas" if not emulated else "xla-chunked",
        "grid": {"n": list(n_grid), "m": list(m_grid), "reps": reps},
        "archs": archs_out,
        "mixer_gate": {"batch": gate_batch, "seq": gate_seq,
                       "rows": gate_rows, "pass": gate_pass},
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"[bigmodel] wrote {out_json}")
    if not gate_pass:
        bad = [r["arch"] for r in gate_rows if r["speedup"] <= 1.0]
        raise RuntimeError(
            f"mixer gate FAILED at batch {gate_batch}: chunked kernel "
            f"formulation did not beat the per-token XLA path for {bad} "
            f"— kernel-path throughput regression")
    return out, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (seconds, not minutes)")
    ap.add_argument("--json", default=None, help="dump results JSON here")
    args = ap.parse_args()
    if args.smoke:
        run(n_grid=(8, 16), m_grid=(8, 16), reps=2, n2m_samples=500,
            gate_seq=64, out_json=args.json)
    else:
        run(out_json=args.json)
