"""Continuous in-flight batching vs block-to-completion serving.

PR 3's batched tiers drain length-bucketed blocks that run to
completion: one long sequence holds its whole block hostage, and every
short member inherits the straggler's latency — the bubble the
end-cloud pipelining literature attacks.  PR 6 removes the barrier
(ROADMAP item 1): finished rows evict between decode steps and queued
requests prefill into the freed slots of the live batch.

Two sections:

* ``run_des`` — the headline sweep, Poisson rate x max slots on the
  deterministic DES: the SAME stream served by a ``SimTier`` in
  block mode (``continuous=False``, the PR 3 model) and in continuous
  mode (``continuous=True``, one slot per sequence, independent
  finishes).  A tight SLO relative to the straggler barrier makes the
  block penalty visible at every load: short requests miss their
  deadline purely by waiting for batch-max.  At the highest swept rate
  continuous mode must strictly improve BOTH p95 latency and SLO
  attainment for every slot count (checked, hard failure on regression).
* ``run_real`` — real execution: a smoke-scale LM behind
  ``CollaborativeEngine.serve_continuous`` with a
  ``ContinuousGenerationSession``, the same virtual arrival schedule
  served with ``refill=True`` (continuous) and ``refill=False``
  (block-to-completion).  Latencies are measured decode wall-clock laid
  onto the virtual arrivals (shapes warmed first); reported for the
  bench trail, not gated — CI machines jitter.

Emits ``BENCH_continuous.json`` (``--json``) with both sections so CI
archives the comparison alongside ``BENCH_decode.json``.

Run: PYTHONPATH=src python benchmarks/continuous_batching.py [--smoke]
     [--json BENCH_continuous.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.simulator import SimTier, make_poisson_stream, simulate_des
from repro.data.synthetic import make_corpus

# service dominated by output length M (the paper's §II-A linearity) so
# the corpus' M spread produces real stragglers inside a block
_POD = DeviceProfile("pod", LinearLatencyModel(2e-5, 2e-3, 1e-3), 0.05)
_OVERHEAD_S = 1e-3
_SEED = 17


def _scheduler(n2m: LinearN2M, slots: int) -> MultiTierScheduler:
    return MultiTierScheduler(
        [SchedTier("pod", dataclasses.replace(_POD.model), None,
                   batch_size=slots, per_seq_overhead_s=_OVERHEAD_S)],
        dataclasses.replace(n2m))


def run_des(n_requests: int = 8000, rates=(30.0, 60.0, 100.0),
            slot_counts=(8, 16), slo_s: float = 0.1,
            verbose: bool = True, check: bool = True):
    """Poisson rate x max-slots sweep, block vs continuous on one tier.

    Returns ``(rows, csv)``; ``rows[(rate, slots, mode)]`` is the DES
    summary dict.  With ``check=True`` the highest swept rate must show
    continuous strictly improving p95 AND SLO attainment over block for
    every slot count — the PR 6 acceptance bar.
    """
    corpus = make_corpus("de-en", n_requests + 2000, seed=_SEED)
    fit, eval_ = corpus.split(2000)
    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    n2m = LinearN2M().fit(nf, mf)

    rows = {}
    csv = []
    for rate in rates:
        for slots in slot_counts:
            for cont in (False, True):
                stream = make_poisson_stream(
                    eval_.n, eval_.m_out, eval_.m_real,
                    rate_hz=rate, seed=_SEED, slo_s=slo_s)
                tiers = [SimTier("pod", _POD, servers=1,
                                 queue_capacity=256, batch_size=slots,
                                 per_seq_overhead_s=_OVERHEAD_S,
                                 continuous=cont)]
                res = simulate_des(_scheduler(n2m, slots), stream, tiers,
                                   seed=_SEED)
                mode = "cont" if cont else "block"
                s = res.summary()
                rows[(rate, slots, mode)] = s
                csv.append(
                    f"continuous_rate{rate:g}_s{slots}_{mode},"
                    f"{s['mean_latency_s']*1e6:.1f},"
                    f"p95={s['p95_latency_s']*1e3:.1f}ms"
                    f"|slo={s['slo_attainment']:.3f}"
                    f"|shed={int(s['shed'])}")
            bl = rows[(rate, slots, "block")]
            co = rows[(rate, slots, "cont")]
            if verbose:
                print(f"[continuous] rate={rate:6.1f}/s slots={slots:<3d} "
                      f"block p95={bl['p95_latency_s']*1e3:7.1f}ms "
                      f"slo={bl['slo_attainment']:.3f}  ->  "
                      f"cont p95={co['p95_latency_s']*1e3:7.1f}ms "
                      f"slo={co['slo_attainment']:.3f}")

    top = max(rates)
    for slots in slot_counts:
        bl = rows[(top, slots, "block")]
        co = rows[(top, slots, "cont")]
        ok = (co["p95_latency_s"] < bl["p95_latency_s"]
              and co["slo_attainment"] > bl["slo_attainment"])
        msg = (f"[continuous] headline rate={top:g}/s slots={slots}: "
               f"p95 {bl['p95_latency_s']*1e3:.1f}->"
               f"{co['p95_latency_s']*1e3:.1f}ms, "
               f"slo {bl['slo_attainment']:.3f}->"
               f"{co['slo_attainment']:.3f}  "
               f"{'WIN' if ok else 'REGRESSION'}")
        if verbose:
            print(msg)
        if check and not ok:
            raise AssertionError(msg)
    return rows, csv


def run_real(n_requests: int = 24, max_slots: int = 4, max_new: int = 12,
             rate_hz: float = 30.0, slo_s: float = 1.0,
             verbose: bool = True):
    """Real-execution comparison on a smoke-scale LM.

    The same virtual Poisson arrival schedule is served twice by
    ``serve_continuous`` — ``refill=True`` (slot table refilled between
    steps) vs ``refill=False`` (block-to-completion) — on fresh
    sessions over the same params.  Sessions are warmed (all admission
    shapes compiled) before measuring, so virtual-time latencies are
    decode wall-clock, not compile time.
    """
    import jax

    from repro.configs import smoke_config
    from repro.models.model import LM
    from repro.runtime.engine import CollaborativeEngine, Tier
    from repro.runtime.serving import ContinuousGenerationSession

    cfg = smoke_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(_SEED)
    prompts = [rng.integers(3, cfg.vocab_size,
                            size=int(rng.integers(2, 12))).astype(np.int32)
               for _ in range(n_requests)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    prof = DeviceProfile("npu", LinearLatencyModel(0.0, 0.0, 0.01), 0.0)

    rows = {}
    for refill in (False, True):
        session = ContinuousGenerationSession(
            model, params, max_slots=max_slots,
            max_len=max(len(p) for p in prompts) + max_new + 8)
        # warm every admission shape the run will see, then reset the
        # table (compiled shapes survive the reset)
        session.serve(prompts, max_new=max_new, refill=refill)
        session.reset()
        eng = CollaborativeEngine(
            n2m=LinearN2M(1.0, 0.0),
            tiers=[Tier(prof, name="npu", servers=1, queue_capacity=256,
                        batch_size=max_slots,
                        continuous_session=session)],
            seed=_SEED)
        res = eng.serve_continuous(prompts, arrival_s=arrivals,
                                   deadline_s=slo_s, max_new=max_new,
                                   refill=refill)
        lat = np.array([r.latency_s for r in res if not r.shed])
        mode = "cont" if refill else "block"
        rows[mode] = {
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "slo_attainment": eng.stats()["slo_attainment"],
            "shed": int(sum(r.shed for r in res)),
            "steps": session.n_steps,
            "prefills": session.n_prefills,
        }
        if verbose:
            s = rows[mode]
            print(f"[continuous-real] {mode:5s} "
                  f"p50={s['p50_latency_s']*1e3:7.1f}ms "
                  f"p95={s['p95_latency_s']*1e3:7.1f}ms "
                  f"slo={s['slo_attainment']:.3f} "
                  f"steps={s['steps']} prefills={s['prefills']}")
    csv = [f"continuous_real_{mode},{s['p50_latency_s']*1e6:.1f},"
           f"p95={s['p95_latency_s']*1e3:.1f}ms|slo={s['slo_attainment']:.3f}"
           for mode, s in rows.items()]
    return rows, csv


def run(n_requests: int = 8000, rates=(30.0, 60.0, 100.0),
        slot_counts=(8, 16), slo_s: float = 0.1, real: bool = True,
        verbose: bool = True, out_json: str | None = None):
    des_rows, csv = run_des(n_requests=n_requests, rates=rates,
                            slot_counts=slot_counts, slo_s=slo_s,
                            verbose=verbose)
    real_rows = {}
    if real:
        real_rows, real_csv = run_real(verbose=verbose)
        csv = csv + real_csv

    if out_json:
        top = max(rates)
        payload = {
            "des": [{"rate_hz": r, "slots": s, "mode": m, **row}
                    for (r, s, m), row in des_rows.items()],
            "headline": {
                "rate_hz": top,
                "slo_s": slo_s,
                "per_slots": {
                    str(s): {
                        "block_p95_ms":
                            des_rows[(top, s, "block")]["p95_latency_s"] * 1e3,
                        "cont_p95_ms":
                            des_rows[(top, s, "cont")]["p95_latency_s"] * 1e3,
                        "block_slo":
                            des_rows[(top, s, "block")]["slo_attainment"],
                        "cont_slo":
                            des_rows[(top, s, "cont")]["slo_attainment"],
                    } for s in slot_counts},
            },
            "real": real_rows,
        }
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        if verbose:
            print(f"[continuous] wrote {out_json}")
    return {"des": des_rows, "real": real_rows}, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI invocation (small request counts)")
    ap.add_argument("--json", default=None, help="dump results JSON here")
    args = ap.parse_args()
    if args.smoke:
        run(n_requests=3000, rates=(30.0, 100.0), slot_counts=(8,),
            out_json=args.json)
    else:
        run(out_json=args.json)
