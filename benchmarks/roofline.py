"""Aggregate the dry-run records into the §Roofline table.

Reads roofline/*.json produced by ``repro.launch.dryrun`` and prints the
per-(arch x shape x mesh) three-term table plus dominant bottleneck and
useful-FLOPs ratio.  Also used to generate EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import glob
import json
import os


def load_records(path: str = "roofline"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def table(recs, mesh: str = "pod1"):
    lines = []
    hdr = (f"{'arch':22s} {'shape':12s} {'mem/dev':>8s} {'fits':>4s} "
           f"{'compute_s':>10s} {'memory_s':>9s} {'collect_s':>10s} "
           f"{'dominant':>10s} {'MF/HLO':>6s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        tag = f"{r['arch']:22s} {r['shape']:12s}"
        if "skipped" in r:
            lines.append(f"{tag} {'skip: ' + r['skipped'][:58]}")
            continue
        if not r.get("ok"):
            lines.append(f"{tag} FAIL {r.get('error', '')[:60]}")
            continue
        m, rl = r["memory"], r["roofline"]
        ratio = rl.get("useful_flops_ratio") or float("nan")
        lines.append(
            f"{tag} {m['per_device_total']/1e9:7.1f}G "
            f"{'Y' if m['fits_hbm'] else 'N':>4s} "
            f"{rl['compute_s']:10.4f} {rl['memory_s']:9.4f} "
            f"{rl['collective_s']:10.4f} {rl['dominant']:>10s} "
            f"{ratio:6.2f}")
    return "\n".join(lines)


def run(path: str = "roofline", verbose: bool = True):
    recs = load_records(path)
    final = load_records("roofline_final") if os.path.isdir(
        "roofline_final") and path == "roofline" else []
    csv = []
    for label, rr in (("baseline", recs), ("final", final)):
        for r in rr:
            if not r.get("ok"):
                continue
            rl = r["roofline"]
            dom_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
            csv.append(
                f"roofline[{label}]_{r['arch']}_{r['shape']}_{r['mesh']},"
                f"{dom_s*1e6:.0f},"
                f"dom={rl['dominant']}|fits={r['memory']['fits_hbm']}")
    if verbose:
        for label, rr in (("baseline TP+FSDP", recs),
                          ("optimized --auto", final)):
            for mesh in ("pod1", "pod2"):
                if any(r.get("mesh") == mesh for r in rr):
                    print(f"\n=== Roofline table ({mesh}, {label}) ===")
                    print(table(rr, mesh))
    return recs + final, csv


if __name__ == "__main__":
    run()
