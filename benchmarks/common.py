"""Shared benchmark plumbing: paper-model calibration + experiment setup."""

from __future__ import annotations

import time
from typing import Dict, Tuple

import numpy as np

from repro.core.calibration import (
    make_edge_cloud_pair,
    measure_seq2seq,
    measure_seq2seq_grid,
)
from repro.data.synthetic import LANGUAGE_PAIRS
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.profiles import make_profile
from repro.core.scheduler import CNMTScheduler, NaiveScheduler
from repro.core.simulator import make_stream, table1_row
from repro.data.synthetic import make_corpus
from repro.models.registry import resolve

# Jetson-TX2-vs-Titan-XP-like speed gap (paper Fig. 2a slopes)
CLOUD_SPEEDUP = 5.0
CAL_LENGTHS = (4, 8, 16, 32, 64, 96)
MODEL_SCALE = 0.25        # CPU-budget scale; latency LINEARITY is scale-free
# The paper's edge device is a Jetson TX2 running the FULL-size models;
# our measurements are quarter-scale models on a fast CPU core.  EDGE_SCALE
# rescales the measured plane to Jetson-class absolute latency (~8x) so the
# edge/cloud/RTT crossover sits inside the corpus length distribution, as
# in the paper.  Slopes/structure stay measured, only the unit changes.
EDGE_SCALE = 8.0


def calibrate_dataset(dataset: str, *, scale: float = MODEL_SCALE,
                      reps: int = 2, seed: int = 0):
    """Measure the real JAX model on this CPU and fit the T_exe planes.

    The (N, M) grid is controlled (forced decode length) so the plane fit
    has coverage; M values per N bracket the language pair's gamma*N+delta
    line.  Returns (edge, cloud, n, m, t).
    """
    _r = resolve(f"cnmt:{dataset}", scale=scale, vocab=2000,
                 max_decode_len=160)
    model, pair = _r.model, _r.pair
    import jax
    params = model.init(jax.random.PRNGKey(seed))
    translate = model.make_translate(params)

    lp = LANGUAGE_PAIRS[dataset]

    def m_grid(n: int):
        center = lp.gamma * n + lp.delta
        return sorted({max(2, int(round(center * f))) for f in (0.5, 1.0, 1.6)})

    n, m, t = measure_seq2seq_grid(
        lambda toks, fl: translate(toks, forced_len=fl),
        CAL_LENGTHS, m_grid, reps=reps, warmup=1, seed=seed, vocab=2000)
    edge, cloud = make_edge_cloud_pair(n, m, t, speedup=CLOUD_SPEEDUP,
                                       edge_scale=EDGE_SCALE)
    return edge, cloud, n, m, t


def build_experiment(dataset: str, *, n_requests: int = 100_000,
                     n_fit: int = 10_000, seed: int = 0,
                     edge=None, cloud=None):
    """Everything table1 needs for one dataset row."""
    corpus = make_corpus(dataset, n_fit + n_requests, seed=seed)
    fit, eval_ = corpus.split(n_fit)
    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    n2m = LinearN2M().fit(nf, mf)
    cnmt = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
    naive = NaiveScheduler(edge, cloud, nf, mf)
    return {"fit": fit, "eval": eval_, "n2m": n2m, "cnmt": cnmt,
            "naive": naive}


def run_table1_cell(dataset: str, profile_name: str, *, edge, cloud,
                    exp, seed: int = 0, probe_interval_s=60.0):
    """One Table-I cell.  ``probe_interval_s``: the gateway refreshes its
    RTT estimate at least this often (paper §II-C assumes near-continuous
    samples; without it a constant-M̂ policy can lock local forever after
    one spike — see tests/test_simulator.py for the paper-faithful mode).
    """
    profile = make_profile(profile_name, seed=seed)
    stream = make_stream(exp["eval"].n, exp["eval"].m_out,
                         exp["eval"].m_real,
                         duration_s=profile.times_s[-1], seed=seed)
    return table1_row(dataset=dataset, stream=stream, profile=profile,
                      edge=edge, cloud=cloud, cnmt=exp["cnmt"],
                      naive=exp["naive"], seed=seed,
                      probe_interval_s=probe_interval_s)
