"""Fault-tolerant collaborative serving vs fail-and-lose baseline.

PR 8's failover machinery (bounded retries with backoff, per-tier
circuit breakers feeding the scheduler's candidate mask, graceful
degradation to edge-only) only earns its complexity if it buys SLO
attainment when tiers actually die.  This benchmark injects the same
deterministic :class:`~repro.core.faults.FaultSchedule` into the DES
twice per scenario:

* **no-retry baseline** (``retry=None``) — the pre-fault-tolerance
  semantics: an attempt that hits a dead tier or a blackholed link is
  simply lost (after the detection time), nothing reroutes.
* **failover** (``retry=RetryPolicy()``) — failed attempts re-enter the
  router with the failed tier masked, breakers steer the argmin away
  from dark tiers, and shed responses carry ``retry_after_s``.

Scenarios swept (all on the 3-tier npu/edge/cloud DES under Poisson
load): a hard mid-run cloud outage, a blackholed cloud link (failure
only detectable by timeout), and a flapping cloud (repeated short
outages — the circuit-breaker stress case).

Hard acceptance bar (the run RAISES on regression): in EVERY scenario
failover must strictly beat the no-retry baseline on SLO attainment
and availability.  The zero-fault pin (armed-but-empty schedule ==
``faults=None`` bit-for-bit) guards the other direction: the machinery
must cost nothing when nothing fails.

Emits ``BENCH_faults.json`` (``--json``) for the CI bench trail.

Run: PYTHONPATH=src python benchmarks/fault_tolerance.py [--smoke]
     [--json BENCH_faults.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.core.faults import (
    FaultSchedule,
    LinkFault,
    RetryPolicy,
    TierOutage,
)
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.simulator import SimTier, make_poisson_stream, simulate_des
from repro.core.tx_estimator import TxEstimator

_SEED = 23


def _three_tier(seed: int = 5):
    """npu / edge / cloud DES setup (the multitier benchmark's shape)."""
    npu = DeviceProfile("npu", LinearLatencyModel(4e-4, 1.6e-3, 0.004), 0.05)
    edge = DeviceProfile("edge", LinearLatencyModel(1.5e-4, 6e-4, 0.008), 0.05)
    cloud = DeviceProfile("cloud", LinearLatencyModel(2e-5, 9e-5, 0.002), 0.08)
    lan, wan = make_profile("cp2", seed=seed), make_profile("cp1", seed=seed)
    tiers = [SimTier("npu", npu, servers=1, queue_capacity=16),
             SimTier("edge", edge, servers=2, queue_capacity=64, link=lan),
             SimTier("cloud", cloud, servers=8, link=wan)]
    sched = MultiTierScheduler(
        [SchedTier("npu", dataclasses.replace(npu.model), None),
         SchedTier("edge", dataclasses.replace(edge.model),
                   TxEstimator(init_rtt_s=float(lan.rtt_at(0.0)))),
         SchedTier("cloud", dataclasses.replace(cloud.model),
                   TxEstimator(init_rtt_s=float(wan.rtt_at(0.0))))],
        LinearN2M(0.9, 2.0))
    return sched, tiers


def _stream(n_requests: int, rate_hz: float, slo_s: float, seed: int = 2):
    rng = np.random.default_rng(seed)
    n = rng.integers(2, 200, n_requests).astype(np.float64)
    m = np.maximum(0.9 * n + rng.normal(0, 3, n_requests), 1.0)
    return make_poisson_stream(n, m, m, rate_hz=rate_hz, seed=seed,
                               slo_s=slo_s)


def _scenarios(horizon_s: float):
    """Named fault schedules scaled to the stream's time span.

    The cloud (tier 2) is the fastest tier, so it carries most of the
    load when healthy — killing it is the worst case the degradation
    ladder must absorb.  The npu (tier 0) stays protected: edge-only
    service must always exist.
    """
    a, b = 0.15 * horizon_s, 0.55 * horizon_s
    flap = tuple(TierOutage(2, t, t + 0.04 * horizon_s)
                 for t in np.linspace(0.1 * horizon_s, 0.8 * horizon_s, 5))
    return {
        "cloud-outage": FaultSchedule(outages=(TierOutage(2, a, b),)),
        "link-blackhole": FaultSchedule(
            link_faults=(LinkFault(2, a, b, blackhole=True),)),
        "flapping-cloud": FaultSchedule(outages=flap),
    }


def run(n_requests: int = 20_000, rate_hz: float = 15.0,
        slo_s: float = 2.0, verbose: bool = True, check: bool = True,
        out_json: str | None = None):
    """Outage-scenario sweep: no-retry baseline vs failover.

    Returns ``(rows, csv)``; ``rows[(scenario, mode)]`` is the DES
    summary (latency stats + fault stats).  With ``check=True`` the
    run raises unless failover strictly beats no-retry on BOTH SLO
    attainment and availability in every scenario, and unless the
    armed-but-empty run is bit-for-bit identical to ``faults=None``.

    The load point matters: failover converts fault losses into extra
    load on the surviving tiers, so the win requires edge+npu headroom
    (here ~2x the offered rate).  An overloaded system degrades to
    shedding either way — that regime is the multitier benchmark's
    story, not this one's.
    """
    # detection tuned to the SLO: a blackholed attempt must leave room
    # to reroute and still finish inside the deadline
    policy = RetryPolicy(timeout_s=0.25, backoff_base_s=0.02)
    stream = _stream(n_requests, rate_hz, slo_s)
    horizon = float(stream.t_arrival_s[-1])

    # zero-fault pin: arming the machinery with an empty schedule must
    # not move a single float
    sched0, tiers0 = _three_tier()
    base = simulate_des(sched0, _stream(n_requests, rate_hz, slo_s),
                        tiers0, seed=_SEED)
    sched1, tiers1 = _three_tier()
    armed = simulate_des(sched1, _stream(n_requests, rate_hz, slo_s),
                         tiers1, seed=_SEED, faults=FaultSchedule())
    for field in ("tier", "t_start_s", "t_finish_s", "wait_s", "tx_s",
                  "exec_s", "latency_s", "shed"):
        if not np.array_equal(getattr(base, field), getattr(armed, field),
                              equal_nan=True):
            raise AssertionError(
                f"[faults] zero-fault pin broken: {field} differs when an "
                f"empty FaultSchedule is armed")
    if verbose:
        print("[faults] zero-fault pin OK (empty schedule == faults=None)")

    rows = {}
    csv = []
    for name, faults in _scenarios(horizon).items():
        for mode, retry in (("no-retry", None), ("failover", policy)):
            sched, tiers = _three_tier()
            res = simulate_des(sched, _stream(n_requests, rate_hz, slo_s),
                               tiers, seed=_SEED, faults=faults, retry=retry)
            s = res.summary()
            rows[(name, mode)] = s
            csv.append(f"faults_{name}_{mode},"
                       f"{s['mean_latency_s']*1e6:.1f},"
                       f"slo={s['slo_attainment']:.3f}"
                       f"|avail={s['availability']:.3f}"
                       f"|lost={int(s['fault_lost'])}")
        nr, fo = rows[(name, "no-retry")], rows[(name, "failover")]
        if verbose:
            print(f"[faults] {name:16s} no-retry "
                  f"slo={nr['slo_attainment']:.3f} "
                  f"avail={nr['availability']:.3f} "
                  f"lost={int(nr['fault_lost'])}  ->  failover "
                  f"slo={fo['slo_attainment']:.3f} "
                  f"avail={fo['availability']:.3f} "
                  f"lost={int(fo['fault_lost'])} "
                  f"retries={int(fo['retries'])} "
                  f"opens={int(fo['breaker_opens'])}")

    if check:
        for name in _scenarios(horizon):
            nr, fo = rows[(name, "no-retry")], rows[(name, "failover")]
            ok = (fo["slo_attainment"] > nr["slo_attainment"]
                  and fo["availability"] > nr["availability"])
            if not ok:
                raise AssertionError(
                    f"[faults] {name}: failover does not strictly beat "
                    f"no-retry (slo {nr['slo_attainment']:.4f}->"
                    f"{fo['slo_attainment']:.4f}, avail "
                    f"{nr['availability']:.4f}->{fo['availability']:.4f})")
        if verbose:
            print("[faults] acceptance bar PASSED: failover strictly beats "
                  "no-retry in every scenario")

    if out_json:
        payload = {
            "setup": {"n_requests": n_requests, "rate_hz": rate_hz,
                      "slo_s": slo_s, "horizon_s": horizon},
            "scenarios": [{"scenario": name, "mode": mode, **row}
                          for (name, mode), row in rows.items()],
        }
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        if verbose:
            print(f"[faults] wrote {out_json}")
    return rows, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI invocation (small request counts)")
    ap.add_argument("--json", default=None, help="dump results JSON here")
    args = ap.parse_args()
    if args.smoke:
        run(n_requests=4000, out_json=args.json)
    else:
        run(out_json=args.json)
