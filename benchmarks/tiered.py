"""Beyond-paper: C-NMT routing between TPU tiers priced from the dry-run.

The paper characterizes devices by measuring them.  The framework can
also price tiers it CANNOT measure: ``device_from_roofline`` converts the
dry-run's analytic per-step cost into a T_exe(N, M) plane.  Here the
"edge" tier is a small dense model on a single v5e chip and the "cloud"
tier is the same family on a 256-chip pod behind a WAN — the C-NMT rule
then routes per request exactly as in the paper, but the whole setup is
derived from compiled artifacts instead of stopwatch runs.
"""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.calibration import device_from_roofline
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.profiles import make_profile
from repro.core.scheduler import CNMTScheduler, NaiveScheduler
from repro.core.simulator import make_stream, table1_row
from repro.data.synthetic import make_corpus
from repro.models.costs import forward_flops, kv_bytes_per_token


def _tier(arch: str, *, chips: int, overhead_s: float, name: str):
    cfg = get_config(arch)
    # per-token costs from the analytic model the dry-run validates
    prefill_flops = forward_flops(cfg, tokens=1, context=1, decode=False)
    decode_flops = forward_flops(cfg, tokens=1, context=2048, decode=True)
    decode_bytes = (cfg.param_counts()["active"] * 2
                    + 2048 * kv_bytes_per_token(cfg))
    return device_from_roofline(
        name, prefill_flops_per_token=prefill_flops,
        decode_flops_per_token=decode_flops,
        decode_bytes_per_token=decode_bytes,
        chips=chips, overhead_s=overhead_s)


def run(n_requests: int = 50_000, verbose: bool = True):
    # edge: qwen3-8b on 1 chip at the cell tower; cloud: qwen3-32b on a pod
    edge = _tier("qwen3-8b", chips=1, overhead_s=0.002, name="edge-1chip")
    cloud = _tier("qwen3-32b", chips=256, overhead_s=0.004,
                  name="cloud-pod")
    corpus = make_corpus("en-zh", n_requests + 5000, seed=21)
    fit, eval_ = corpus.split(5000)
    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    n2m = LinearN2M().fit(nf, mf)
    profile = make_profile("cp1", seed=21)
    stream = make_stream(eval_.n, eval_.m_out, eval_.m_real,
                         duration_s=profile.times_s[-1], seed=21)
    row = table1_row(
        dataset="en-zh(tiered-tpu)", stream=stream, profile=profile,
        edge=edge, cloud=cloud,
        cnmt=CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m),
        naive=NaiveScheduler(edge, cloud, nf, mf), seed=21)
    csv = []
    for pol in ("naive", "c-nmt"):
        r = row[pol]
        csv.append(f"tiered_{pol},{r['total_s']*1e6/n_requests:.1f},"
                   f"vs_gw={r['vs_gw']:+.2f}%|vs_server={r['vs_server']:+.2f}%"
                   f"|vs_oracle={r['vs_oracle']:+.2f}%")
        if verbose:
            print(f"[tiered] {pol:6s}: vs_edge={r['vs_gw']:+6.2f}% "
                  f"vs_pod={r['vs_server']:+6.2f}% "
                  f"vs_oracle={r['vs_oracle']:+6.2f}% "
                  f"offload={r['offload_frac']:.2f}")
    return row, csv


if __name__ == "__main__":
    run()
