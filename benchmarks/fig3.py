"""Paper Fig. 3: the linear N->M mapping quality per language pair.

Fits gamma/delta on ground-truth pairs (after ParaCrawl-style
pre-filtering, as the paper does) and reports R^2 / MSE on the
bucket-averaged M-per-N curve the figure plots.  Paper numbers:
R^2 = 0.99 on all three pairs; gamma < 1 for FR->EN and EN->ZH.
"""

from __future__ import annotations

import numpy as np

from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.data.synthetic import make_corpus


def run(size: int = 50_000, verbose: bool = True):
    out = {}
    csv = []
    for pair in ("de-en", "fr-en", "en-zh"):
        corpus = make_corpus(pair, size, seed=3)
        n, m = prefilter_pairs(corpus.n, corpus.m_real)
        reg = LinearN2M().fit(n, m)
        uniq = np.array([u for u in np.unique(n) if (n == u).sum() >= 5])
        avg = np.array([m[n == u].mean() for u in uniq])
        r2 = reg.r2(uniq, avg)
        mse = reg.mse(uniq, avg)
        out[pair] = {"gamma": reg.gamma, "delta": reg.delta,
                     "r2": r2, "mse": mse}
        csv.append(f"fig3_{pair},0,gamma={reg.gamma:.3f}|r2={r2:.3f}"
                   f"|mse={mse:.2f}")
        if verbose:
            print(f"[fig3] {pair}: gamma={reg.gamma:.3f} "
                  f"delta={reg.delta:.2f} R^2={r2:.3f} MSE={mse:.2f}")
    return out, csv


if __name__ == "__main__":
    run()
