"""Beyond-paper: N-tier queue-aware serving under Poisson load sweeps.

The paper's Table I replays independent requests over exactly two
devices.  This benchmark stresses the generalized rule

    d_tgt = argmin_k [ T_queue,k + T_tx,k + T_exe,k(N, M_hat) ]

on a 3-tier topology (on-device NPU, LAN edge gateway, WAN cloud pod)
with bounded FIFO queues and finite server counts, swept across Poisson
arrival rates.  Reported per rate: per-tier offload fractions, p95/mean
latency, mean queue wait, and the static single-tier baselines — the
headline being that the queue-aware policy keeps p95 bounded by shifting
traffic toward deeper tiers as the shallow ones saturate, which the
paper's load-blind Eq. (1) cannot do.

Run: PYTHONPATH=src python benchmarks/multitier.py
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.calibration import OnlineCalibrator
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.profiles import make_profile
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.simulator import SimTier, make_poisson_stream, simulate_des
from repro.core.tx_estimator import TxEstimator
from repro.data.synthetic import make_corpus


def _topology(seed: int):
    """3-tier NPU / edge / cloud setup (planes in the paper's ms range)."""
    npu = DeviceProfile("npu", LinearLatencyModel(4e-4, 1.6e-3, 0.004), 0.05)
    edge = DeviceProfile("edge", LinearLatencyModel(1.5e-4, 6e-4, 0.008), 0.05)
    cloud = DeviceProfile("cloud", LinearLatencyModel(2e-5, 9e-5, 0.002), 0.08)
    lan = make_profile("cp2", seed=seed)      # clean LAN-ish link
    wan = make_profile("cp1", seed=seed)      # congested WAN link
    tiers = [
        SimTier("npu", npu, servers=1, queue_capacity=8),
        SimTier("edge", edge, servers=2, queue_capacity=64, link=lan),
        SimTier("cloud", cloud, servers=8, link=wan),
    ]
    return tiers, (lan, wan)


def _scheduler(tiers, links, n2m: LinearN2M) -> MultiTierScheduler:
    lan, wan = links
    return MultiTierScheduler(
        [SchedTier("npu", dataclasses.replace(tiers[0].profile.model), None),
         SchedTier("edge", dataclasses.replace(tiers[1].profile.model),
                   TxEstimator(init_rtt_s=float(lan.rtt_at(0.0)))),
         SchedTier("cloud", dataclasses.replace(tiers[2].profile.model),
                   TxEstimator(init_rtt_s=float(wan.rtt_at(0.0))))],
        dataclasses.replace(n2m))


def _simulate_static(tier: SimTier, stream, seed: int):
    """True single-tier baseline: the topology contains ONLY tier k (its
    queue unbounded, as a pure static policy queues everything), so no
    bounded-queue rerouting can spill traffic to other tiers."""
    solo = dataclasses.replace(tier, queue_capacity=None)
    tx = None
    if solo.link is not None:
        tx = TxEstimator(init_rtt_s=float(solo.link.rtt_at(0.0)))
    sched = MultiTierScheduler(
        [SchedTier(solo.name, dataclasses.replace(solo.profile.model), tx)],
        LinearN2M(1.0, 0.0))
    return simulate_des(sched, stream, [solo], seed=seed)


def run(n_requests: int = 20_000, rates=(5.0, 30.0, 120.0),
        refit_interval: int = 1000, verbose: bool = True):
    corpus = make_corpus("de-en", n_requests + 4000, seed=11)
    fit, eval_ = corpus.split(4000)
    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    n2m = LinearN2M().fit(nf, mf)

    csv = []
    rows = {}
    for rate in rates:
        tiers, links = _topology(seed=11)
        stream = make_poisson_stream(eval_.n, eval_.m_out, eval_.m_real,
                                     rate_hz=rate, seed=11)
        sched = _scheduler(tiers, links, n2m)
        cal = OnlineCalibrator(len(tiers), interval=refit_interval)
        res = simulate_des(sched, stream, tiers, seed=11, calibrator=cal)
        s = res.summary()
        fracs = res.tier_frac()

        # static single-tier baselines (queues still simulated!)
        static_p95 = {
            t.name: _simulate_static(t, stream, seed=11).p95_latency_s()
            for t in tiers}

        rows[rate] = {"summary": s, "tier_frac": fracs,
                      "static_p95": static_p95}
        frac_str = "|".join(f"{name}={f:.2f}" for name, f in fracs.items())
        csv.append(
            f"multitier_rate{rate:g},{s['mean_latency_s']*1e6:.1f},"
            f"p95={s['p95_latency_s']*1e3:.1f}ms|wait={s['mean_wait_s']*1e3:.1f}ms"
            f"|{frac_str}")
        if verbose:
            best_static = min(static_p95.values())
            print(f"[multitier] rate={rate:7.1f}/s  "
                  f"p95={s['p95_latency_s']*1e3:7.1f}ms  "
                  f"mean_wait={s['mean_wait_s']*1e3:6.1f}ms  "
                  f"offload {frac_str}  "
                  f"(best static p95={best_static*1e3:.1f}ms, "
                  f"refits={cal.n_refits})")
    return rows, csv


if __name__ == "__main__":
    run()
