"""Beyond-paper: N-tier queue-aware serving under Poisson load sweeps.

The paper's Table I replays independent requests over exactly two
devices.  This benchmark stresses the generalized rule

    d_tgt = argmin_k [ T_queue,k + T_tx,k + T_exe,k(N, M_hat) ]

on a 3-tier topology (on-device NPU, LAN edge gateway, WAN cloud pod)
with bounded FIFO queues and finite server counts, swept across Poisson
arrival rates.  Reported per rate: per-tier offload fractions, p95/mean
latency, mean queue wait, and the static single-tier baselines — the
headline being that the queue-aware policy keeps p95 bounded by shifting
traffic toward deeper tiers as the shallow ones saturate, which the
paper's load-blind Eq. (1) cannot do.

``run_batched`` sweeps batch size x Poisson rate with per-request SLO
deadlines: the pod tier drains its queue in length-bucketed batches
(sub-linear batch cost), so sustained throughput rises with batch size
while deadline-aware admission sheds what cannot meet the SLO — the
report shows SLO attainment alongside p95, not just latency.

Run: PYTHONPATH=src python benchmarks/multitier.py  [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core.calibration import OnlineCalibrator
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.profiles import make_profile
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.simulator import SimTier, make_poisson_stream, simulate_des
from repro.core.tx_estimator import TxEstimator
from repro.data.synthetic import make_corpus


def _topology(seed: int):
    """3-tier NPU / edge / cloud setup (planes in the paper's ms range)."""
    npu = DeviceProfile("npu", LinearLatencyModel(4e-4, 1.6e-3, 0.004), 0.05)
    edge = DeviceProfile("edge", LinearLatencyModel(1.5e-4, 6e-4, 0.008), 0.05)
    cloud = DeviceProfile("cloud", LinearLatencyModel(2e-5, 9e-5, 0.002), 0.08)
    lan = make_profile("cp2", seed=seed)      # clean LAN-ish link
    wan = make_profile("cp1", seed=seed)      # congested WAN link
    tiers = [
        SimTier("npu", npu, servers=1, queue_capacity=8),
        SimTier("edge", edge, servers=2, queue_capacity=64, link=lan),
        SimTier("cloud", cloud, servers=8, link=wan),
    ]
    return tiers, (lan, wan)


def _scheduler(tiers, links, n2m: LinearN2M) -> MultiTierScheduler:
    lan, wan = links
    return MultiTierScheduler(
        [SchedTier("npu", dataclasses.replace(tiers[0].profile.model), None),
         SchedTier("edge", dataclasses.replace(tiers[1].profile.model),
                   TxEstimator(init_rtt_s=float(lan.rtt_at(0.0)))),
         SchedTier("cloud", dataclasses.replace(tiers[2].profile.model),
                   TxEstimator(init_rtt_s=float(wan.rtt_at(0.0))))],
        dataclasses.replace(n2m))


def _simulate_static(tier: SimTier, stream, seed: int):
    """True single-tier baseline: the topology contains ONLY tier k (its
    queue unbounded, as a pure static policy queues everything), so no
    bounded-queue rerouting can spill traffic to other tiers."""
    solo = dataclasses.replace(tier, queue_capacity=None)
    tx = None
    if solo.link is not None:
        tx = TxEstimator(init_rtt_s=float(solo.link.rtt_at(0.0)))
    sched = MultiTierScheduler(
        [SchedTier(solo.name, dataclasses.replace(solo.profile.model), tx)],
        LinearN2M(1.0, 0.0))
    return simulate_des(sched, stream, [solo], seed=seed)


def run(n_requests: int = 20_000, rates=(5.0, 30.0, 120.0),
        refit_interval: int = 1000, verbose: bool = True):
    corpus = make_corpus("de-en", n_requests + 4000, seed=11)
    fit, eval_ = corpus.split(4000)
    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    n2m = LinearN2M().fit(nf, mf)

    csv = []
    rows = {}
    for rate in rates:
        tiers, links = _topology(seed=11)
        stream = make_poisson_stream(eval_.n, eval_.m_out, eval_.m_real,
                                     rate_hz=rate, seed=11)
        sched = _scheduler(tiers, links, n2m)
        cal = OnlineCalibrator(len(tiers), interval=refit_interval)
        res = simulate_des(sched, stream, tiers, seed=11, calibrator=cal)
        s = res.summary()
        fracs = res.tier_frac()

        # static single-tier baselines (queues still simulated!)
        static_p95 = {
            t.name: _simulate_static(t, stream, seed=11).p95_latency_s()
            for t in tiers}

        rows[rate] = {"summary": s, "tier_frac": fracs,
                      "static_p95": static_p95}
        frac_str = "|".join(f"{name}={f:.2f}" for name, f in fracs.items())
        csv.append(
            f"multitier_rate{rate:g},{s['mean_latency_s']*1e6:.1f},"
            f"p95={s['p95_latency_s']*1e3:.1f}ms|wait={s['mean_wait_s']*1e3:.1f}ms"
            f"|{frac_str}")
        if verbose:
            best_static = min(static_p95.values())
            print(f"[multitier] rate={rate:7.1f}/s  "
                  f"p95={s['p95_latency_s']*1e3:7.1f}ms  "
                  f"mean_wait={s['mean_wait_s']*1e3:6.1f}ms  "
                  f"offload {frac_str}  "
                  f"(best static p95={best_static*1e3:.1f}ms, "
                  f"refits={cal.n_refits})")
    return rows, csv


def _batched_topology(batch_size: int, seed: int):
    """2-tier NPU + batched WAN pod; the pod saturates serially at the
    upper sweep rates, so batching is the only throughput lever."""
    npu = DeviceProfile("npu", LinearLatencyModel(4e-4, 1.6e-3, 0.004), 0.05)
    pod = DeviceProfile("pod", LinearLatencyModel(2e-5, 9e-5, 0.002), 0.08)
    wan = make_profile("cp2", seed=seed)
    tiers = [
        SimTier("npu", npu, servers=1, queue_capacity=8),
        SimTier("pod", pod, servers=2, queue_capacity=256, link=wan,
                batch_size=batch_size, per_seq_overhead_s=1.5e-3),
    ]
    return tiers, wan


def _batched_scheduler(tiers, wan, n2m: LinearN2M) -> MultiTierScheduler:
    return MultiTierScheduler(
        [SchedTier("npu", dataclasses.replace(tiers[0].profile.model), None),
         SchedTier("pod", dataclasses.replace(tiers[1].profile.model),
                   TxEstimator(init_rtt_s=float(wan.rtt_at(0.0))),
                   batch_size=tiers[1].batch_size,
                   per_seq_overhead_s=tiers[1].per_seq_overhead_s)],
        dataclasses.replace(n2m))


def run_batched(n_requests: int = 20_000, rates=(700.0, 1200.0),
                batch_sizes=(1, 4, 8), slo_s: float = 0.3,
                verbose: bool = True):
    """Batch-size x Poisson-rate sweep with per-request SLO deadlines.

    Headline: at rates past the serial saturation point, larger batch
    sizes sustain higher throughput and keep SLO attainment near 1.0
    where batch_size=1 must shed heavily.
    """
    corpus = make_corpus("de-en", n_requests + 4000, seed=13)
    fit, eval_ = corpus.split(4000)
    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    n2m = LinearN2M().fit(nf, mf)

    csv = []
    rows = {}
    for rate in rates:
        for b in batch_sizes:
            tiers, wan = _batched_topology(b, seed=13)
            stream = make_poisson_stream(eval_.n, eval_.m_out, eval_.m_real,
                                         rate_hz=rate, seed=13, slo_s=slo_s)
            res = simulate_des(_batched_scheduler(tiers, wan, n2m), stream,
                               tiers, seed=13)
            s = res.summary()
            rows[(rate, b)] = s
            csv.append(
                f"multitier_batched_rate{rate:g}_b{b},"
                f"{s['mean_latency_s']*1e6:.1f},"
                f"thru={s['throughput_rps']:.0f}rps"
                f"|p95={s['p95_latency_s']*1e3:.1f}ms"
                f"|slo={s['slo_attainment']:.3f}"
                f"|shed={int(s['shed'])}")
            if verbose:
                print(f"[batched ] rate={rate:7.1f}/s  b={b:<2d} "
                      f"thru={s['throughput_rps']:7.1f}rps  "
                      f"p95={s['p95_latency_s']*1e3:7.1f}ms  "
                      f"slo={s['slo_attainment']:.3f}  "
                      f"shed={int(s['shed']):5d}  "
                      f"overflow={int(s['overflow'])}")
    return rows, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI invocation (small request counts)")
    args = ap.parse_args()
    if args.smoke:
        run(n_requests=2000, rates=(30.0, 120.0))
        run_batched(n_requests=2000, rates=(700.0,), batch_sizes=(1, 8))
    else:
        run()
        run_batched()
