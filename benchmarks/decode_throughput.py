"""Decode fast-path benchmark: compiled scan vs per-sequence host loop.

The paper's latency argument (§II-A, Fig. 2a) needs decode cost linear in
the output length M; the HOST loop (one jitted dispatch per token per
sequence) keeps that property but pays a dispatch/sync constant per
token.  The compiled path (``make_translate_batched``: encoder + KV-cache
init + the whole greedy decode in ONE ``lax.scan`` dispatch, on-device
EOS masking) removes that constant and scales across the batch.

Sweeps batch size x source length at a forced output length and reports
per cell:

* ``tok_s_host``       — generated tokens/sec, per-sequence host loop;
* ``tok_s_scan``       — generated tokens/sec, compiled batched scan;
* ``speedup``          — scan / host;
* ``p50_step_us_host`` — TRUE median over individually timed decode-step
  dispatches (one jitted step per token, the host path's unit of work);
* ``step_us_scan``     — the scan path's amortized per-token cost,
  call-time / (B*M) (individual steps are invisible inside the scan).

Results are printed, returned, emitted as ``name,us_per_call,derived``
CSV lines for the bench trajectory, and dumped as JSON (``--json`` /
``out_json=``) so CI can archive the artifact (BENCH_decode.json).

Run: PYTHONPATH=src python benchmarks/decode_throughput.py [--smoke]
     [--json BENCH_decode.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.nmt import MarianTransformer, TransformerConfig

# small-but-real Marian config: deep enough that a decode step is a real
# transformer stack, small enough that CI finishes in seconds
_CFG = dict(vocab_src=256, vocab_tgt=256, d_model=64, heads=4, d_ff=128,
            enc_layers=2, dec_layers=2, max_src_len=64)


def _make_batch(rng, batch: int, src_len: int):
    src = rng.integers(4, _CFG["vocab_src"], (batch, src_len)).astype(np.int32)
    mask = np.ones((batch, src_len), np.float32)
    return src, mask


def _time_host(translate_host, src, mask, m_out: int, reps: int):
    """Best wall-clock of the per-sequence host loop over ``reps``."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        translate_host(src, mask, forced_len=m_out)
        best = min(best, time.perf_counter() - t0)
    return best


def _host_step_p50_us(model, params, src_row, m_out: int):
    """Median latency of individual jitted decode-step dispatches on one
    sequence — the per-token unit the host loop pays M times."""
    import jax.numpy as jnp

    enc_outs, msk = model.encode(params, jnp.asarray(src_row))
    state = model.init_cache(params, enc_outs, msk)
    step = jax.jit(lambda st, tok: model.decode_step(params, st, tok))
    tok = jnp.asarray(1, jnp.int32)
    state, logits = step(state, tok)          # compile
    np.asarray(logits)
    times = []
    for _ in range(m_out):
        t0 = time.perf_counter()
        state, logits = step(state, tok)
        tok = jnp.argmax(logits).astype(jnp.int32)
        np.asarray(tok)                       # the loop's per-step sync
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def _time_scan(translate_fast, src, mask, m_out: int, reps: int):
    lens, toks = translate_fast(src, mask, forced_len=m_out)  # compile
    np.asarray(toks)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        lens, toks = translate_fast(src, mask, forced_len=m_out)
        np.asarray(toks)                     # block on the device value
        best = min(best, time.perf_counter() - t0)
    return best


def run(batches=(1, 8, 16), src_lens=(8, 32), m_out: int = 16,
        reps: int = 3, verbose: bool = True, out_json: str | None = None):
    cfg = TransformerConfig(max_decode_len=m_out + 2, **_CFG)
    model = MarianTransformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t_fast = model.make_translate_batched(params)
    t_host = model.make_translate_batched(params, compiled=False)
    rng = np.random.default_rng(0)

    rows = []
    csv = []
    for src_len in src_lens:
        for batch in batches:
            src, mask = _make_batch(rng, batch, src_len)
            # one warm call each so both paths are post-compile
            t_host(src, mask, forced_len=m_out)
            host_s = _time_host(t_host, src, mask, m_out, reps)
            scan_s = _time_scan(t_fast, src, mask, m_out, reps)
            host_step_us = _host_step_p50_us(model, params, src[0], m_out)
            n_tok = batch * m_out
            row = {
                "batch": batch,
                "src_len": src_len,
                "m_out": m_out,
                "tok_s_host": n_tok / host_s,
                "tok_s_scan": n_tok / scan_s,
                "speedup": host_s / scan_s,
                "p50_step_us_host": host_step_us,
                "step_us_scan": scan_s / n_tok * 1e6,
            }
            rows.append(row)
            csv.append(
                f"decode_b{batch}_n{src_len},{scan_s/n_tok*1e6:.1f},"
                f"tok_s={row['tok_s_scan']:.0f}|host={row['tok_s_host']:.0f}"
                f"|speedup={row['speedup']:.2f}x")
            if verbose:
                print(f"[decode] B={batch:3d} N={src_len:3d} M={m_out} "
                      f"scan {row['tok_s_scan']:8.0f} tok/s  "
                      f"host {row['tok_s_host']:8.0f} tok/s  "
                      f"speedup {row['speedup']:5.2f}x  "
                      f"scan step {row['step_us_scan']:6.1f}us  "
                      f"host p50 step {row['p50_step_us_host']:7.1f}us")

    out = {"config": _CFG, "m_out": m_out, "rows": rows,
           "max_speedup": max(r["speedup"] for r in rows),
           "best_tok_s": max(r["tok_s_scan"] for r in rows)}
    if verbose:
        print(f"[decode] best {out['best_tok_s']:.0f} tok/s, "
              f"max speedup {out['max_speedup']:.2f}x")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"[decode] wrote {out_json}")
    return out, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--json", default=None, help="dump results JSON here")
    args = ap.parse_args()
    if args.smoke:
        run(batches=(1, 8), src_lens=(8,), m_out=12, reps=2,
            out_json=args.json)
    else:
        run(out_json=args.json)
