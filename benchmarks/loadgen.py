"""MLPerf-style load-generation harness for the collaborative engine.

The paper's headline claim (up to 44% latency reduction from
collaborative placement) is only meaningful under realistic arrival
processes and mixed workloads.  This harness drives the REAL
:class:`~repro.runtime.engine.CollaborativeEngine` — its actual
routing, deadline-aware admission and virtual-time occupancy code, with
modelled tier execution so runs are fast and deterministic — under the
four arrival processes of an MLPerf-loadgen-shaped benchmark, with a
clean QSL/SUT split:

* :class:`QuerySampleLibrary` (QSL) owns the query *samples*: input and
  output lengths drawn from a :class:`WorkloadMix` — weighted length
  buckets over one language pair plus a per-mix SLO.  Two mixes ship by
  default: short chat-like ``de-en`` requests under a tight SLO and
  long ``en-zh`` document translations under a loose one.
* :class:`EngineSUT` (SUT) wraps the engine behind ``issue()`` and
  records per-request outcomes through the engine's ``on_complete``
  completion callback and per-request ``tag`` (the hooks this harness
  motivated).

Scenarios (MLPerf analogue in parentheses):

* ``poisson`` (Server)       — open-loop constant-rate Poisson;
* ``closed``  (SingleStream, generalized to C clients) — fixed
  concurrency, each client issuing its next query the moment its
  previous one completes (+ think time): the issue process is *derived*
  from completions, not generated;
* ``bursty``                 — open-loop nonhomogeneous Poisson with a
  diurnal raised-cosine rate modulation (thinning sampler);
* ``trace``  (replay)        — arrival instants read verbatim from a
  trace FILE (synthesized steady+burst here, recorded in deployment);
  the run asserts the issued times match the file bit-for-bit.

Every scenario's issue times — including the *realized* times of the
closed-loop run — are replayed through the DES twin
(:func:`~repro.core.simulator.make_trace_stream` + ``simulate_des`` on
a matched 3-tier setup), so modelled-vs-real drift is part of the
scoreboard, per scenario, in the emitted JSON.

Reports per scenario x mix: p50/p90/p95/p99 latency, SLO attainment,
throughput (requests/s and tokens/s), shed/rejected/retry counts, and
the DES-twin drift.  Emits ``BENCH_loadgen.json`` (``--json``) for the
CI bench trail — the standing scoreboard every later scaling PR must
move.

Run: PYTHONPATH=src python benchmarks/loadgen.py [--smoke]
     [--json BENCH_loadgen.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import heapq
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import (
    bursty_arrivals,
    load_trace,
    poisson_arrivals,
    save_trace,
)
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.core.scheduler import MultiTierScheduler, SchedTier
from repro.core.simulator import SimTier, make_trace_stream, simulate_des
from repro.core.tx_estimator import TxEstimator
from repro.data.synthetic import LANGUAGE_PAIRS
from repro.runtime.engine import CollaborativeEngine, Tier

_SEED = 29
SCENARIOS = ("poisson", "closed", "bursty", "trace")


# ------------------------------------------------------------------ QSL --
@dataclasses.dataclass(frozen=True)
class WorkloadMix:
    """One workload class: a language pair, weighted token-length
    buckets ``(lo, hi, weight)`` for the input length N, and the
    relative SLO (seconds) every request of this mix carries."""

    name: str
    pair: str
    buckets: Tuple[Tuple[int, int, float], ...]
    slo_s: float


MIXES: Dict[str, WorkloadMix] = {
    # short chat-like turns, tight deadline (interactive translation)
    "chat": WorkloadMix("chat", "de-en", ((2, 16, 0.7), (16, 40, 0.3)), 0.6),
    # long document translations, loose deadline (batch-ish offline work)
    "doc": WorkloadMix("doc", "en-zh", ((40, 120, 0.6), (120, 200, 0.4)),
                       3.0),
}


class QuerySampleLibrary:
    """QSL half of the MLPerf split: owns ``size`` query samples drawn
    from a :class:`WorkloadMix` — input lengths from the weighted
    buckets, output lengths from the pair's verbosity line
    ``gamma*N + delta`` plus its heteroscedastic noise (the Fig. 3
    statistics).  Deterministic given ``seed``; ``query(i)`` returns the
    token ids of sample ``i`` (values are irrelevant to latency)."""

    def __init__(self, mix: WorkloadMix, size: int, *, seed: int = _SEED):
        self.mix = mix
        lp = LANGUAGE_PAIRS[mix.pair]
        rng = np.random.default_rng(seed)
        w = np.asarray([b[2] for b in mix.buckets], np.float64)
        pick = rng.choice(len(mix.buckets), size=size, p=w / w.sum())
        lo = np.asarray([b[0] for b in mix.buckets], np.float64)[pick]
        hi = np.asarray([b[1] for b in mix.buckets], np.float64)[pick]
        self.n = np.round(lo + rng.random(size) * (hi - lo)).astype(np.int64)
        noise = lp.noise_base + lp.noise_slope * self.n
        m = lp.gamma * self.n + lp.delta + rng.standard_normal(size) * noise
        self.m_out = np.clip(np.round(m), 1, lp.max_len)

    def __len__(self) -> int:
        return int(self.n.size)

    def query(self, i: int) -> np.ndarray:
        return np.zeros(int(self.n[i]), np.int32)


# ------------------------------------------------------------------ SUT --
def _profiles(seed: int = 5):
    """The 3-tier npu/edge/cloud shape shared with the multitier and
    fault benchmarks: local npu, edge over a LAN trace, cloud over a
    WAN trace."""
    npu = DeviceProfile("npu", LinearLatencyModel(4e-4, 1.6e-3, 0.004), 0.05)
    edge = DeviceProfile("edge", LinearLatencyModel(1.5e-4, 6e-4, 0.008),
                         0.05)
    cloud = DeviceProfile("cloud", LinearLatencyModel(2e-5, 9e-5, 0.002),
                          0.08)
    lan, wan = make_profile("cp2", seed=seed), make_profile("cp1", seed=seed)
    return npu, edge, cloud, lan, wan


def _make_engine(mix: WorkloadMix, *, seed: int = _SEED) -> CollaborativeEngine:
    npu, edge, cloud, lan, wan = _profiles()
    lp = LANGUAGE_PAIRS[mix.pair]
    tiers = [
        Tier(npu, servers=1, queue_capacity=16),
        Tier(edge, servers=2, queue_capacity=64, rtt_fn=lan.rtt_at,
             bandwidth_bps=lan.bandwidth_bps),
        Tier(cloud, servers=8, rtt_fn=wan.rtt_at,
             bandwidth_bps=wan.bandwidth_bps),
    ]
    return CollaborativeEngine(n2m=LinearN2M(lp.gamma, lp.delta),
                               tiers=tiers, seed=seed)


def _des_setup(mix: WorkloadMix):
    """DES twin of :func:`_make_engine`: same planes, links, capacities
    and N->M regressor, expressed as SimTiers + MultiTierScheduler."""
    npu, edge, cloud, lan, wan = _profiles()
    lp = LANGUAGE_PAIRS[mix.pair]
    tiers = [SimTier("npu", npu, servers=1, queue_capacity=16),
             SimTier("edge", edge, servers=2, queue_capacity=64, link=lan),
             SimTier("cloud", cloud, servers=8, link=wan)]
    sched = MultiTierScheduler(
        [SchedTier("npu", dataclasses.replace(npu.model), None),
         SchedTier("edge", dataclasses.replace(edge.model),
                   TxEstimator(init_rtt_s=float(lan.rtt_at(0.0)),
                               bandwidth_bps=lan.bandwidth_bps)),
         SchedTier("cloud", dataclasses.replace(cloud.model),
                   TxEstimator(init_rtt_s=float(wan.rtt_at(0.0)),
                               bandwidth_bps=wan.bandwidth_bps))],
        LinearN2M(lp.gamma, lp.delta))
    return sched, tiers


class EngineSUT:
    """SUT half of the MLPerf split: the real CollaborativeEngine behind
    ``issue()``.  Per-request outcomes are recorded through the engine's
    ``on_complete`` completion callback (never by scraping
    ``engine.results``), each record carrying the issue/finish instants
    the closed-loop driver and the concurrency-invariant test need."""

    def __init__(self, mix: WorkloadMix, *, seed: int = _SEED):
        self.engine = _make_engine(mix, seed=seed)
        self.records: List[dict] = []
        self._issue_s = 0.0
        self.engine.on_complete = self._on_complete

    def _on_complete(self, res) -> None:
        t = self._issue_s
        self.records.append({
            "tag": res.tag,
            "issue_s": t,
            "finish_s": float("nan") if res.shed else t + res.latency_s,
            "latency_s": res.latency_s,
            "shed": bool(res.shed),
            "slo_met": res.slo_met,
            "n": int(res.n),
            "m_out": int(res.m_out),
            "tier": res.tier_name,
            "retry_after_s": res.retry_after_s,
        })

    def issue(self, t: float, tokens: np.ndarray,
              deadline_s: Optional[float], tag: str):
        self._issue_s = float(t)
        return self.engine.submit(tokens, now_s=float(t),
                                  deadline_s=deadline_s, tag=tag)


# ------------------------------------------------------------ scenarios --
def run_open_loop(sut: EngineSUT, qsl: QuerySampleLibrary,
                  arrivals: np.ndarray, *, tag: str) -> np.ndarray:
    """Open-loop driver shared by poisson/bursty/trace: issue sample i
    at ``arrivals[i]`` (virtual seconds) regardless of completions."""
    slo = qsl.mix.slo_s
    for i, t in enumerate(arrivals):
        sut.issue(float(t), qsl.query(i), slo, tag)
    return np.asarray(arrivals, np.float64)


def run_closed_loop(sut: EngineSUT, qsl: QuerySampleLibrary, *,
                    concurrency: int, think_s: float = 0.01,
                    tag: str) -> np.ndarray:
    """Fixed-concurrency closed loop: ``concurrency`` clients, each
    issuing its next query at its previous completion + ``think_s`` (a
    shed response waits out its ``retry_after_s`` backpressure hint
    first).  At most ``concurrency`` requests are ever in flight — the
    invariant the tests pin.  Returns the realized issue times (the
    trace the DES twin replays)."""
    slo = qsl.mix.slo_s
    # microsecond stagger so client start order is well-defined
    heap = [(c * 1e-6, c) for c in range(concurrency)]
    heapq.heapify(heap)
    issued = np.empty(len(qsl), np.float64)
    for i in range(len(qsl)):
        t, c = heapq.heappop(heap)
        res = sut.issue(t, qsl.query(i), slo, tag)
        issued[i] = t
        if res.shed:
            nxt = t + think_s + float(res.retry_after_s or 0.0)
        else:
            nxt = t + float(res.latency_s) + think_s
        heapq.heappush(heap, (nxt, c))
    return issued


def _trace_arrivals(size: int, rate_hz: float,
                    path: Optional[str]) -> Tuple[np.ndarray, str, bool]:
    """Synthesize a "recorded" trace — a steady phase followed by a 3x
    burst — persist it, and load it back: the replay consumes the FILE,
    so the save/load round-trip is part of the scenario.  Returns
    (arrivals, path, owns_path)."""
    half = size // 2
    a = poisson_arrivals(rate_hz, half, seed=_SEED + 17)
    t0 = float(a[-1]) if half else 0.0
    b = poisson_arrivals(3.0 * rate_hz, size - half, seed=_SEED + 18, t0=t0)
    arr = np.concatenate([a, b])
    own = path is None
    if own:
        fd, path = tempfile.mkstemp(suffix=".json", prefix="loadgen_trace_")
        os.close(fd)
    save_trace(path, arr, meta={"rate_hz": rate_hz, "burst_factor": 3.0})
    return load_trace(path), path, own


# ------------------------------------------------------------ reporting --
def max_in_flight(records: Sequence[dict]) -> int:
    """Peak number of simultaneously in-flight served requests (a
    request is in flight on [issue_s, finish_s); shed requests never
    occupy the system).  The closed-loop invariant: <= concurrency."""
    ev: List[Tuple[float, int]] = []
    for r in records:
        if r["shed"]:
            continue
        ev.append((r["issue_s"], 1))
        ev.append((r["finish_s"], -1))
    ev.sort(key=lambda e: (e[0], e[1]))   # finish before issue at ties
    peak = cur = 0
    for _, d in ev:
        cur += d
        peak = max(peak, cur)
    return peak


def _summarize(records: Sequence[dict],
               engine: CollaborativeEngine) -> Dict[str, float]:
    """Per-scenario scoreboard row from the SUT's completion records."""
    served = [r for r in records if not r["shed"]]
    with_dl = [r for r in records if r["slo_met"] is not None]
    out: Dict[str, float] = {
        "requests": float(len(records)),
        "served": float(len(served)),
        "shed": float(len(records) - len(served)),
        "rejected": float(engine.rejected.sum()),
        "retries": float(engine.retry_count),
        "slo_attainment": (sum(bool(r["slo_met"]) for r in with_dl)
                           / len(with_dl)) if with_dl else 1.0,
    }
    if not served:
        for k in ("mean_latency_s", "p50_latency_s", "p90_latency_s",
                  "p95_latency_s", "p99_latency_s", "throughput_rps",
                  "tokens_per_s"):
            out[k] = float("nan")
        return out
    lat = np.array([r["latency_s"] for r in served])
    fin = np.array([r["finish_s"] for r in served])
    span = max(float(fin.max()) - min(r["issue_s"] for r in records), 1e-9)
    out.update({
        "mean_latency_s": float(lat.mean()),
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p90_latency_s": float(np.percentile(lat, 90)),
        "p95_latency_s": float(np.percentile(lat, 95)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "throughput_rps": len(served) / span,
        "tokens_per_s": float(sum(r["n"] + r["m_out"]
                                  for r in served)) / span,
    })
    return out


def _des_twin(mix: WorkloadMix, issued: np.ndarray,
              qsl: QuerySampleLibrary) -> Dict[str, float]:
    """Replay the SAME issue times through the matched DES."""
    sched, tiers = _des_setup(mix)
    stream = make_trace_stream(issued, qsl.n.astype(np.float64),
                               qsl.m_out, slo_s=mix.slo_s)
    return simulate_des(sched, stream, tiers, seed=_SEED).summary()


def _drift(real: Dict[str, float],
           twin: Dict[str, float]) -> Dict[str, float]:
    """Relative modelled-vs-real drift, (real - modelled) / modelled,
    for the latency keys both sides report.  Reported, not gated: the
    engine and the DES are different queueing models of the same fleet,
    and the scoreboard tracks how far apart they sit per scenario."""
    out = {}
    for k in ("mean_latency_s", "p50_latency_s", "p95_latency_s"):
        t, r = twin.get(k), real.get(k)
        if t and np.isfinite(t) and r is not None and np.isfinite(r):
            out[k] = (r - t) / t
    return out


# ------------------------------------------------------------------ run --
def run(n_requests: int = 2000, rate_hz: float = 10.0,
        concurrency: int = 8, think_s: float = 0.01,
        verbose: bool = True, check: bool = True,
        out_json: Optional[str] = None,
        mixes: Sequence[str] = ("chat", "doc"),
        scenarios: Sequence[str] = SCENARIOS,
        trace_path: Optional[str] = None):
    """Full scenario x mix sweep against the real engine + DES twin.

    Returns ``(rows, csv)``; ``rows[(scenario, mix)]`` holds the engine
    summary, the DES-twin summary and the drift between them.  With
    ``check=True`` the run raises unless every scenario served requests,
    the trace replay issued EXACTLY the file's arrival times, and the
    closed loop never exceeded its concurrency.
    """
    rows: Dict[Tuple[str, str], Dict] = {}
    csv: List[str] = []
    for mix_name in mixes:
        mix = MIXES[mix_name]
        for scenario in scenarios:
            qsl = QuerySampleLibrary(mix, n_requests)
            sut = EngineSUT(mix)
            tag = f"{scenario}/{mix_name}"
            if scenario == "poisson":
                arr = poisson_arrivals(rate_hz, n_requests, seed=_SEED + 11)
                issued = run_open_loop(sut, qsl, arr, tag=tag)
            elif scenario == "bursty":
                arr = bursty_arrivals(
                    n_requests, base_rate_hz=0.5 * rate_hz, peak_factor=4.0,
                    period_s=max(n_requests / rate_hz / 2.0, 30.0),
                    seed=_SEED + 13)
                issued = run_open_loop(sut, qsl, arr, tag=tag)
            elif scenario == "trace":
                arr, path, own = _trace_arrivals(n_requests, rate_hz,
                                                 trace_path)
                issued = run_open_loop(sut, qsl, arr, tag=tag)
                if check and not np.array_equal(issued, load_trace(path)):
                    raise AssertionError(
                        "[loadgen] trace replay: issued times deviate "
                        "from the trace file")
                if own:
                    os.unlink(path)
            elif scenario == "closed":
                issued = run_closed_loop(sut, qsl, concurrency=concurrency,
                                         think_s=think_s, tag=tag)
                peak = max_in_flight(sut.records)
                if check and peak > concurrency:
                    raise AssertionError(
                        f"[loadgen] closed loop exceeded its concurrency: "
                        f"{peak} > {concurrency}")
            else:
                raise ValueError(f"unknown scenario {scenario!r}")

            real = _summarize(sut.records, sut.engine)
            twin = _des_twin(mix, issued, qsl)
            drift = _drift(real, twin)
            if check and real["served"] == 0:
                raise AssertionError(
                    f"[loadgen] {tag}: no request was served")
            rows[(scenario, mix_name)] = {"engine": real, "des_twin": twin,
                                          "drift": drift}
            csv.append(f"loadgen_{scenario}_{mix_name},"
                       f"{real['mean_latency_s'] * 1e6:.1f},"
                       f"p95={real['p95_latency_s'] * 1e3:.1f}ms"
                       f"|slo={real['slo_attainment']:.3f}"
                       f"|thr={real['throughput_rps']:.1f}rps"
                       f"|shed={int(real['shed'])}")
            if verbose:
                d95 = drift.get("p95_latency_s", float("nan"))
                print(f"[loadgen] {tag:14s} p50={real['p50_latency_s']*1e3:7.1f}ms "
                      f"p95={real['p95_latency_s']*1e3:7.1f}ms "
                      f"p99={real['p99_latency_s']*1e3:7.1f}ms "
                      f"slo={real['slo_attainment']:.3f} "
                      f"thr={real['throughput_rps']:6.1f}rps "
                      f"shed={int(real['shed']):4d} "
                      f"des-drift(p95)={d95:+.2%}")

    if out_json:
        payload = {
            "setup": {"n_requests": n_requests, "rate_hz": rate_hz,
                      "concurrency": concurrency, "think_s": think_s,
                      "seed": _SEED, "mixes": list(mixes),
                      "scenarios": list(scenarios)},
            "scenarios": [{"scenario": s, "mix": m,
                           "slo_s": MIXES[m].slo_s, **row}
                          for (s, m), row in rows.items()],
        }
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        if verbose:
            print(f"[loadgen] wrote {out_json}")
    return rows, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI invocation (small request counts)")
    ap.add_argument("--json", default=None, help="dump results JSON here")
    args = ap.parse_args()
    smoke = args.smoke or bool(int(os.environ.get("REPRO_SMOKE", "0")))
    if smoke:
        run(n_requests=150, out_json=args.json)
    else:
        run(out_json=args.json)
