"""Benchmark driver — one function per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV lines at the end.

  table1     — paper Table I (the headline result)
  fig2a      — T_exe linearity in M (measured on real JAX models)
  fig3       — N->M regression quality per language pair
  predictors — beyond-paper estimator ablation (paper's future work)
  tiered     — beyond-paper: roofline-priced TPU tiers under C-NMT
  multitier  — beyond-paper: 3-tier queue-aware DES under Poisson load,
               plus a batch-size x rate sweep with SLO-deadline shedding
  decode     — compiled-scan batched decode vs per-sequence host loop
               (tokens/sec + p50 step latency, batch x src_len sweep)
  continuous — continuous in-flight batching vs block-to-completion
               (DES rate x slots sweep + real slot-table execution)
  partition  — encoder/decoder split placement vs whole-request offload
               (backbone bandwidth x length sweep + two-leg DES replay)
  faults     — fault-tolerant serving: injected tier outages / link
               blackholes, no-retry baseline vs breaker-masked failover
  loadgen    — MLPerf-style load generation against the real engine:
               Poisson / closed-loop / bursty / trace-replay arrivals
               over mixed workloads, with a DES-twin drift report
  bigmodel   — Fig. 2a/Table 1 re-run on the big models/model.py stack:
               per-architecture latency planes + N->M regressors
               consumed by MultiTierScheduler, plus the chunked-vs-
               stepwise mixer-kernel gate (hard-fails on regression)
  roofline   — aggregated dry-run roofline table (if records exist)

Fast mode (REPRO_BENCH_FAST=1): fewer requests per simulation — used by
CI; the defaults reproduce the paper's 100k-request setting.
"""

from __future__ import annotations

import os
import sys
import time


def main() -> None:
    fast = bool(int(os.environ.get("REPRO_BENCH_FAST", "0")))
    n_req = 20_000 if fast else 100_000
    csv_all = []
    t0 = time.time()

    from benchmarks import fig3
    _, csv = fig3.run(size=20_000 if fast else 50_000)
    csv_all += csv

    from benchmarks import fig2a
    _, csv = fig2a.run()
    csv_all += csv

    from benchmarks import table1
    _, csv = table1.run(n_requests=n_req)
    csv_all += csv

    from benchmarks import predictors
    _, csv = predictors.run(n_requests=min(n_req, 50_000))
    csv_all += csv

    from benchmarks import tiered
    _, csv = tiered.run(n_requests=min(n_req, 50_000))
    csv_all += csv

    from benchmarks import multitier
    _, csv = multitier.run(n_requests=min(n_req, 20_000))
    csv_all += csv
    _, csv = multitier.run_batched(n_requests=min(n_req, 20_000))
    csv_all += csv

    from benchmarks import continuous_batching
    if fast:
        _, csv = continuous_batching.run(
            n_requests=3000, rates=(30.0, 100.0), slot_counts=(8,),
            out_json="BENCH_continuous.json")
    else:
        _, csv = continuous_batching.run(out_json="BENCH_continuous.json")
    csv_all += csv

    from benchmarks import decode_throughput
    if fast:
        _, csv = decode_throughput.run(batches=(1, 8), src_lens=(8,),
                                       m_out=12, reps=2,
                                       out_json="BENCH_decode.json")
    else:
        _, csv = decode_throughput.run(out_json="BENCH_decode.json")
    csv_all += csv

    from benchmarks import partitioned
    if fast:
        _, csv = partitioned.run(backbone_bps=(1e6, 1e8),
                                 src_lens=(16, 128), n_requests=500,
                                 out_json="BENCH_partition.json")
    else:
        _, csv = partitioned.run(out_json="BENCH_partition.json")
    csv_all += csv

    from benchmarks import fault_tolerance
    if fast:
        _, csv = fault_tolerance.run(n_requests=4000,
                                     out_json="BENCH_faults.json")
    else:
        _, csv = fault_tolerance.run(out_json="BENCH_faults.json")
    csv_all += csv

    from benchmarks import loadgen
    if fast:
        _, csv = loadgen.run(n_requests=300, out_json="BENCH_loadgen.json")
    else:
        _, csv = loadgen.run(out_json="BENCH_loadgen.json")
    csv_all += csv

    from benchmarks import bigmodel
    if fast:
        _, csv = bigmodel.run(n_grid=(8, 16), m_grid=(8, 16), reps=2,
                              n2m_samples=500, gate_seq=64,
                              out_json="BENCH_bigmodel.json")
    else:
        _, csv = bigmodel.run(out_json="BENCH_bigmodel.json")
    csv_all += csv

    from benchmarks import roofline
    recs, csv = roofline.run()
    if recs:
        csv_all += csv

    print(f"\n[bench] total wall time {time.time()-t0:.1f}s")
    print("\nname,us_per_call,derived")
    for line in csv_all:
        print(line)


if __name__ == "__main__":
    main()
