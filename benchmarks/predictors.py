"""Beyond-paper ablation: output-length estimator quality vs C-NMT gains.

The paper's conclusion names "more advanced output length estimation
methods" as future work.  This benchmark swaps the estimator inside the
same CI decision rule and measures total execution time on the same
request stream: corpus mean (=the paper's Naive), the paper's linear
fit, Huber-robust fit (no pre-filter needed), and per-bucket conditional
median / 0.75-quantile (hedging against under-prediction).
"""

from __future__ import annotations

import numpy as np

from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import (
    BucketN2M,
    HuberN2M,
    LinearN2M,
    MeanN2M,
    prefilter_pairs,
)
from repro.core.profiles import make_profile
from repro.core.scheduler import CNMTScheduler, OracleScheduler, StaticScheduler, EDGE, CLOUD
from repro.core.simulator import make_stream, simulate
from repro.data.synthetic import make_corpus


def run(n_requests: int = 50_000, verbose: bool = True):
    corpus = make_corpus("en-zh", n_requests + 10_000, seed=11,
                         model_len_noise=2.5)
    fit, eval_ = corpus.split(10_000)
    edge = DeviceProfile("edge", LinearLatencyModel(5e-4, 9e-3, 0.01), 0.05)
    cloud = DeviceProfile("cloud", edge.model.scaled(5.0), 0.08)
    profile = make_profile("cp1", seed=11)
    stream = make_stream(eval_.n, eval_.m_out, eval_.m_real,
                         duration_s=profile.times_s[-1], seed=11)

    nf, mf = prefilter_pairs(fit.n, fit.m_real)
    estimators = {
        "mean(naive)": MeanN2M().fit(nf, mf),
        "linear(paper)": LinearN2M().fit(nf, mf),
        "huber-nofilter": HuberN2M().fit(fit.n, fit.m_real),  # raw corpus!
        "bucket-median": BucketN2M(quantile=0.5).fit(nf, mf),
        "bucket-q75": BucketN2M(quantile=0.75).fit(nf, mf),
    }

    oracle = simulate(OracleScheduler(), stream, profile, edge, cloud, seed=1)
    gw = simulate(StaticScheduler(EDGE), stream, profile, edge, cloud, seed=1)
    sv = simulate(StaticScheduler(CLOUD), stream, profile, edge, cloud, seed=1)
    out, csv = {}, []
    for name, est in estimators.items():
        sched = CNMTScheduler(edge=edge, cloud=cloud, n2m=est)
        r = simulate(sched, stream, profile, edge, cloud, seed=1)
        vs_oracle = r.vs(oracle)
        out[name] = {"total_s": r.total_s, "vs_oracle": vs_oracle,
                     "offload": r.offload_frac}
        csv.append(f"predictors_{name},{r.total_s*1e6/n_requests:.1f},"
                   f"vs_oracle={vs_oracle:+.2f}%")
        if verbose:
            print(f"[predictors] {name:15s}: total={r.total_s:9.1f}s "
                  f"vs_oracle={vs_oracle:+6.2f}% offload={r.offload_frac:.2f}")
    if verbose:
        print(f"[predictors] statics: gw={gw.total_s:.1f}s sv={sv.total_s:.1f}s "
              f"oracle={oracle.total_s:.1f}s")
    return out, csv


if __name__ == "__main__":
    run()
