"""Partitioned (encoder/decoder split) placement vs whole-request offload.

PR 7's ``PlacementPlan`` lets the scheduler put the encoder and the
decoder of one request on *different* tiers, shipping the encoder
states (n x d_model activations) over the inter-tier link instead of
bouncing the whole request off a single tier.  The classic regime where
this wins: the cloud decodes an order of magnitude faster but sits
behind a slow client link, while a nearby edge box encodes cheaply —
encode at the edge, ship states over the fat edge->cloud backbone,
decode in the cloud, return tokens over the cloud downlink only once.

Two sections:

* ``run_analytic`` — the headline sweep: backbone bandwidth x source
  length, zero queues.  Every plan (3 whole placements + all ordered
  splits) is priced with the scheduler's own ``plan_cost_fast`` and the
  best split is compared against the best whole placement.  The split
  must STRICTLY beat every whole placement in at least one swept cell
  (hard failure otherwise — the PR 7 acceptance bar) and must lose when
  the backbone is throttled to ~1 Mbps (activation shipping has to pay
  for itself, otherwise the cost model is broken).
* ``run_des`` — the winning analytic cell replayed on the two-leg DES
  (encode station -> transfer event -> decode station) under light
  Poisson load with noisy ground truth: the same stream served with
  splits disabled and enabled; enabled must actually split and must
  strictly improve mean latency.

Emits ``BENCH_partition.json`` (``--json``) for the CI artifact trail.

Run: PYTHONPATH=src python benchmarks/partitioned.py [--smoke]
     [--json BENCH_partition.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.latency_model import (ActivationCostModel, DeviceProfile,
                                      LinearLatencyModel)
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import ConnectionProfile
from repro.core.scheduler import MultiTierScheduler, PlacementPlan, SchedTier
from repro.core.simulator import RequestStream, SimTier, simulate_des
from repro.core.tx_estimator import LinkModel, TxEstimator

_SEED = 23
_D_MODEL = 512
_DTYPE_BYTES = 4

# device: fast to reach (local), slow to compute; edge: cheap encoder,
# mediocre decoder, good client link; cloud: very fast decoder behind a
# slow client link.  Encode cost ~alpha_n, decode cost ~alpha_m (paper
# SS II-A linearity), beta split evenly across the legs.
_DEV = LinearLatencyModel(3e-4, 5e-3, 2e-3)
_EDGE = LinearLatencyModel(2e-5, 2.5e-3, 4e-3)
_CLOUD = LinearLatencyModel(1e-5, 1e-4, 2e-3)
_EDGE_RTT, _EDGE_BW = 5e-3, 200e6
_CLOUD_RTT, _CLOUD_BW = 90e-3, 20e6
_BACKBONE_RTT = 4e-3


def _build_scheduler(backbone_bps: float, *,
                     allow_split: bool = True) -> MultiTierScheduler:
    tiers = [
        SchedTier("dev", LinearLatencyModel(*_as_tuple(_DEV)), None),
        SchedTier("edge", LinearLatencyModel(*_as_tuple(_EDGE)),
                  TxEstimator(init_rtt_s=_EDGE_RTT, bandwidth_bps=_EDGE_BW)),
        SchedTier("cloud", LinearLatencyModel(*_as_tuple(_CLOUD)),
                  TxEstimator(init_rtt_s=_CLOUD_RTT,
                              bandwidth_bps=_CLOUD_BW)),
    ]
    links = LinkModel(3)
    links.add_link(1, 2, TxEstimator(init_rtt_s=_BACKBONE_RTT,
                                     bandwidth_bps=backbone_bps))
    n2m = LinearN2M().fit(np.arange(1.0, 400.0), np.arange(1.0, 400.0))
    return MultiTierScheduler(
        tiers, n2m, links=links,
        activation=ActivationCostModel(_D_MODEL, _DTYPE_BYTES),
        allow_split=allow_split)


def _as_tuple(m: LinearLatencyModel):
    return (m.alpha_n, m.alpha_m, m.beta)


def _const_profile(name: str, rtt_s: float,
                   bandwidth_bps: float) -> ConnectionProfile:
    times = np.array([0.0, 3600.0])
    return ConnectionProfile(name=name, times_s=times,
                             rtt_s=np.array([rtt_s, rtt_s]),
                             bandwidth_bps=bandwidth_bps)


def run_analytic(backbone_bps=(1e6, 1e7, 1e8, 1e9),
                 src_lens=(8, 32, 128, 256), verbose: bool = True,
                 check: bool = True):
    """Zero-queue plan costs over a backbone-bandwidth x length grid.

    Returns ``(rows, csv)``; ``rows[(bps, n)]`` holds the best whole /
    best split plan costs and the chosen plan.  With ``check=True`` the
    sweep must contain at least one cell where a split STRICTLY beats
    every whole placement, and no split win at the slowest backbone.
    """
    rows = {}
    csv = []
    zero_q = [0.0, 0.0, 0.0]
    plans_split = [PlacementPlan.split(e, d)
                   for e in range(3) for d in range(3) if e != d]
    for bps in backbone_bps:
        sched = _build_scheduler(bps)
        for n in src_lens:
            m_hat = float(np.asarray(sched.n2m.predict(float(n))))
            whole = {k: sched.plan_cost_fast(PlacementPlan.whole(k),
                                             float(n), m_hat, 0.0, zero_q)
                     for k in range(3)}
            split = {p: sched.plan_cost_fast(p, float(n), m_hat, 0.0, zero_q)
                     for p in plans_split}
            best_whole_k = min(whole, key=whole.get)
            best_split_p = min(split, key=split.get)
            bw_t, bs_t = whole[best_whole_k], split[best_split_p]
            rows[(bps, n)] = {
                "best_whole_tier": best_whole_k,
                "best_whole_s": bw_t,
                "best_split": (best_split_p.encode_tier,
                               best_split_p.decode_tier),
                "best_split_s": bs_t,
                "split_wins": bool(bs_t < bw_t),
                "speedup": bw_t / bs_t if bs_t > 0 else float("inf"),
            }
            csv.append(f"partition_bw{bps:.0e}_n{n},"
                       f"{min(bw_t, bs_t)*1e6:.1f},"
                       f"whole={bw_t*1e3:.1f}ms|split={bs_t*1e3:.1f}ms"
                       f"|{'SPLIT' if bs_t < bw_t else 'WHOLE'}")
            if verbose:
                print(f"[partition] bw={bps:8.0e}bps n={n:4d} "
                      f"whole[{best_whole_k}]={bw_t*1e3:8.2f}ms "
                      f"split{rows[(bps, n)]['best_split']}="
                      f"{bs_t*1e3:8.2f}ms "
                      f"{'SPLIT WINS' if bs_t < bw_t else ''}")
    wins = [(bps, n) for (bps, n), r in rows.items() if r["split_wins"]]
    slowest = min(backbone_bps)
    slow_wins = [c for c in wins if c[0] == slowest]
    if check:
        if not wins:
            raise AssertionError(
                "[partition] no swept regime where a split placement "
                "strictly beats the best whole placement — the "
                "PlacementPlan cost model is not paying off")
        if slow_wins:
            raise AssertionError(
                f"[partition] split 'wins' at a {slowest:.0e} bps backbone "
                "— activation shipping is not being priced")
    if verbose:
        print(f"[partition] split wins in {len(wins)}/{len(rows)} cells")
    return rows, csv


def run_des(backbone_bps: float, n_src: int, n_requests: int = 2000,
            rate_hz: float = 5.0, verbose: bool = True, check: bool = True):
    """Replay the winning analytic cell on the two-leg DES.

    The same stream is served split-disabled and split-enabled; enabled
    must actually produce splits and strictly improve mean latency.
    """
    rng = np.random.default_rng(_SEED)
    arr = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    ns = rng.integers(max(n_src // 2, 4), n_src + n_src // 2,
                      n_requests).astype(np.float64)
    stream = RequestStream(t_arrival_s=arr, n=ns, m_out=ns.copy(),
                           m_real=ns.copy())

    def tiers():
        return [
            SimTier("dev", DeviceProfile("dev", LinearLatencyModel(
                *_as_tuple(_DEV)), 0.05)),
            SimTier("edge", DeviceProfile("edge", LinearLatencyModel(
                *_as_tuple(_EDGE)), 0.05),
                link=_const_profile("edge-up", _EDGE_RTT, _EDGE_BW)),
            SimTier("cloud", DeviceProfile("cloud", LinearLatencyModel(
                *_as_tuple(_CLOUD)), 0.05),
                link=_const_profile("cloud-up", _CLOUD_RTT, _CLOUD_BW)),
        ]

    inter = {(1, 2): _const_profile("backbone", _BACKBONE_RTT, backbone_bps)}
    base = simulate_des(_build_scheduler(backbone_bps, allow_split=False),
                        stream, tiers(), seed=_SEED)
    part = simulate_des(_build_scheduler(backbone_bps), stream, tiers(),
                        seed=_SEED, inter_links=inter, collect_events=True)
    n_split = sum(1 for e in part.events if e[1] == "xfer")
    rows = {
        "whole_mean_latency_s": float(np.nanmean(base.latency_s)),
        "whole_p95_latency_s": base.p95_latency_s(),
        "split_mean_latency_s": float(np.nanmean(part.latency_s)),
        "split_p95_latency_s": part.p95_latency_s(),
        "n_split": int(n_split),
        "n_requests": int(n_requests),
    }
    ok = (n_split > 0
          and rows["split_mean_latency_s"] < rows["whole_mean_latency_s"])
    msg = (f"[partition] DES bw={backbone_bps:.0e} n~{n_src}: "
           f"whole mean={rows['whole_mean_latency_s']*1e3:.1f}ms -> "
           f"split mean={rows['split_mean_latency_s']*1e3:.1f}ms "
           f"({n_split}/{n_requests} split)  "
           f"{'WIN' if ok else 'REGRESSION'}")
    if verbose:
        print(msg)
    if check and not ok:
        raise AssertionError(msg)
    csv = [f"partition_des_whole,{rows['whole_mean_latency_s']*1e6:.1f},"
           f"p95={rows['whole_p95_latency_s']*1e3:.1f}ms",
           f"partition_des_split,{rows['split_mean_latency_s']*1e6:.1f},"
           f"p95={rows['split_p95_latency_s']*1e3:.1f}ms"
           f"|splits={n_split}"]
    return rows, csv


def run(backbone_bps=(1e6, 1e7, 1e8, 1e9), src_lens=(8, 32, 128, 256),
        n_requests: int = 2000, verbose: bool = True,
        out_json: str | None = None):
    analytic, csv = run_analytic(backbone_bps=backbone_bps,
                                 src_lens=src_lens, verbose=verbose)
    # replay the widest-margin winning cell on the DES
    win_cell = max((c for c, r in analytic.items() if r["split_wins"]),
                   key=lambda c: analytic[c]["speedup"])
    des, des_csv = run_des(win_cell[0], win_cell[1], n_requests=n_requests,
                           verbose=verbose)
    csv = csv + des_csv

    if out_json:
        payload = {
            "d_model": _D_MODEL,
            "dtype_bytes": _DTYPE_BYTES,
            "analytic": [{"backbone_bps": bps, "n": n, **row}
                         for (bps, n), row in analytic.items()],
            "des_cell": {"backbone_bps": win_cell[0], "n": win_cell[1]},
            "des": des,
        }
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        if verbose:
            print(f"[partition] wrote {out_json}")
    return {"analytic": analytic, "des": des}, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI invocation (small sweep + stream)")
    ap.add_argument("--json", default=None, help="dump results JSON here")
    args = ap.parse_args()
    if args.smoke:
        run(backbone_bps=(1e6, 1e8), src_lens=(16, 128), n_requests=500,
            out_json=args.json)
    else:
        run(out_json=args.json)
