"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid (batch*head, chunk); carry = the (P x N) SSM state in VMEM scratch.
Per chunk with L-row tiles (x (L,P), b/c (L,N), dt/log-decay (L,1)):

    cum     = prefix-sum log decay                      (L,1) per-head scalar
    CB      = c @ b^T, masked lower-triangular, * e^{cum_t-cum_j} * dt_j
    y       = CB @ x  +  (c * e^{cum}) @ S
    S       = e^{cum_L} S + (b * dt * e^{cum_L - cum})^T @ x

Mamba2's scalar-per-head decay factorizes through the (L,L) score matrix
directly (unlike RWKV6's per-channel decay) so the mask/decay is an
elementwise multiply on the MXU matmul output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, ld_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)       # (L,P)
    bb = b_ref[0].astype(jnp.float32)      # (L,N)
    cc = c_ref[0].astype(jnp.float32)      # (L,N)
    dt = dt_ref[0].astype(jnp.float32)     # (L,1)
    ld = ld_ref[0].astype(jnp.float32)     # (L,1) <= 0

    l = x.shape[0]
    cum = jnp.cumsum(ld, axis=0)           # (L,1)
    cb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L,L)
    seg = cum - cum.reshape(1, l)          # seg[t,j] = cum_t - cum_j
    ti = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    scores = jnp.where(tj <= ti, cb * jnp.exp(seg), 0.0) * dt.reshape(1, l)

    s_prev = s_scr[...]                    # (N,P) state (key-major)
    y = (jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(cc * jnp.exp(cum), s_prev,
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))

    wj = jnp.exp(cum[-1:] - cum) * dt      # (L,1)
    inc = jax.lax.dot_general(bb * wj, x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N,P)
    s_scr[...] = s_prev * jnp.exp(cum[-1, 0]) + inc

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit():
        sT_ref[0] = s_scr[...].astype(sT_ref.dtype)


def ssd_scan(x, dt, a_log, b_in, c_in, s0=None, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    """x (B,S,H,P); dt (B,S,H) post-softplus; a_log (H,); b/c (B,S,H,N).

    Returns (y (B,S,H,P), s_final (B,H,P,N) f32) matching
    ``repro.kernels.ref.ssd_ref``.
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,)
    log_decay = dt.astype(jnp.float32) * a[None, None, :]   # (B,S,H)

    def to_bh(t, d_last):
        return t.transpose(0, 2, 1, 3).reshape(bsz * h, s, d_last)

    xx = to_bh(x, p)
    bb = to_bh(b_in, n)
    cc = to_bh(c_in, n)
    dd = dt.astype(jnp.float32).transpose(0, 2, 1).reshape(bsz * h, s, 1)
    ll = log_decay.transpose(0, 2, 1).reshape(bsz * h, s, 1)
    if s0 is None:
        s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    # kernel state is key-major (N,P)
    ss = s0.transpose(0, 1, 3, 2).reshape(bsz * h, n, p)

    grid = (bsz * h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, s_t = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, n, p), lambda g, ci: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, n, p), lambda g, ci: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xx, bb, cc, dd, ll, ss)

    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    s_t = s_t.reshape(bsz, h, n, p).transpose(0, 1, 3, 2)   # back to (P,N)
    return y, s_t
