"""Pallas TPU flash-decode: one query token vs a long KV cache.

Decode attention is HBM-bandwidth-bound: per generated token the whole
cache (B x S x Hkv x D) streams through once.  The kernel tiles the cache
sequence dim into BLOCK_S VMEM tiles, one grid cell per (batch*kv_head,
s_block), carrying the online-softmax running (max, sum, acc) in VMEM
scratch across cache blocks.  The GQA query group (rep = H/Hkv heads)
rides in one (rep x D) VMEM tile and is reused against every cache tile —
the bandwidth argument for GQA.

``lengths`` masks the valid prefix of each sequence's cache (slot ==
position discipline of the serving runtime).

Consumers: the big-model serving decode step, and — via
``attn_impl="pallas"`` — the batched Marian decode path
(:meth:`repro.nmt.transformer.MarianTransformer.decode_step` with a
leading batch dim), which issues one call for self-attention against
the growing KV cache (lengths = pos+1) and one for cross-attention
against the precomputed encoder K/V (lengths = source lengths).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 256
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (rep, D)
    k = k_ref[0]                                   # (block_s, D)
    v = v_ref[0]
    length = len_ref[0]

    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (rep, block_s)
    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, lengths, *, scale=None,
                 block_s: int = DEFAULT_BLOCK_S, interpret: bool = False):
    """q (B,H,D); k/v_cache (B,S,Hkv,D); lengths (B,) -> (B,H,D)."""
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5
    assert s % block_s == 0, (s, block_s)

    qr = q.reshape(b, hkv, rep, d).reshape(b * hkv, rep, d)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), hkv)     # (B*Hkv,)

    grid = (b * hkv, s // block_s)
    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda g, si: (g,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, rep, d), lambda g, si: (g, 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda g, si: (g, si, 0)),
            pl.BlockSpec((1, block_s, d), lambda g, si: (g, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, d), lambda g, si: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, hkv, rep, d).reshape(b, h, d)
