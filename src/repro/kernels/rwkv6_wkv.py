"""Pallas TPU kernel: RWKV6 chunked WKV recurrence (data-dependent decay).

One grid cell per (batch*head); the chunk axis is the second grid dim
with the (P x P) state carried in VMEM scratch across chunk steps (same
carry idiom as the flash kernels).  Per chunk (L x P tiles in VMEM):

    cum_t   = prefix-sum of log w within the chunk        (L,P)
    A[t,j]  = (r_t e^{cum_{t-1}}) · (k_j e^{-cum_j}),  j<t    -> MXU matmul
    y       = A @ v + (u·(r k)) v   + (r e^{cum_{t-1}}) @ S
    S       = diag(e^{cum_L}) S + sum_j e^{cum_L - cum_j} k_j v_j^T

TPU adaptation notes: per-channel decay makes A non-factorizable through
a scalar like Mamba2's — the decay-weighted r'/k' trick keeps everything
as (L,P)x(P,L) MXU matmuls; the per-step log-decay clamp (|log w| <=
2.5) bounds e^{-cum} in f32 for chunk 32 (lossless: decay^32 underflows
anyway).  P=64 head dim and L=32 chunks keep tiles lane-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)      # (L,P)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)    # (L,P) <= 0
    u = u_ref[0].astype(jnp.float32)      # (1,P)

    cum = jnp.cumsum(lw, axis=0)
    cum_prev = cum - lw
    r_dec = r * jnp.exp(cum_prev)
    k_inc = k * jnp.exp(-cum)

    l = r.shape[0]
    a = jax.lax.dot_general(r_dec, k_inc, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L,L)
    ti = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    a = jnp.where(tj < ti, a, 0.0)
    bonus = jnp.sum(r * u * k, axis=-1, keepdims=True)           # (L,1)

    s_prev = s_scr[...]                    # (P,P) key x value
    y = (jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + bonus * v
         + jax.lax.dot_general(r_dec, s_prev, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))

    wj = jnp.exp(cum[-1:, :] - cum)        # (L,P)
    inc = jax.lax.dot_general(k * wj, v, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P,P)
    s_scr[...] = s_prev * jnp.exp(cum[-1, :])[:, None] + inc

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        sT_ref[0] = s_scr[...].astype(sT_ref.dtype)


def rwkv6_wkv(r, k, v, log_w, u, s0=None, *, chunk: int = DEFAULT_CHUNK,
              interpret: bool = False):
    """r/k/v (B,S,H,P); log_w (B,S,H,P) (<=0); u (H,P); s0 (B,H,P,P).

    Returns (y (B,S,H,P), s_final (B,H,P,P) f32).
    """
    b, s, h, p = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if s0 is None:
        s0 = jnp.zeros((b, h, p, p), jnp.float32)

    def to_bh(x):   # (B,S,H,P) -> (B*H, S, P)
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, p)

    rr, kk, vv, ll = map(to_bh, (r, k, v, log_w))
    uu = jnp.broadcast_to(u[None, :, None, :], (b, h, 1, p)) \
        .reshape(b * h, 1, p)
    ss = s0.reshape(b * h, p, p)

    grid = (b * h, nc)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    y, s_t = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, 1, p), lambda g, ci: (g, 0, 0)),
            pl.BlockSpec((1, p, p), lambda g, ci: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, p, p), lambda g, ci: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), r.dtype),
            jax.ShapeDtypeStruct((b * h, p, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ll, uu, ss)

    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, s_t.reshape(b, h, p, p)
