"""Pure-jnp oracles for every Pallas kernel (and for the chunked jnp model
paths).  These are the simplest correct implementations — O(S^2)
materialized attention, 1-step-at-a-time recurrences — used as the
ground truth in kernel allclose tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------- flash attention --
def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None, lengths=None):
    """Materialized softmax attention with GQA head grouping.

    q (B,S,H,D), k/v (B,T,Hkv,D) -> (B,S,H,D).  f32 softmax.
    ``lengths`` (B,) optionally restricts each sequence to its valid key
    prefix (>= 1 valid key per row required, as in the Pallas kernels).
    """
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, s, hkv, rep, d).astype(jnp.float32)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg,
                        k.astype(jnp.float32)) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        # queries are the LAST s positions of the t-long key sequence
        offset = t - s
        mask &= j <= (i + offset)
        if window is not None:
            mask &= j > (i + offset - window)
    mask = jnp.broadcast_to(mask[None], (b, s, t))
    if lengths is not None:
        mask &= (jnp.arange(t)[None, None, :]
                 < lengths.astype(jnp.int32)[:, None, None])
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths, *, scale=None):
    """Single-token decode oracle.

    q (B,H,D); k/v_cache (B,T,Hkv,D); lengths (B,) = #valid cache slots.
    """
    b, h, d = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, rep, d).astype(jnp.float32)
    scores = jnp.einsum("bgrd,btgd->bgrt", qg,
                        k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(t)[None, :] < lengths[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrt,btgd->bgrd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


# ----------------------------------------------------------------- rwkv6 --
def rwkv6_ref(r, k, v, log_w, u, s0=None):
    """Step-by-step WKV6 recurrence (the definitionally-correct form).

    r/k/v (B,S,H,P), log_w (B,S,H,P) (<=0, f32), u (H,P).
    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (y (B,S,H,P), S_final (B,H,P,P)).
    """
    b, s, h, p = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(log_w.astype(jnp.float32))

    if s0 is None:
        s0 = jnp.zeros((b, h, p, p), jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp                     # (B,H,P) each
        kv = jnp.einsum("bhp,bhq->bhpq", kt, vt)
        y = jnp.einsum("bhp,bhpq->bhq", rt, state + u[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, w))
    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s_fin


# ------------------------------------------------------------ mamba2 ssd --
def ssd_ref(x, dt, a_log, b_in, c_in, s0=None):
    """Step-by-step SSD recurrence.

    x (B,S,H,P), dt (B,S,H) (post-softplus), a_log (H,) with A=-exp(a_log),
    b/c (B,S,H,N).
    H_t = exp(dt_t*A) H_{t-1} + dt_t * x_t ⊗ B_t ;  y_t = H_t · C_t
    Returns (y (B,S,H,P), H_final (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    if s0 is None:
        s0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a)                  # (B,H)
        state = state * decay[..., None, None]
        state = state + jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c_in.astype(jnp.float32), 1, 0))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s_fin
