"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel *body* runs in Python per grid cell, which validates the tiling
and carry logic; on TPU the same `pl.pallas_call` lowers to Mosaic.
Wrappers handle padding to block multiples and auto-select interpret
mode off the default backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import rwkv6_wkv as _wkv
from repro.kernels import ssd_scan as _ssd


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, lengths=None, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q (B,S,H,D); k/v (B,T,Hkv,D) -> (B,S,H,D). Pads S/T to blocks.

    ``lengths`` (B,) int32 marks each sequence's valid KEY prefix — the
    padded-batch discipline of the batched NMT/serving paths.  When None
    every real key position is valid; block-padding tail keys are masked
    either way, so non-causal callers no longer need to pre-pad.
    """
    interpret = _auto_interpret(interpret)
    s, t = q.shape[1], k.shape[1]
    bq = min(block_q, max(8, 1 << (s - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (t - 1).bit_length()))
    qp, pad_q = _pad_to(q, 1, bq)
    kp, pad_k = _pad_to(k, 1, bk)
    vp, _ = _pad_to(v, 1, bk)
    if lengths is None:
        lengths = jnp.full((q.shape[0],), t, jnp.int32)
    out = _fa.flash_attention(qp, kp, vp, causal=causal,
                              lengths=jnp.asarray(lengths, jnp.int32),
                              block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :s] if pad_q or pad_k else out


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, block_s: int = 256,
                 interpret: bool | None = None):
    """q (B,H,D); caches (B,S,Hkv,D); lengths (B,) -> (B,H,D)."""
    interpret = _auto_interpret(interpret)
    s = k_cache.shape[1]
    bs = min(block_s, max(8, 1 << (s - 1).bit_length()))
    kp, _ = _pad_to(k_cache, 1, bs)
    vp, _ = _pad_to(v_cache, 1, bs)
    return _da.flash_decode(q, kp, vp, lengths, block_s=bs,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, log_w, u, s0=None, *, chunk: int = 32,
              interpret: bool | None = None):
    """Chunked WKV6. Shapes as in repro.kernels.ref.rwkv6_ref."""
    interpret = _auto_interpret(interpret)
    return _wkv.rwkv6_wkv(r, k, v, log_w, u, s0, chunk=chunk,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, b_in, c_in, s0=None, *, chunk: int = 64,
             interpret: bool | None = None):
    """Chunked Mamba2 SSD. Shapes as in repro.kernels.ref.ssd_ref."""
    interpret = _auto_interpret(interpret)
    return _ssd.ssd_scan(x, dt, a_log, b_in, c_in, s0, chunk=chunk,
                         interpret=interpret)
