"""Pallas TPU flash attention (prefill/training forward).

TPU adaptation of the flash schedule (DESIGN.md §6): the grid walks
(batch*kv_head, q_block, k_block) with K innermost so the output block
accumulates in VMEM across K steps; online softmax keeps running max/sum
per row.  BlockSpecs stage (BLOCK_Q x head_dim) query tiles and
(BLOCK_K x head_dim) key/value tiles HBM->VMEM; head_dim and the block
sizes are multiples of the 128-lane MXU tiling.

GQA: the q tile carries the `rep` query heads of one kv head
(rep*head_dim lanes), so every staged K/V tile is reused by all grouped
queries — the same reuse argument that makes GQA decode bandwidth-
efficient on TPU.

Causal masking is positional (no mask tensor); fully-masked K blocks are
skipped by the grid via block pruning in the index map (we keep them and
mask instead: simpler, and XLA-CPU interpret mode is the validation
target — noted as a TODO for real-TPU tuning).

Padded batches: ``lengths`` (B,) optionally masks each sequence's valid
KEY prefix (slot < length), the prefix-padding discipline of the serving
batcher — this is how the batched Marian encoder/teacher-forced path
routes ragged length-bucketed batches through the kernel without
pre-trimming.  Rows whose query position is padding attend only to valid
keys (garbage-in-padding stays confined to padding rows).  ``lengths``
must be >= 1: a fully-masked row degenerates to exp(0)=1 weights on
every key (the online-softmax max never leaves NEG_INF), same contract
as the decode kernel and ``ref.attention_ref``; callers clamp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, scale: float, causal: bool, block_q: int,
                  block_k: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (block_q, rep*d)
    k = k_ref[0]                       # (block_k, d)
    v = v_ref[0]
    d = k.shape[-1]
    rep = q.shape[-1] // d
    bq = q.shape[0]

    qh = q.reshape(bq * rep, d) if rep > 1 else q
    s = jax.lax.dot_general(
        qh.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (bq*rep, block_k)

    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (bq * rep, block_k), 1)
    s = jnp.where(k_pos < len_ref[0], s, NEG_INF)     # valid key prefix
    if causal:
        q_pos = (qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (bq, rep, block_k), 0)).reshape(bq * rep, block_k)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]                # (bq*rep, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)             # (bq*rep, block_k)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).reshape(bq, rep * d).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None,
                    lengths=None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q (B,S,H,D); k/v (B,T,Hkv,D) -> (B,S,H,D).

    S % block_q == 0 and T % block_k == 0 required (production shapes are
    powers of two; ops.py pads otherwise).  ``lengths`` (B,) int32
    optionally restricts each sequence to its valid key prefix (padded
    batch discipline); None means all T keys are valid.
    """
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)

    if lengths is None:
        lengths = jnp.full((b,), t, jnp.int32)

    # (B*Hkv, S, rep*D): group query heads with their kv head
    qr = (q.reshape(b, s, hkv, rep, d).transpose(0, 2, 1, 3, 4)
          .reshape(b * hkv, s, rep * d))
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), hkv)      # (B*Hkv,)

    grid = (b * hkv, s // block_q, t // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, seq_k=t)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda g, qi, ki: (g,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_q, rep * d), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, qi, ki: (g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, qi, ki: (g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, rep * d),
                               lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, s, rep * d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * rep, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q * rep, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q * rep, d), jnp.float32),   # o accumulator
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)

    return (out.reshape(b, hkv, s, rep, d).transpose(0, 2, 1, 3, 4)
            .reshape(b, s, h, d))
