"""Production meshes.

Target hardware: TPU v5e pods — 256 chips/pod (16x16), 197 bf16
TFLOP/s + 819 GB/s HBM per chip, ~50 GB/s/link ICI.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state; callers opt in.
The dry-run spawns processes with
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so 512 host placeholder devices exist.
"""

from __future__ import annotations

import jax
import numpy as np


# ---- hardware constants used by the roofline analysis (EXPERIMENTS.md) ----
TPU_V5E = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    "hbm_bytes": 16e9,           # HBM capacity per chip
}

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for tests (requires >=prod(shape) visible devices)."""
    return jax.make_mesh(shape, axes)


def chips(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
