"""Serving driver: batched generation + optional C-NMT tiered routing.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 16

Pass ``--mesh DxM`` (e.g. ``--mesh 2x2``) to serve the LM sharded over a
device mesh (``data`` x ``model`` axes); on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first so the host
platform exposes N devices.  Decode output is bit-for-bit identical to
the unsharded run.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.launch.mesh import make_host_mesh
from repro.models.registry import resolve
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import GenerationSession, build_executor
from repro.runtime.sharded import make_sharded_session


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--tiered", action="store_true",
                    help="route through the C-NMT engine")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the LM over a (data, model) host mesh, "
                         "e.g. 2x2 (needs that many visible devices)")
    args = ap.parse_args(argv)

    r = resolve(args.arch, size="smoke" if args.smoke else "full")
    model, cfg = r.model, r.cfg
    params = model.init(jax.random.PRNGKey(0))
    if args.mesh:
        d, m = (int(x) for x in args.mesh.lower().split("x"))
        mesh = make_host_mesh((d, m))
        sess = make_sharded_session(model, params, mesh, max_len=64,
                                    batch_size=min(args.requests, 8))
        print(f"[serve] sharded over {d}x{m} mesh, layout={sess.layout}")
    else:
        sess = GenerationSession(model, params, max_len=64)
    rng = np.random.default_rng(0)

    if not args.tiered:
        b = min(args.requests, 8)
        prompts = rng.integers(4, cfg.vocab_size, (b, 12)).astype(np.int32)
        t0 = time.perf_counter()
        out = sess.generate(prompts, max_new=args.max_new)
        print(f"[serve] generated {out.shape} in "
              f"{time.perf_counter()-t0:.2f}s (cold)")
        t0 = time.perf_counter()
        sess.generate(prompts, max_new=args.max_new)
        print(f"[serve] warm: {time.perf_counter()-t0:.3f}s")
        return

    profile = make_profile("cp2", seed=0)
    edge_exec = build_executor(sess, kind="solo", max_new=args.max_new,
                               vocab_clip=cfg.vocab_size)
    edge_batched = build_executor(sess, kind="batched", max_new=args.max_new,
                                  vocab_clip=cfg.vocab_size)

    engine = CollaborativeEngine(
        tiers=[
            Tier(DeviceProfile("edge", LinearLatencyModel(1e-4, 2e-3, 5e-3)),
                 executor=edge_exec, batched_executor=edge_batched,
                 batch_size=4, name="edge"),
            Tier(DeviceProfile("pod", LinearLatencyModel(2e-5, 4e-4, 2e-3)),
                 name="cloud", rtt_fn=profile.rtt_at),
        ],
        n2m=LinearN2M(0.8, 1.0))
    # concurrent slots of 4: edge-routed members run as REAL batched
    # generates (submit_batch), not per-sequence calls
    slot = 4
    for i in range(0, args.requests, slot):
        reqs = [rng.integers(4, cfg.vocab_size,
                             (int(rng.integers(4, 48)),)).astype(np.int32)
                for _ in range(min(slot, args.requests - i))]
        engine.submit_batch(reqs, now_s=float(i))
    s = engine.stats()
    print(f"[serve] {s['requests']} reqs, mean {s['mean_latency_s']*1e3:.1f}ms,"
          f" offload {s['offload_frac']*100:.0f}%")


if __name__ == "__main__":
    main()
