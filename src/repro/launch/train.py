"""Training driver for the big-model stack.

On real hardware this launches the sharded train loop on the production
mesh; on this CPU it runs reduced configs end-to-end (the full configs
are exercised by launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.pipeline import lm_batches
from repro.models.model import LM
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import init_train_state, make_train_step
from repro.training.optimizer import cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    sched = cosine_schedule(args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps)
    step_fn = jax.jit(make_train_step(
        model, lr_schedule=sched, opt_cfg=AdamWConfig(lr=args.lr)))

    rng = np.random.default_rng(0)
    stream = rng.integers(1, cfg.vocab_size,
                          args.steps * args.batch * (args.seq + 1) * 2
                          ).astype(np.int32)
    t0, losses = time.time(), []
    for i, batch in enumerate(lm_batches(stream, batch_size=args.batch,
                                         seq_len=args.seq)):
        if i >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if i % 10 == 0:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}", flush=True)
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {time.time()-t0:.0f}s")
    if args.ckpt:
        save_checkpoint(args.ckpt, state, step=args.steps)
        print(f"checkpoint: {args.ckpt}")


if __name__ == "__main__":
    main()
