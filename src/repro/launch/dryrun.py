# The dry-run needs 512 placeholder devices; jax locks the device count on
# first init, so these two lines MUST precede every other import.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) combination, lower + compile the
real step function against the production mesh with abstract
ShapeDtypeStruct inputs (no allocation), then record:

* memory_analysis()  — per-device bytes: proves the sharding fits HBM;
* cost_analysis()    — HLO FLOPs / bytes for the roofline terms;
* the collective mix — parsed from the partitioned HLO text: bytes moved
  by all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute (not in cost_analysis).

Shapes lower the unit that really runs in production:
  train_4k    -> train_step   (loss + grads + clip + AdamW update)
  prefill_32k -> prefill_step (last logits + decode state)
  decode_32k  -> serve_step   (ONE token vs a seq_len KV cache/state)
  long_500k   -> serve_step   (sub-quadratic archs + documented SWA
                               variants only; see configs.shape_supported)

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh pod1 --out roofline/
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ARCH_NAMES, get_config, shape_supported
from repro.launch.mesh import TPU_V5E, chips, make_production_mesh
from repro.models.model import LM
from repro.runtime.serving import make_prefill_step, make_serve_step
from repro.sharding.policy import (
    batch_specs,
    decode_state_specs,
    make_policy,
    param_specs,
    to_shardings,
    train_state_specs,
)
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainState, init_train_state, make_train_step

PARAM_DTYPE = jnp.bfloat16
# >=100B params: bf16 AdamW moments (ZeRO-style memory knob, DESIGN.md §7)
BF16_MOMENTS_THRESHOLD = 100e9


def input_specs(cfg, shape_name: str, *, model: LM):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    seq, batch, kind = INPUT_SHAPES[shape_name]
    i32 = jnp.int32
    if kind == "train":
        batch_tree = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "targets": jax.ShapeDtypeStruct((batch, seq), i32),
        }
        if cfg.is_encoder_decoder:
            batch_tree["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder.max_frames, cfg.d_model), PARAM_DTYPE)
        return batch_tree
    if kind == "prefill":
        tree = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
        if cfg.is_encoder_decoder:
            tree["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder.max_frames, cfg.d_model), PARAM_DTYPE)
        return tree
    if kind == "decode":
        state = jax.eval_shape(
            lambda: model.init_decode_state(None, batch, seq,
                                            dtype=PARAM_DTYPE))
        return {"state": state,
                "tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
    raise ValueError(kind)


# ------------------------------------------------------ HLO collective scan
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TYPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64"
                      r"|c64|c128)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> dict:
    """computation name -> list of body lines (HLO text format)."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)(?:\.clone)?\s*\(.*\)\s*->.*{",
                     line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _while_edges(comps: dict):
    """(parent_comp, body_comp, cond_comp) for every while op."""
    edges = []
    pat = re.compile(r"while\(.*\),\s*condition=%?([\w.\-]+),"
                     r"\s*body=%?([\w.\-]+)")
    for name, lines in comps.items():
        for ln in lines:
            m = pat.search(ln)
            if m:
                edges.append((name, m.group(2), m.group(1)))
    return edges


def _trip_count(cond_lines) -> int:
    """Trip count from the condition computation: the constant compared
    against the induction variable (scan conds are `i < N`)."""
    consts = {}
    for ln in cond_lines:
        m = re.search(r"%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)",
                      ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "compare(" in ln and ("direction=LT" in ln or "direction=GT" in ln):
            ops = re.search(r"compare\(([^)]*)\)", ln)
            if ops:
                for tok in ops.group(1).split(","):
                    tok = tok.strip().lstrip("%")
                    tok = tok.split(" ")[-1].lstrip("%")
                    if tok in consts:
                        return consts[tok]
    # fall back: max constant in the tiny cond computation
    return max(consts.values(), default=1)


def _comp_multipliers(hlo_text: str) -> dict:
    """computation -> effective execution count (nested whiles multiply).

    XLA's cost_analysis counts while bodies ONCE; these multipliers are
    how the roofline recovers per-step totals (EXPERIMENTS.md §Roofline
    methodology).
    """
    comps = _split_computations(hlo_text)
    edges = _while_edges(comps)
    mult = {name: 0 for name in comps}
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    # computations reachable only as while bodies get parent_mult * trip;
    # everything else (fusions, called comps) inherits parent's multiplier
    # implicitly through cost_analysis, so we only track while bodies.
    body_parent = {b: (p, c) for p, b, c in edges}

    def resolve(name, seen=()):
        if name not in body_parent:
            return 1
        if name in seen:
            return 1
        p, c = body_parent[name]
        trips = _trip_count(comps.get(c, []))
        return trips * resolve(p, seen + (name,))

    return {name: resolve(name) for name in comps}, comps


def collective_stats(hlo_text: str) -> dict:
    """Bytes moved by collectives, with while-body trip-count scaling."""
    mult, comps = _comp_multipliers(hlo_text)
    stats = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for cname, lines in comps.items():
        k = mult.get(cname, 1)
        for line in lines:
            if "=" not in line:
                continue
            rhs = line.split("=", 1)[1].strip()
            for c in _COLLECTIVES:
                m = re.match(r"^(\([^)]*\)|\S+)\s+" + c + r"(-start|-done)?\(",
                             rhs)
                if m:
                    if m.group(2) == "-done":
                        break
                    stats[c]["bytes"] += _shape_bytes(m.group(1)) * k
                    stats[c]["count"] += k
                    break
    stats["total_bytes"] = sum(stats[c]["bytes"] for c in _COLLECTIVES)
    return stats


# --------------------------------------------------------------- lowering
def build_lowered(arch: str, shape_name: str, mesh, *, remat=True,
                  constrain_acts=True, layout: str = "tp",
                  seq_parallel: bool = False, flash_decode_sp: bool = False,
                  fsdp: bool = True):
    cfg = get_config(arch, shape=shape_name)
    seq, batch, kind = INPUT_SHAPES[shape_name]
    pol = make_policy(mesh, batch_size=batch, layout=layout, fsdp=fsdp)

    constrain = None
    if constrain_acts:
        # pin the residual stream's batch sharding through scan+remat;
        # seq_parallel additionally shards the sequence dim over the model
        # axis between layers (Megatron-SP): the saved per-layer carries
        # shrink by the TP degree, at the cost of gather/scatter around
        # each mixer (XLA inserts them during propagation).
        seq_ax = pol.seq(seq) if (seq_parallel and kind != "decode") else None
        act_sh = NamedSharding(
            mesh, P(pol.batch(batch), seq_ax, None))

        def constrain(t):
            if t.ndim == 3:
                return jax.lax.with_sharding_constraint(t, act_sh)
            return t

        # layer-internal chunk tensors (rwkv/mamba) keep batch sharding too
        from repro.sharding import ctx as shard_ctx

        def batch_constrainer(t, axis):
            ax = pol.batch(t.shape[axis])
            if ax is None:
                return t
            spec = [None] * t.ndim
            spec[axis] = ax
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, P(*spec)))

        shard_ctx.set_batch_constrainer(batch_constrainer)

    model = LM(cfg, param_dtype=PARAM_DTYPE,
               remat=remat and kind == "train", constrain=constrain)

    params_abs = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = param_specs(pol, params_abs)
    batch_abs = input_specs(cfg, shape_name, model=model)

    if kind == "train":
        moments = (jnp.bfloat16
                   if cfg.param_counts()["total"] >= BF16_MOMENTS_THRESHOLD
                   else jnp.float32)
        state_abs = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0),
                                     moments_dtype=moments))
        state_specs = train_state_specs(pol, state_abs)
        b_specs = batch_specs(pol, batch_abs)
        step = make_train_step(model)
        in_sh = (to_shardings(mesh, state_specs), to_shardings(mesh, b_specs))
        out_sh = (to_shardings(mesh, state_specs), None)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        args = (state_abs, batch_abs)
    elif kind == "prefill":
        b_specs = batch_specs(pol, batch_abs)
        prefill = make_prefill_step(model)

        def step(params, batch_in):
            return prefill(params, **batch_in)

        # pin the produced decode state (otherwise XLA materializes the
        # full KV tensors with whatever layout propagation guessed)
        out_abs = jax.eval_shape(step, params_abs, batch_abs)
        logits_spec = P(pol.batch(batch), pol.model(cfg.padded_vocab))
        out_sh = (NamedSharding(mesh, logits_spec),
                  to_shardings(mesh, decode_state_specs(pol, out_abs[1])))
        in_sh = (to_shardings(mesh, p_specs), to_shardings(mesh, b_specs))
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        args = (params_abs, batch_abs)
    else:  # decode
        state_abs = batch_abs["state"]
        st_specs = decode_state_specs(pol, state_abs)
        tok_spec = P(pol.batch(batch), None)
        serve = make_serve_step(model)
        if flash_decode_sp and pol.seq(seq) and not cfg.sliding_window:
            from repro.sharding import ctx as shard_ctx
            shard_ctx.set_decode_seq_shard(
                (mesh, "model", pol.batch(batch)))
        in_sh = (to_shardings(mesh, p_specs),
                 to_shardings(mesh, st_specs),
                 NamedSharding(mesh, tok_spec))
        out_sh = (None, to_shardings(mesh, st_specs))
        jitted = jax.jit(serve, in_shardings=in_sh, out_shardings=out_sh)
        args = (params_abs, state_abs, batch_abs["tokens"])

    with mesh:
        lowered = jitted.lower(*args)
    return cfg, lowered


def analyze(arch: str, shape_name: str, mesh_name: str, *, remat=True,
            layout: str = "tp", seq_parallel: bool = False,
            flash_decode_sp: bool = False, fsdp: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    n_chips = chips(mesh)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": n_chips, "layout": layout, "seq_parallel": seq_parallel,
           "flash_decode_sp": flash_decode_sp, "fsdp": fsdp, "ok": False}
    ok, reason = shape_supported(arch, shape_name)
    if not ok:
        rec["skipped"] = reason
        return rec
    t0 = time.time()
    cfg, lowered = build_lowered(arch, shape_name, mesh, remat=remat,
                                 layout=layout, seq_parallel=seq_parallel,
                                 flash_decode_sp=flash_decode_sp, fsdp=fsdp)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    # ---- memory ----
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)
        }
        args_b = rec["memory"].get("argument_size_in_bytes", 0)
        temp_b = rec["memory"].get("temp_size_in_bytes", 0)
        rec["memory"]["per_device_total"] = args_b + temp_b
        rec["memory"]["fits_hbm"] = bool(args_b + temp_b
                                         <= TPU_V5E["hbm_bytes"])
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    # ---- cost ----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed",
                                "bytes accessed output", "transcendentals")
                       or k.startswith("bytes accessed")}
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}

    # ---- collectives (from partitioned HLO) ----
    hlo = compiled.as_text()
    rec["collectives"] = collective_stats(hlo)
    rec["hlo_bytes"] = len(hlo)

    # ---- model-level reference numbers ----
    pc = cfg.param_counts()
    seq, batch, kind = INPUT_SHAPES[shape_name]
    tokens = batch * seq if kind != "decode" else batch
    rec["params_total"] = pc["total"]
    rec["params_active"] = pc["active"]
    rec["tokens_per_call"] = tokens
    mult = 6 if kind == "train" else 2
    rec["model_flops"] = float(mult * pc["active"] * tokens)

    # ---- analytic step cost (whole mesh) ----
    # XLA HloCostAnalysis counts while bodies once (scan undercounting);
    # the analytic model is the roofline numerator, validated against
    # cost_analysis on unrolled reduced configs in tests/test_costs.py.
    from repro.models.costs import step_cost
    moments_b = 2 if pc["total"] >= BF16_MOMENTS_THRESHOLD else 8
    sc = step_cost(cfg, kind=kind, batch=batch, seq=seq,
                   moments_bytes=moments_b)
    rec["analytic"] = {"flops": sc.flops, "hbm_bytes": sc.hbm_bytes}
    rec["ok"] = True
    return rec


def roofline_terms(rec: dict) -> dict:
    """The three §Roofline terms, in seconds per step.

    compute/memory use the ANALYTIC whole-mesh numbers divided over the
    chips (cost_analysis undercounts scan bodies; raw per-partition
    values stay in rec["cost"] for reference).  The collective term uses
    the trip-count-scaled HLO collective bytes (per partition) over the
    per-chip ICI bandwidth.
    """
    n = rec["chips"]
    flops = rec.get("analytic", {}).get("flops", 0.0) / n
    bytes_ = rec.get("analytic", {}).get("hbm_bytes", 0.0) / n
    coll = rec.get("collectives", {}).get("total_bytes", 0)
    t_compute = flops / TPU_V5E["peak_flops_bf16"]
    t_memory = bytes_ / TPU_V5E["hbm_bw"]
    t_coll = coll / TPU_V5E["ici_bw"]
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    hlo_flops = rec.get("cost", {}).get("flops", 0.0)
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dom[1],
        "useful_flops_ratio": (rec["model_flops"]
                               / rec["analytic"]["flops"]
                               if rec.get("analytic", {}).get("flops")
                               else None),
        "hlo_raw_flops_per_partition": hlo_flops,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["pod1", "pod2"], default="pod1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="roofline")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--layout", choices=["tp", "ddp"], default="tp")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--flash-decode-sp", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--auto", action="store_true",
                    help="per-combo best-known settings (EXPERIMENTS.md "
                         "§Perf): ddp for <=3B archs on train/prefill, "
                         "no-fsdp + shard_map flash-decode for decode")
    ap.add_argument("--tag-suffix", default="")
    args = ap.parse_args(argv)

    combos = ([(a, s) for a in ARCH_NAMES for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    SMALL_ARCHS = {"rwkv6-3b", "zamba2-1.2b", "whisper-large-v3"}
    n_fail = 0
    for arch, shape in combos:
        layout, fsdp, fdsp = args.layout, not args.no_fsdp, \
            args.flash_decode_sp
        if args.auto:
            kind = INPUT_SHAPES[shape][2]
            if kind == "decode":
                # TP-only weights only when the TP shard fits comfortably
                # (<=4.5 GB): bigger models keep FSDP and pay the gathers
                tp_shard_gb = get_config(arch).param_counts()["total"] \
                    * 2 / 16 / 1e9
                fsdp = tp_shard_gb > 4.5
                fdsp = True           # shard_map split-cache flash decode
            elif arch in SMALL_ARCHS:
                layout = "ddp"        # head counts don't divide TP=16
        tag = f"{arch}_{shape}_{args.mesh}{args.tag_suffix}"
        try:
            rec = analyze(arch, shape, args.mesh, remat=not args.no_remat,
                          layout=layout, seq_parallel=args.seq_parallel,
                          flash_decode_sp=fdsp, fsdp=fsdp)
            if rec["ok"]:
                rec["roofline"] = roofline_terms(rec)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "ok": False, "error": str(e),
                   "traceback": traceback.format_exc()}
            n_fail += 1
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        status = ("SKIP " + rec.get("skipped", "")) if "skipped" in rec else \
            ("OK" if rec.get("ok") else "FAIL " + rec.get("error", "")[:200])
        print(f"[dryrun] {tag}: {status}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
