"""Serving runtime: prefill/decode steps, generation sessions, and the
C-NMT-routed tiered serving engine."""

from repro.runtime.serving import (
    GenerationSession,
    TierFaultError,
    make_batched_tier_executor,
    make_faulty_executor,
    make_prefill_step,
    make_serve_step,
    make_tier_executor,
)
from repro.runtime.engine import CollaborativeEngine, Tier, RequestResult

__all__ = [
    "GenerationSession",
    "TierFaultError",
    "make_batched_tier_executor",
    "make_faulty_executor",
    "make_prefill_step",
    "make_serve_step",
    "make_tier_executor",
    "CollaborativeEngine",
    "Tier",
    "RequestResult",
]
