"""Serving runtime: prefill/decode steps, generation sessions, and the
C-NMT-routed tiered serving engine."""

from repro.runtime.serving import (
    ContinuousGenerationSession,
    GenerationSession,
    TierFaultError,
    build_executor,
    make_batched_tier_executor,
    make_faulty_executor,
    make_prefill_step,
    make_serve_step,
    make_split_tier_executors,
    make_tier_executor,
)
from repro.runtime.engine import CollaborativeEngine, Tier, RequestResult

__all__ = [
    "ContinuousGenerationSession",
    "GenerationSession",
    "TierFaultError",
    "build_executor",
    "make_batched_tier_executor",
    "make_faulty_executor",
    "make_prefill_step",
    "make_serve_step",
    "make_split_tier_executors",
    "make_tier_executor",
    "CollaborativeEngine",
    "Tier",
    "RequestResult",
]
