"""Mesh-backed serving for the big ``models/model.py`` stack.

This is the bridge ROADMAP item 4 asked for: a
:class:`~repro.runtime.serving.GenerationSession` /
:class:`~repro.runtime.serving.ContinuousGenerationSession` whose
parameters live SHARDED across a device mesh (``launch/mesh.py`` host
mesh in tests, a TPU pod in production), so a
:class:`~repro.runtime.engine.Tier` of the ``CollaborativeEngine`` can
be a multi-device sharded LM server instead of a single-device model.

The sessions themselves need no changes: ``jax.jit`` picks up the
committed :class:`~jax.sharding.NamedSharding` of the parameters, GSPMD
partitions the prefill / compiled-scan decode executables, and the
decode state inherits propagated shardings.  What this module owns is
the *placement*: choosing a layout (``tp`` tensor-parallel vs ``ddp``
pure data-parallel, per ``sharding/policy.py``) and ``device_put``-ing
the parameter pytree under the policy's :func:`param_specs`.

Decode output is BIT-FOR-BIT equal to the unsharded single-device run
for every smoke architecture — pinned under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` in
tests/test_bigmodel_serving.py.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.models.model import LM
from repro.runtime.serving import (
    ContinuousGenerationSession,
    GenerationSession,
)
from repro.sharding.policy import (
    ShardingPolicy,
    make_policy,
    param_specs,
    to_shardings,
)


def infer_layout(cfg, mesh) -> str:
    """Pick the policy layout for this architecture on this mesh.

    ``tp`` when the attention head counts divide the ``model`` axis (the
    TP collectives then split real work); ``ddp`` otherwise — right for
    head counts that don't divide the axis (rwkv6's 40 heads, whisper's
    20 on an 8-way axis) and for models whose mixers carry no head axis
    worth splitting (see sharding/policy.py docstring).
    """
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = int(axes.get("model", 1))
    if tp <= 1:
        return "ddp"
    heads_ok = (cfg.num_heads % tp == 0 and cfg.num_kv_heads % tp == 0)
    has_heads = any(g.mixer in ("attn", "shared_attn", "mla")
                    for g in cfg.layer_plan)
    return "tp" if (has_heads and heads_ok) else "ddp"


def shard_lm(model: LM, params, mesh, *, batch_size: int = 8,
             layout: str = "auto", fsdp: bool = True
             ) -> Tuple[object, ShardingPolicy]:
    """Place ``params`` on ``mesh`` under the sharding policy.

    Returns ``(sharded_params, policy)``; ``layout="auto"`` delegates to
    :func:`infer_layout`.  The returned params carry committed
    NamedShardings, so any jit consuming them (the session entry points)
    compiles a partitioned executable without explicit in_shardings.
    """
    if layout == "auto":
        layout = infer_layout(model.cfg, mesh)
    pol = make_policy(mesh, batch_size=batch_size, layout=layout, fsdp=fsdp)
    shardings = to_shardings(
        mesh, param_specs(pol, jax.eval_shape(lambda: params)))
    return jax.device_put(params, shardings), pol


def make_sharded_session(model: LM, params, mesh, *,
                         continuous: bool = False,
                         batch_size: int = 8,
                         layout: str = "auto",
                         fsdp: bool = True,
                         max_len: int = 64,
                         max_slots: int = 8,
                         bucket_shapes: bool = True,
                         host_loop: bool = False):
    """Build a generation session whose params are sharded over ``mesh``.

    ``continuous=False`` returns a :class:`GenerationSession` (compiled
    scan decode), ``continuous=True`` a
    :class:`ContinuousGenerationSession` (slot-table in-flight batching;
    decoder-only plans).  Everything downstream — ``build_executor``,
    ``Tier``, ``CollaborativeEngine.serve_continuous`` — composes
    unchanged, which is the point: a sharded pod tier is just a tier.
    """
    params_s, pol = shard_lm(model, params, mesh, batch_size=batch_size,
                             layout=layout, fsdp=fsdp)
    if continuous:
        sess = ContinuousGenerationSession(
            model, params_s, max_slots=max_slots, max_len=max_len,
            bucket_shapes=bucket_shapes)
    else:
        sess = GenerationSession(model, params_s, max_len=max_len,
                                 host_loop=host_loop,
                                 bucket_shapes=bucket_shapes)
    sess.policy = pol            # introspection: which layout was chosen
    sess.layout = "tp" if pol.model_axes else "ddp"
    sess.mesh = mesh
    return sess
