"""N-tier collaborative serving engine: the C-NMT decision rule
generalized to a fleet of heterogeneous compute tiers with per-tier
queues — the production integration of ``repro.core``.

Each :class:`Tier` is one place an inference can run (on-device NPU,
edge gateway, regional pod, central cloud, ...) and carries

* a latency plane (``DeviceProfile`` — measured by ``core.calibration``
  or priced from dry-run rooflines via ``device_from_roofline``),
* optionally a REAL executor callable (a ``repro.nmt`` translate fn or a
  :class:`~repro.runtime.serving.GenerationSession`) — the engine then
  measures actual wall-clock; without one the tier is MODELLED and the
  engine simulates the latency (how TPU-pod tiers we cannot run locally
  participate, mirroring the paper's simulated network + real inference
  testbed),
* optionally a live link (``rtt_fn``) — its T_tx is tracked through
  §II-C timestamped samples of *offloaded* requests only, one
  :class:`TxEstimator` per link,
* a concurrency limit (``servers``) and a bounded FIFO queue
  (``queue_capacity``) — the engine keeps per-tier occupancy in virtual
  time, so a busy tier's queue delay enters the decision rule:

      d_tgt = argmin_k [ T_queue,k + T_tx,k + T_exe,k(N, M_hat) ]

With two tiers (local edge + one cloud behind a link) and empty queues
this reduces exactly to paper Eq. (1)/(2); the regression tests pin the
reduction bit-for-bit against the seed engine semantics.  An optional
online-feedback loop (``refit_interval``) refits the scheduler's planes
and the N->M regressor from observed completions every K requests.

Batched continuous serving (beyond paper): a tier with ``batch_size``
b > 1 coalesces requests in virtual time — while a server is busy,
arrivals assigned to it accumulate into the next not-yet-started batch
(up to b members) and start together when the server frees; a batch of
b costs  max member execution + ``per_seq_overhead_s``·(b−1)  (the
sub-linear continuous-batching model, same formula as the DES).  A
member's reported latency reflects the batch state at its own admission;
``batch_size=1`` keeps the exact unbatched virtual-time bookkeeping.

REAL batched execution: a tier carrying a ``batched_executor`` (from
:func:`repro.runtime.serving.build_executor` with
``kind="batched"``) serves
:meth:`CollaborativeEngine.submit_batch` — concurrent arrivals routed
to it are drained through a length-bucketed
:class:`~repro.data.pipeline.TokenBatcher` into padded blocks of up to
``batch_size`` sequences, each block runs as ONE batched generate (the
compiled-scan decode path), and every member gets its own
``(m_out, tokens)`` plus the measured batch wall-clock in its latency —
execution finally matches the batch-aware occupancy accounting instead
of only being modelled by it.

CONTINUOUS in-flight batching: a tier carrying a ``continuous_session``
(:class:`~repro.runtime.serving.ContinuousGenerationSession`) serves
:meth:`CollaborativeEngine.serve_continuous` — an event loop over a
virtual arrival schedule where the batch is re-formed BETWEEN decode
steps: finished rows evict and free their slot immediately, and queued
requests prefill into the freed slots of the live batch (EDF across
deadline values, FIFO within a deadline class).  Admission reuses the
same deadline-aware shed/reroute rule as ``submit`` with slot-table
space standing in for server space; each tier's virtual clock advances
by its *measured* prefill/step wall time, so reported latencies are
real compute under the modelled arrival process.  ``refill=False``
degenerates to PR 3 block-to-completion scheduling (admit only into an
empty table) — the baseline the continuous benchmark compares against.

Deadline-aware admission (SLO): ``submit(..., deadline_s=...)`` attaches
a relative deadline.  When the chosen tier is full the engine re-routes
to the cheapest tier with space whose predicted total meets the
deadline, and **sheds** the request (``RequestResult.shed``) when no
tier can — instead of the blind force-enqueue used for deadline-less
requests.  ``stats()`` reports SLO attainment and shed counts alongside
the latency percentiles.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.calibration import OnlineCalibrator
from repro.core.faults import (
    OPEN,
    CircuitBreaker,
    FaultSchedule,
    RetryPolicy,
    make_breakers,
)
from repro.data.pipeline import TokenBatcher
from repro.core.latency_model import (
    ActivationCostModel,
    DeviceProfile,
    bytes_for_tokens,
)
from repro.core.length_regressor import LinearN2M
from repro.core.scheduler import (
    MultiTierDecision,
    MultiTierScheduler,
    PlacementPlan,
    SchedTier,
)
from repro.core.tx_estimator import LinkModel, TxEstimator


@dataclasses.dataclass
class Tier:
    """One compute tier (device NPU / edge gateway / regional pod / cloud).

    ``rtt_fn(now) -> rtt_seconds`` marks a REMOTE tier (a ConnectionProfile's
    ``rtt_at`` in experiments; a real prober in deployment); None marks a
    local tier.  ``servers`` bounds concurrent executions (batches); up
    to ``queue_capacity`` further requests wait in FIFO order (None =
    unbounded).

    ``batch_size`` > 1 makes each server a continuous-batching worker:
    queued requests coalesce (in virtual time) into batches of up to
    ``batch_size`` that start together when the server frees, a batch of
    b costing  max member exec + ``per_seq_overhead_s``·(b−1).  The
    overhead is calibratable from batched timing grids
    (``repro.core.calibration.fit_batch_overhead``).

    ``batched_executor`` (``(block (b,w), lengths) -> [(m_out, tokens)]``,
    built by :func:`repro.runtime.serving.build_executor` with
    ``kind="batched"``)
    makes execution itself batched: ``submit_batch`` drains concurrent
    arrivals into length-bucketed blocks of up to ``batch_size`` and runs
    each block as one real batched generate.  Per-request ``executor``
    calls (``submit``) stay per-sequence.
    """

    profile: DeviceProfile
    executor: Optional[Callable] = None   # tokens -> (m_out, out_tokens)
    name: Optional[str] = None
    rtt_fn: Optional[Callable[[float], float]] = None
    servers: int = 1
    queue_capacity: Optional[int] = None
    bandwidth_bps: float = 100e6
    batch_size: int = 1
    per_seq_overhead_s: float = 0.0
    batched_executor: Optional[Callable] = None   # (block, lengths) -> [...]
    # ContinuousGenerationSession — marks the tier for serve_continuous's
    # in-flight batching (slot-table space replaces server space there)
    continuous_session: Optional[object] = None
    # Split-placement legs (serving.build_executor kind="split"): the
    # tier can run just the encoder (tokens -> EncoderStates) and/or just
    # the decoder (EncoderStates -> (m_out, tokens)).  Both tiers of a
    # split plan need their respective leg for REAL execution; otherwise
    # the engine models the leg times from the profile planes.
    encode_executor: Optional[Callable] = None
    decode_executor: Optional[Callable] = None

    def __post_init__(self):
        if self.name is None:
            self.name = self.profile.name
        if self.servers < 1:
            raise ValueError("servers must be >= 1")
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")

    def run(self, tokens: np.ndarray, m_hat: float,
            rng: np.random.Generator) -> tuple[int, float]:
        """Execute one request on this tier: returns
        ``(output_len_tokens, execution_seconds)``.

        With a real ``executor`` the time is measured wall-clock and
        ``m_out`` is the model's actual output length (ground truth);
        without one the tier is MODELLED — the time is drawn around the
        profile's plane at the *predicted* ``m_hat`` (an estimator
        input), and ``m_out`` is ``round(m_hat)``.  Exactly one of the
        two paths runs; the engine's accounting downstream is identical
        for both.
        """
        if self.executor is not None:
            t0 = time.perf_counter()
            m_out, _ = self.executor(tokens)
            return int(m_out), time.perf_counter() - t0
        # modelled: draw the true time around the plane at predicted M
        t = float(self.profile.true_time(float(len(tokens)), m_hat, rng))
        return int(max(round(m_hat), 1)), t


class _TierOccupancy:
    """Virtual-time FIFO bookkeeping for one tier: ``free_at`` holds each
    server's next-free time; assigned-but-not-started requests count
    against the bounded queue.

    With ``batch_size`` > 1 each server coalesces assignments: the last
    batch scheduled on a server stays *open* while its start time is
    still in the future, and new assignments join it (extending its
    finish by the max-exec/overhead rule) instead of queueing behind it.
    A joining member's reported service time is the batch duration as of
    its join — earlier members keep the (shorter) duration they saw,
    a deliberately causal per-request accounting.
    """

    def __init__(self, servers: int, batch_size: int = 1,
                 per_seq_overhead_s: float = 0.0):
        self.free_at = [0.0] * servers      # per-server next-free time
        self.batch_size = batch_size
        self.per_seq = per_seq_overhead_s
        # per-server open tail batch: [start, base_exec_max, count]
        self._tail: List[Optional[list]] = [None] * servers
        self.inflight: List[tuple] = []     # (start, finish), pruned lazily

    def _prune(self, now: float) -> None:
        self.inflight = [(s, f) for s, f in self.inflight if f > now]

    def queue_delay(self, now: float) -> float:
        d = min(self.free_at) - now
        return d if d > 0.0 else 0.0

    def free_servers(self, now: float) -> int:
        return sum(1 for f in self.free_at if f <= now)

    def queue_len(self, now: float) -> int:
        self._prune(now)
        return sum(1 for s, _ in self.inflight if s > now)

    def assign(self, now: float, exec_s: float) -> tuple[float, float]:
        """FIFO-assign one request; returns (wait, service_s) — the
        T_queue it experiences and the duration of the service (solo
        exec, or its batch's duration as of joining)."""
        self._prune(now)                 # keep inflight bounded over time
        if self.batch_size > 1:
            open_idx = [s for s, t in enumerate(self._tail)
                        if t is not None and t[0] > now
                        and t[2] < self.batch_size]
            if open_idx:
                s = min(open_idx, key=lambda j: self._tail[j][0])
                tail = self._tail[s]
                tail[1] = max(tail[1], exec_s)
                tail[2] += 1
                service = tail[1] + self.per_seq * (tail[2] - 1)
                finish = tail[0] + service
                self.free_at[s] = finish
                self.inflight.append((tail[0], finish))
                return tail[0] - now, service
        idx = min(range(len(self.free_at)), key=self.free_at.__getitem__)
        earliest = self.free_at[idx]
        wait = earliest - now
        if wait <= 0.0:
            wait = 0.0
        start = now + wait
        finish = start + exec_s
        self.free_at[idx] = finish
        if self.batch_size > 1:
            # a future-start batch stays open for joins; a batch that
            # started immediately is already running and cannot be joined
            self._tail[idx] = [start, exec_s, 1] if start > now else None
        self.inflight.append((start, finish))
        return wait, exec_s

    def assign_batch(self, now: float, exec_s: float,
                     count: int) -> tuple[float, float]:
        """Book one REAL batch of ``count`` members, measured to take
        ``exec_s``, on the earliest-free server; every member shares the
        (wait, service).  The batch is closed — it started as a unit, so
        later virtual-time arrivals queue behind it instead of joining."""
        self._prune(now)
        idx = min(range(len(self.free_at)), key=self.free_at.__getitem__)
        wait = max(self.free_at[idx] - now, 0.0)
        start = now + wait
        finish = start + exec_s
        self.free_at[idx] = finish
        self._tail[idx] = None
        self.inflight.extend([(start, finish)] * count)
        return wait, exec_s


@dataclasses.dataclass
class RequestResult:
    """One request's terminal record (served or shed).

    All ``*_s`` fields are seconds of the engine's virtual clock;
    ``latency_s`` is what the client experienced end to end (queue wait
    + execution + link legs + any retry delays), ground truth rather
    than the scheduler's prediction — the prediction that routed the
    request is preserved in ``decision``.  Appending fields (with
    defaults) is backward-compatible; the existing fields are pinned by
    the bit-for-bit engine-semantics tests.
    """

    req_id: int
    device: int           # tier index (EDGE/CLOUD for the 2-tier config);
                          # -1 when the request was shed
    n: int
    m_out: int
    latency_s: float      # queue wait + execution + (tx if offloaded);
                          # NaN when shed
    decision: MultiTierDecision
    wait_s: float = 0.0
    tier_name: str = ""
    # free-form client label (e.g. loadgen's scenario/workload-mix tag);
    # never read by routing — observability only
    tag: Optional[str] = None
    deadline_s: Optional[float] = None   # relative SLO, None = no deadline
    shed: bool = False    # dropped by deadline-aware admission control
    # the executed placement; None on the scalar path, whole(device) or
    # split(e, d) when the plan-aware scheduler routed the request —
    # ``device`` stays the DECODE tier either way
    plan: Optional[PlacementPlan] = None
    # fault-tolerance bookkeeping: dispatch attempts consumed (1 = clean
    # first-try service), tiers that failed this request along the way,
    # and — on shed responses — the backpressure hint telling the client
    # when re-submitting is predicted to succeed (ROADMAP 5c)
    attempts: int = 1
    failed_tiers: tuple = ()
    retry_after_s: Optional[float] = None

    @property
    def slo_met(self) -> Optional[bool]:
        """True/False for deadline-carrying requests, None otherwise."""
        if self.deadline_s is None:
            return None
        return (not self.shed) and self.latency_s <= self.deadline_s


class CollaborativeEngine:
    """Queue-aware N-tier serving under the generalized C-NMT rule.

    Construct with ``tiers=[...]``, each Tier carrying its own ``rtt_fn``
    when remote.  The PR-1 two-tier keywords ``edge=Tier(...),
    cloud=Tier(...), rtt_fn=...`` still work — they build the equivalent
    local edge + remote cloud pair, whose empty-queue decisions reproduce
    the seed engine (CNMTScheduler + single TxEstimator) bit-for-bit —
    but emit ``DeprecationWarning``.

    ``refit_interval`` (beyond paper) closes the feedback loop: every K
    completed requests an :class:`OnlineCalibrator` refits the
    scheduler's per-tier planes and the LinearN2M regressor from the
    observed (N, M_out, T_exe) samples; the scheduler then operates on
    its own model copies so ground-truth tier profiles stay untouched.
    """

    def __init__(self, *, n2m: LinearN2M,
                 tiers: Optional[Sequence[Tier]] = None,
                 edge: Optional[Tier] = None,
                 cloud: Optional[Tier] = None,
                 rtt_fn: Optional[Callable[[float], float]] = None,
                 bytes_per_token: int = 2,
                 hedge_margin_s: float = 0.0,
                 seed: int = 0,
                 refit_interval: Optional[int] = None,
                 links: Optional[LinkModel] = None,
                 inter_rtt_fns: Optional[Dict] = None,
                 activation: Optional[ActivationCostModel] = None,
                 allow_split: bool = False,
                 explore_eps: float = 0.0,
                 faults: Optional[FaultSchedule] = None,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None):
        if tiers is None:
            if edge is None or cloud is None or rtt_fn is None:
                raise ValueError("pass tiers=[...] or edge/cloud/rtt_fn")
            warnings.warn(
                "CollaborativeEngine(edge=, cloud=, rtt_fn=) is deprecated;"
                " pass tiers=[Tier(..., name='edge'), Tier(..., name='cloud',"
                " rtt_fn=...)] instead",
                DeprecationWarning, stacklevel=2)
            edge = dataclasses.replace(edge, name=edge.name or "edge",
                                       rtt_fn=None)
            cloud = dataclasses.replace(cloud, name=cloud.name or "cloud",
                                        rtt_fn=rtt_fn)
            tiers = [edge, cloud]
        self.tiers: List[Tier] = list(tiers)
        if not self.tiers:
            raise ValueError("need at least one tier")

        sched_tiers = []
        for t in self.tiers:
            model = t.profile.model
            if refit_interval is not None:
                model = dataclasses.replace(model)   # scheduler-owned copy
            tx = None
            if t.rtt_fn is not None:
                tx = TxEstimator(init_rtt_s=float(t.rtt_fn(0.0)),
                                 bandwidth_bps=t.bandwidth_bps)
            sched_tiers.append(SchedTier(
                t.name, model, tx, batch_size=t.batch_size,
                per_seq_overhead_s=t.per_seq_overhead_s))
        n2m_model = dataclasses.replace(n2m) if refit_interval is not None \
            else n2m
        self.scheduler = MultiTierScheduler(
            sched_tiers, n2m_model, bytes_per_token=bytes_per_token,
            hedge_margin_s=hedge_margin_s,
            links=links, activation=activation, allow_split=allow_split,
            explore_eps=explore_eps, explore_seed=seed)
        self.calibrator = None if refit_interval is None else \
            OnlineCalibrator(len(self.tiers), interval=refit_interval)
        # ground-truth RTT processes for inter-tier links, keyed (i, j);
        # the scheduler's LinkModel holds the *estimators* those feed
        self._inter_rtt_fns = dict(inter_rtt_fns or {})
        self.split_count = 0

        self._occ = [_TierOccupancy(t.servers, t.batch_size,
                                    t.per_seq_overhead_s)
                     for t in self.tiers]
        self.rng = np.random.default_rng(seed)
        self.results: List[RequestResult] = []
        # completion callback (loadgen hook): invoked with each terminal
        # RequestResult — after any fault-tolerant retry adjustments —
        # once per request, in completion order for ``submit`` and in
        # request order for the batch/continuous entry points.  Closed-
        # loop load generators hang their next-issue logic off it.
        # ``None`` (default) is a strict no-op: no behaviour change.
        self.on_complete: Optional[Callable[[RequestResult], None]] = None
        self.rejected = np.zeros(len(self.tiers), np.int64)
        self.shed_count = np.zeros(len(self.tiers), np.int64)
        self._t0 = time.perf_counter()
        self._next_id = 0

        # -- fault tolerance (ISSUE 8) ----------------------------------
        # ``faults`` is injection ground truth the dispatcher never routes
        # on; routing health comes from the per-tier breakers.  Arming
        # either knob switches ``submit`` to the retry/failover dispatch
        # loop; with an empty schedule that loop is pinned bit-for-bit
        # identical to the plain path (tests enforce it).
        self.faults = faults
        self.retry = retry
        self._ft = faults is not None or retry is not None \
            or breaker is not None
        self.breakers = make_breakers(len(self.tiers), breaker) \
            if self._ft else None
        # retry jitter draws from a dedicated stream so arming faults
        # never perturbs ``self.rng``'s modelled-execution draws
        self._fault_rng = np.random.default_rng(seed + 0x5EED) \
            if self._ft else None
        self.fault_failures = np.zeros(len(self.tiers), np.int64)
        self.retry_count = 0        # re-dispatches after a failed attempt
        self.failover_count = 0     # served requests that needed >1 attempt
        self.fault_lost = 0         # shed because retries ran out / expired
        self.decode_failovers = 0   # split decode legs re-homed mid-plan

    # convenience handles for the 2-tier configuration ---------------------
    @property
    def edge(self) -> Tier:
        return self.tiers[0]

    @property
    def cloud(self) -> Tier:
        return self.tiers[1]

    @property
    def tx(self) -> Optional[TxEstimator]:
        """First remote tier's link estimator (the §II-C state)."""
        for st in self.scheduler.tiers:
            if st.tx is not None:
                return st.tx
        return None

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _notify(self, res: RequestResult,
                tag: Optional[str]) -> RequestResult:
        """Terminal-result hook tail: attach the client's ``tag`` and
        fire ``on_complete``.  Called exactly once per request by the
        public entry points, after all latency adjustments."""
        if tag is not None:
            res.tag = tag
        if self.on_complete is not None:
            self.on_complete(res)
        return res

    # ------------------------------------------------------------- submit --
    def submit(self, tokens: np.ndarray, *, now_s: Optional[float] = None,
               deadline_s: Optional[float] = None,
               tag: Optional[str] = None) -> RequestResult:
        """Route and (virtually) serve one request.

        ``deadline_s`` is a relative SLO (seconds from ``now_s``): the
        deadline-aware admission path may shed the request (returned
        with ``shed=True`` and NaN latency) when no tier is predicted to
        meet it.  ``tag`` is a free-form client label copied onto the
        result (per-request tagging for load generators); routing never
        reads it.  ``on_complete`` (if set) fires with the final result
        before this returns.

        With fault tolerance armed (``faults``/``retry``/``breaker``)
        dispatch goes through the bounded-retry failover loop: a failed
        attempt trips the tier's circuit breaker, waits out the detection
        timeout + backoff, and re-runs the placement decision with
        unhealthy tiers excluded — the degradation ladder split →
        whole-remote → edge-only → shed.
        """
        now = self._now() if now_s is None else now_s
        if self._ft:
            res = self._submit_ft(tokens, now, deadline_s)
        else:
            res = self._submit_once(tokens, now, deadline_s)
        return self._notify(res, tag)

    def _submit_once(self, tokens: np.ndarray, now: float,
                     deadline_s: Optional[float]) -> RequestResult:
        """The fault-free dispatch path (pre-ISSUE-8 `submit` body)."""
        n = int(len(tokens))
        qd = [occ.queue_delay(now) for occ in self._occ]
        if self.scheduler._split_ready():
            d = self.scheduler.decide_plan(n, now, qd)
        else:
            d = self.scheduler.decide(n, now, qd)
        k = self._admit(d, now, deadline_s)
        if k < 0:                       # shed: never enters any queue
            return self._shed(n, d, deadline_s,
                              retry_after_s=self._retry_after(now))
        if (d.plan is not None and d.plan.is_split
                and k == d.plan.decode_tier
                and self._has_space(d.plan.encode_tier, now)):
            return self._submit_split(np.asarray(tokens, np.int32), d, now,
                                      deadline_s)
        tier = self.tiers[k]
        m_out, exec_s = tier.run(tokens, d.m_hat, self.rng)
        wait, service_s = self._occ[k].assign(now, exec_s)
        return self._complete(k, d, n, m_out, exec_s, wait, service_s, now,
                              deadline_s)

    # ---------------------------------------------- fault-tolerant submit --
    def _injected_failure(self, k: int, t: float) -> Optional[str]:
        """Injection check at dispatch: 'down' (crashed tier — connection
        refused, fails fast), 'blackhole' (silent packet loss on the
        client link — fails only after the full timeout), or None."""
        if self.faults is None:
            return None
        if self.faults.tier_down(k, t):
            return "down"
        if self.tiers[k].rtt_fn is not None \
                and self.faults.link_blackhole(k, t):
            return "blackhole"
        return None

    def _record_failure(self, k: int, t: float) -> None:
        self.fault_failures[k] += 1
        self.breakers[k].record_failure(t)

    def _record_success(self, k: int) -> None:
        """Successful completion on tier k; on breaker recovery
        (OPEN/HALF_OPEN → CLOSED) the tier's link state is stale by
        construction — an estimate warmed before/through the outage —
        so it is invalidated wholesale (satellite: TxEstimator reset)."""
        if not self.breakers[k].record_success():
            return
        st = self.scheduler.tiers[k]
        if st.tx is not None:
            st.tx.invalidate()
        if self.scheduler.links is not None:
            self.scheduler.links.invalidate(k)

    def _retry_after(self, now: float) -> float:
        """Backpressure hint for shed responses (ROADMAP 5c): predicted
        seconds until SOME tier could accept work — the best over tiers
        of queue drain, plus the breaker's probe cool-down when open."""
        best = math.inf
        for k, occ in enumerate(self._occ):
            t = occ.queue_delay(now)
            if self.breakers is not None and self.breakers[k].state == OPEN:
                t = max(t, self.breakers[k].time_to_probe(now))
            best = min(best, t)
        return best if math.isfinite(best) else 0.0

    def _submit_ft(self, tokens: np.ndarray, now: float,
                   deadline_s: Optional[float]) -> RequestResult:
        """Bounded-retry failover dispatch (tentpole).

        Per attempt: mask = this request's already-failed tiers ∪ tiers
        whose breaker refuses dispatch; re-run the placement decision
        excluding the mask; on an injected (or real executor) failure,
        trip the breaker, advance the virtual clock by the detection
        time + exponential backoff with jitter, and go again.  The
        request is shed when every tier is masked (with a
        ``retry_after_s`` hint), when the retry budget runs out, or when
        its deadline expires mid-retry."""
        n = int(len(tokens))
        now0 = now
        t = now
        budget = 0 if self.retry is None else self.retry.max_retries
        failed: list = []           # order preserved for the result record
        attempts = 0
        while True:
            attempts += 1
            mask = set(failed)
            mask.update(k for k in range(len(self.tiers))
                        if not self.breakers[k].allow(t))
            if len(mask) >= len(self.tiers):
                # every tier dark: shed with the backpressure hint
                self.fault_lost += 1
                d = MultiTierDecision(0, tuple([math.inf] * len(self.tiers)),
                                      self.scheduler.m_hat(n))
                return self._shed(n, d, deadline_s,
                                  retry_after_s=self._retry_after(t),
                                  attempts=attempts,
                                  failed_tiers=tuple(failed))
            exclude = frozenset(mask) if mask else None
            qd = [occ.queue_delay(t) for occ in self._occ]
            if self.scheduler._split_ready():
                d = self.scheduler.decide_plan(n, t, qd, exclude=exclude)
            else:
                d = self.scheduler.decide(n, t, qd, exclude=exclude)
            rem_dl = None if deadline_s is None \
                else deadline_s - (t - now0)
            if rem_dl is not None and rem_dl <= 0.0:
                self.fault_lost += 1
                return self._shed(n, d, deadline_s,
                                  retry_after_s=self._retry_after(t),
                                  attempts=attempts,
                                  failed_tiers=tuple(failed))
            allowed = (lambda j, m=frozenset(mask): j not in m) \
                if mask else None
            k = self._admit(d, t, rem_dl, allowed=allowed)
            if k < 0:               # admission shed (queues, not faults)
                return self._shed(n, d, deadline_s,
                                  retry_after_s=self._retry_after(t),
                                  attempts=attempts,
                                  failed_tiers=tuple(failed))
            if (d.plan is not None and d.plan.is_split
                    and k == d.plan.decode_tier
                    and self._injected_failure(d.plan.encode_tier, t) is None
                    and self._has_space(d.plan.encode_tier, t)):
                res = self._submit_split(np.asarray(tokens, np.int32), d, t,
                                         deadline_s)
                # res.device is the tier that actually decoded — the
                # planned one, or the failover target when it died mid-plan
                return self._finish_ft(res, res.device, t, now0, attempts,
                                       failed)
            tier = self.tiers[k]
            fail = self._injected_failure(k, t)
            m_out = exec_s = None
            if fail is None:
                try:
                    m_out, exec_s = tier.run(tokens, d.m_hat, self.rng)
                except Exception:
                    fail = "down"   # a real executor raising = crashed
            if fail is not None:
                self._record_failure(k, t)
                failed.append(k)
                detect = RetryPolicy().detect_s(fail == "blackhole") \
                    if self.retry is None \
                    else self.retry.detect_s(fail == "blackhole")
                if attempts > budget:
                    self.fault_lost += 1
                    return self._shed(n, d, deadline_s,
                                      retry_after_s=self._retry_after(
                                          t + detect),
                                      attempts=attempts,
                                      failed_tiers=tuple(failed))
                t = t + detect + self.retry.backoff(attempts - 1,
                                                    self._fault_rng)
                self.retry_count += 1
                continue
            if self.faults is not None:
                s = self.faults.slowdown(k, t)
                if s != 1.0:        # straggler window: degraded, not failed
                    exec_s *= s
            wait, service_s = self._occ[k].assign(t, exec_s)
            res = self._complete(k, d, n, m_out, exec_s, wait, service_s, t,
                                 deadline_s)
            return self._finish_ft(res, k, t, now0, attempts, failed)

    def _finish_ft(self, res: RequestResult, k: int, t: float, now0: float,
                   attempts: int, failed: list) -> RequestResult:
        """Shared success tail of the failover loop: breaker/link-state
        bookkeeping plus folding the retry delays into the latency."""
        self._record_success(k)
        # combine with what _submit_split already recorded (a decode-leg
        # failover inside the plan counts as its own extra attempt)
        res.attempts += attempts - 1
        res.failed_tiers = tuple(failed) + res.failed_tiers
        if t != now0:               # detection + backoff time is real
            res.latency_s += t - now0
        if res.attempts > 1:
            self.failover_count += 1
        return res

    # -------------------------------------------------------- split plans --
    def _ship_time(self, e: int, k: int, now: float,
                   payload_bytes: float) -> float:
        """True one-way activation-shipping time e→k, feeding the link's
        estimator when a ground-truth RTT process is registered."""
        fn = self._inter_rtt_fns.get((e, k))
        est = self.scheduler.links.link(e, k)
        if fn is not None:
            rtt = float(fn(now))
            bw = est.bandwidth_bps if est is not None else 100e6
            if self.faults is not None:
                # an inter-tier hop degrades when EITHER endpoint's link
                # is in an episode; overlapping episodes compound
                for end in (e, k):
                    rf, bf = self.faults.link_factors(end, now)
                    if rf != 1.0 or bf != 1.0:
                        rtt *= rf
                        bw *= bf
            if est is not None:
                self.scheduler.links.observe(e, k, now, rtt)
            return rtt / 2.0 + payload_bytes * 8.0 / bw
        # no truth process: the estimate is the model (multi-hop included)
        return self.scheduler.links.tx_time(e, k, now, payload_bytes,
                                            one_way=True)

    def _client_leg(self, k: int, now: float, tokens: float) -> float:
        """One-way client-link time for ``tokens`` tokens to/from tier k
        (0 for a local tier): rtt/2 + serialization."""
        tier = self.tiers[k]
        if tier.rtt_fn is None:
            return 0.0
        rtt = float(tier.rtt_fn(now))
        bw = tier.bandwidth_bps
        if self.faults is not None:
            rf, bf = self.faults.link_factors(k, now)
            if rf != 1.0 or bf != 1.0:
                rtt *= rf
                bw *= bf
        tx = self.scheduler.tiers[k].tx
        if tx is not None:
            tx.observe(now, rtt)
        payload = float(bytes_for_tokens(tokens, self.scheduler.bytes_per_token))
        return rtt / 2.0 + payload * 8.0 / bw

    def _submit_split(self, tokens: np.ndarray, d: MultiTierDecision,
                      now: float, deadline_s: Optional[float]
                      ) -> RequestResult:
        """Execute a split plan: encode on tier e, ship the encoder
        states over the e→d link, decode on tier d.  Both legs' occupancy
        is charged (the decode leg joining tier d's virtual queue at its
        states-arrival time), and every traversed link feeds its RTT
        estimator.  With real split executors on both tiers the leg times
        are measured wall-clock and the payload is the states' actual
        wire size; otherwise legs are modelled from the profile planes
        (``DeviceProfile.true_leg_times``) and the payload priced by the
        scheduler's ActivationCostModel."""
        plan = d.plan
        e, k = plan.encode_tier, plan.decode_tier
        enc_tier = self.tiers[e]
        n = int(len(tokens))
        real = (enc_tier.encode_executor is not None
                and self.tiers[k].decode_executor is not None)
        if real:
            t0 = time.perf_counter()
            states = enc_tier.encode_executor(tokens)
            t_enc = time.perf_counter() - t0
            payload = float(states.payload_bytes())
        else:
            states = None
            t_enc = float(enc_tier.profile.true_leg_times(
                float(n), d.m_hat, self.rng)[0])
            payload = float(self.scheduler.activation.payload_bytes(n))
        if self.faults is not None:
            s = self.faults.slowdown(e, now)
            if s != 1.0:
                t_enc *= s

        up = self._client_leg(e, now, n)
        wait_e, svc_e = self._occ[e].assign(now, t_enc)
        ship = self._ship_time(e, k, now, payload)
        dec_arrival = now + up + wait_e + svc_e + ship

        # decode-leg failover (tentpole): the planned decode tier died
        # while the encoder states were in flight.  The states survive at
        # the ENCODE tier, so recovery re-ships them to a healthy decode
        # target (possibly tier e itself — decode-local) instead of
        # re-running the whole request from the prompt.
        k_exec, dec_dispatch, extra, failed_dec = k, dec_arrival, 0.0, ()
        if self._ft:
            fail = self._injected_failure(k, dec_arrival)
            if fail is not None:
                self._record_failure(k, dec_arrival)
                pol = self.retry if self.retry is not None else RetryPolicy()
                detect = pol.detect_s(fail == "blackhole")
                k2 = -1 if self.retry is None else \
                    self._decode_failover_target(e, k, dec_arrival + detect,
                                                 real, d.m_hat, payload)
                if k2 < 0:          # no retries, or nowhere healthy left
                    self.fault_lost += 1
                    return self._shed(
                        n, d, deadline_s,
                        retry_after_s=self._retry_after(dec_arrival + detect),
                        attempts=2, failed_tiers=(k,))
                backoff = pol.backoff(0, self._fault_rng)
                t2 = dec_arrival + detect + backoff
                reship = 0.0 if k2 == e else \
                    self._ship_time(e, k2, t2, payload)
                k_exec, dec_dispatch = k2, t2 + reship
                extra = detect + backoff + reship
                failed_dec = (k,)
                self.decode_failovers += 1
                self.retry_count += 1

        dec_tier = self.tiers[k_exec]
        if real and dec_tier.decode_executor is not None:
            t0 = time.perf_counter()
            m_out, _ = dec_tier.decode_executor(states)
            t_dec = time.perf_counter() - t0
            m_out = int(m_out)
        else:
            t_dec = float(dec_tier.profile.true_leg_times(
                float(n), d.m_hat, self.rng)[1])
            m_out = int(max(round(d.m_hat), 1))
        if self.faults is not None:
            s = self.faults.slowdown(k_exec, dec_dispatch)
            if s != 1.0:
                t_dec *= s

        wait_d, svc_d = self._occ[k_exec].assign(dec_dispatch, t_dec)
        down = self._client_leg(k_exec, now, m_out)
        latency = up + wait_e + svc_e + ship + extra + wait_d + svc_d + down

        res = RequestResult(self._next_id, k_exec, n, m_out, latency, d,
                            wait_s=wait_e + wait_d, tier_name=dec_tier.name,
                            deadline_s=deadline_s,
                            plan=(plan if k_exec == k
                                  else PlacementPlan.split(e, k_exec)),
                            attempts=2 if failed_dec else 1,
                            failed_tiers=failed_dec)
        self._next_id += 1
        self.results.append(res)
        self.split_count += 1
        # calibrator feedback skipped: leg samples are half-planes
        # (alpha_n-only / alpha_m-only) and would corrupt the full fit
        return res

    def _decode_failover_target(self, e: int, k_failed: int, t: float,
                                need_real: bool, m_hat: float,
                                payload: float) -> int:
        """Cheapest healthy tier to re-home a split plan's decode leg on:
        predicted queue drain + states re-ship + decode-leg cost.  With
        REAL split executors only decode-capable tiers can consume the
        shipped states, so those are preferred; -1 when nothing healthy
        remains (caller sheds)."""
        cands = [j for j in range(len(self.tiers))
                 if j != k_failed and self.breakers[j].allow(t)
                 and self._injected_failure(j, t) is None]
        if not cands:
            return -1
        if need_real:
            real_c = [j for j in cands
                      if self.tiers[j].decode_executor is not None]
            if real_c:
                cands = real_c

        def cost(j: int) -> float:
            st = self.scheduler.tiers[j]
            t_dec = st.model.alpha_m * m_hat + 0.5 * st.model.beta
            ship = 0.0 if j == e else self.scheduler.links.tx_time(
                e, j, t, payload, one_way=True)
            return self._occ[j].queue_delay(t) + ship + t_dec

        return min(cands, key=cost)

    def _shed(self, n: int, d: MultiTierDecision,
              deadline_s: Optional[float], *,
              retry_after_s: Optional[float] = None,
              attempts: int = 1,
              failed_tiers: tuple = ()) -> RequestResult:
        res = RequestResult(self._next_id, -1, n, 0, float("nan"), d,
                            deadline_s=deadline_s, shed=True,
                            attempts=attempts, failed_tiers=failed_tiers,
                            retry_after_s=retry_after_s)
        self._next_id += 1
        self.results.append(res)
        return res

    def _complete(self, k: int, d: MultiTierDecision, n: int, m_out: int,
                  exec_s: float, wait: float, service_s: float, now: float,
                  deadline_s: Optional[float]) -> RequestResult:
        """Shared completion bookkeeping: link terms, result record,
        online-calibration feedback.  ``exec_s`` is the execution sample
        fed to the calibrator (for a real batch: the batch wall-clock,
        an upper bound on the member's solo cost — feedback noise the
        refit's robust plane fit tolerates)."""
        tier = self.tiers[k]
        if tier.rtt_fn is not None:
            rtt = float(tier.rtt_fn(now))
            payload = float(bytes_for_tokens(
                n + m_out, self.scheduler.bytes_per_token))
            tx = self.scheduler.tiers[k].tx
            bw = tx.bandwidth_bps
            if self.faults is not None:
                # degradation episode on the client link: the TRUE rtt
                # spikes / bandwidth collapses; the estimator observes
                # the degraded value — that is what measurement sees
                rf, bf = self.faults.link_factors(k, now)
                if rf != 1.0 or bf != 1.0:
                    rtt *= rf
                    bw *= bf
            net = service_s + rtt + payload * 8.0 / bw
            # §II-C timestamp mechanism, per link.  Stamped with the
            # submit clock (monotone across calls): this synchronous
            # engine ingests the sample when it resolves the request, and
            # a completion-time stamp would let one long request park the
            # estimator's clock in the virtual future, making the stale
            # guard drop every faster request's sample until then.
            tx.observe(now, rtt)
        else:
            net = service_s
        latency = wait + net

        res = RequestResult(self._next_id, k, n, m_out, latency, d,
                            wait_s=wait, tier_name=tier.name,
                            deadline_s=deadline_s,
                            plan=(PlacementPlan.whole(k)
                                  if d.plan is not None else None))
        self._next_id += 1
        self.results.append(res)
        if self.calibrator is not None:
            if self.calibrator.record(k, n, m_out, exec_s):
                self.calibrator.refit(
                    [st.model for st in self.scheduler.tiers],
                    self.scheduler.n2m)
        return res

    # -------------------------------------------------------- submit_batch --
    def submit_batch(self, requests: Sequence[np.ndarray], *,
                     now_s: Optional[float] = None,
                     deadline_s: Optional[float] = None,
                     tag: Optional[str] = None,
                     ) -> List[RequestResult]:
        """Route and serve a slot of CONCURRENT requests with real
        batched execution.

        Each request is routed/admitted individually (same decision rule
        and deadline shedding as :meth:`submit`); requests landing on the
        same tier are drained through a length-bucketed
        :class:`TokenBatcher` into padded blocks of up to that tier's
        ``batch_size`` and — where the tier carries a
        ``batched_executor`` — each block runs as ONE real batched
        generate whose measured wall-clock is booked as a single batch
        occupancy (``assign_batch``).  Tiers without a batched executor
        fall back to the per-request path.  Results come back in request
        order.

        Concurrent-slot semantics: all members are decided at the same
        ``now`` (they arrived together), but earlier same-slot members
        COUNT against the bounded queues (``pending``), so a slot cannot
        oversubscribe a capacity the sequential path would enforce.
        Deadline feasibility still uses slot-start predictions — the
        queueing a member induces on its batch peers shows up in their
        measured latency, not in their admission test.
        """
        now = self._now() if now_s is None else now_s
        if self._ft:
            # fault-tolerant batch serving degenerates to per-request
            # failover dispatch: a member's failure/retry timeline is
            # per-request state a shared batched generate cannot carry
            return [self._notify(self._submit_ft(np.asarray(t, np.int32),
                                                 now, deadline_s), tag)
                    for t in requests]
        results: List[Optional[RequestResult]] = [None] * len(requests)
        groups: Dict[int, List[tuple]] = {}
        pending = [0] * len(self.tiers)
        split_ready = self.scheduler._split_ready()
        for i, tokens in enumerate(requests):
            tokens = np.asarray(tokens, np.int32)
            n = int(len(tokens))
            qd = [occ.queue_delay(now) for occ in self._occ]
            d = (self.scheduler.decide_plan(n, now, qd) if split_ready
                 else self.scheduler.decide(n, now, qd))
            k = self._admit(d, now, deadline_s, pending)
            if k < 0:
                results[i] = self._shed(n, d, deadline_s)
                continue
            pending[k] += 1
            if (d.plan is not None and d.plan.is_split
                    and k == d.plan.decode_tier
                    and self._has_space(d.plan.encode_tier, now, pending)):
                # split members run per-request: their decode leg enters
                # tier k's virtual queue at its own states-arrival time,
                # which a shared batch block could not represent
                results[i] = self._submit_split(tokens, d, now, deadline_s)
                continue
            groups.setdefault(k, []).append((i, tokens, d))

        for k, members in groups.items():
            tier = self.tiers[k]
            if tier.batched_executor is None:
                for i, toks, d in members:
                    m_out, exec_s = tier.run(toks, d.m_hat, self.rng)
                    wait, service_s = self._occ[k].assign(now, exec_s)
                    results[i] = self._complete(
                        k, d, len(toks), m_out, exec_s, wait, service_s,
                        now, deadline_s)
                continue
            tb = TokenBatcher(max_batch=max(tier.batch_size, 1))
            for j, (_, toks, _) in enumerate(members):
                tb.add(j, toks)
            while (nb := tb.next_batch()) is not None:
                ids, block = nb
                lens = [len(members[j][1]) for j in ids]
                t0 = time.perf_counter()
                outs = tier.batched_executor(block, lens)
                exec_s = time.perf_counter() - t0
                wait, service_s = self._occ[k].assign_batch(
                    now, exec_s, len(ids))
                for j, (m_out, _) in zip(ids, outs):
                    i, toks, d = members[j]
                    results[i] = self._complete(
                        k, d, len(toks), int(m_out), exec_s, wait,
                        service_s, now, deadline_s)
        return [self._notify(r, tag) for r in results]

    # ---------------------------------------------------- serve_continuous --
    def serve_continuous(self, requests: Sequence[np.ndarray], *,
                         arrival_s: Optional[Sequence[float]] = None,
                         deadline_s: Union[None, float,
                                           Sequence[Optional[float]]] = None,
                         max_new: int = 16,
                         refill: bool = True) -> List[RequestResult]:
        """Serve a virtual arrival schedule with CONTINUOUS in-flight
        batching on every tier that carries a ``continuous_session``.

        The event loop interleaves three things per tier step:

        1. requests whose ``arrival_s`` has passed are routed
           (``scheduler.decide`` with live backlog estimates) and admitted
           under the same deadline-aware shed/reroute rule as ``submit``
           — slot-table space (free slots, then the bounded wait queue)
           standing in for server space;
        2. freed slots are refilled from the tier's wait queue — EDF
           across deadline values, FIFO within a deadline class — by
           prefilling the dequeued prompts INTO the live batch;
        3. one decode step runs over the whole slot table; rows that
           finish evict and complete at the tier's clock.

        Each continuous tier's virtual clock advances by its *measured*
        prefill/step wall-clock, so latencies are real compute laid onto
        the modelled arrival process (warm the session's shapes first
        when benchmarking — compiles are billed to the requests that
        trigger them).  Tiers without a session serve routed requests
        through the usual virtual-time path, so mixed fleets work.

        ``refill=False`` is the PR 3 block-to-completion baseline: a
        tier admits only into an EMPTY table, and the block runs until
        every member finished.  ``deadline_s`` is a scalar applied to all
        requests or a per-request sequence.  Results come back in request
        order; shed requests carry a shed record (``shed=True``).
        """
        sessions = {k: t.continuous_session
                    for k, t in enumerate(self.tiers)
                    if t.continuous_session is not None}
        if not sessions:
            raise ValueError("serve_continuous needs at least one tier "
                             "with a continuous_session")
        n_req = len(requests)
        if arrival_s is None:
            arrival_s = [0.0] * n_req
        if deadline_s is None or isinstance(deadline_s, (int, float)):
            deadlines = [deadline_s] * n_req
        else:
            deadlines = list(deadline_s)
        order = sorted(range(n_req), key=lambda i: (arrival_s[i], i))
        results: List[Optional[RequestResult]] = [None] * n_req
        # per-tier wait queue: (deadline-class key, fifo seq, req, ...)
        queues: Dict[int, list] = {k: [] for k in sessions}
        tclock = {k: 0.0 for k in sessions}   # tier virtual clock
        svc_ewma = {k: 0.0 for k in sessions}
        inflight: Dict[int, tuple] = {}       # req -> (k, d, n, arr, dl, t_admit)
        seq = 0
        ptr = 0
        now = 0.0

        def queue_est(k: int) -> float:
            if k not in sessions:
                return self._occ[k].queue_delay(now)
            s = sessions[k]
            if s.free_slots > len(queues[k]):
                return max(tclock[k] - now, 0.0)
            waves = 1 + len(queues[k]) // max(s.max_slots, 1)
            return max(tclock[k] - now, 0.0) + svc_ewma[k] * waves

        def drain(k: int) -> None:
            """Refill free slots of tier k from its wait queue, then run
            one decode step; completions land at the advanced clock."""
            s = sessions[k]
            if queues[k] and (refill or s.live_count == 0):
                take = min(s.free_slots, len(queues[k]))
                if take:
                    wave = [heapq.heappop(queues[k]) for _ in range(take)]
                    t0 = time.perf_counter()
                    s.admit([w[3] for w in wave], max_new=max_new,
                            req_ids=[w[2] for w in wave])
                    tclock[k] = now + (time.perf_counter() - t0)
                    for _, _, i, toks, d, arr, dl in wave:
                        inflight[i] = (k, d, len(toks), arr, dl, now)
            if s.live_count:
                t0 = time.perf_counter()
                _, finished = s.step()
                tclock[k] = max(tclock[k], now) + (time.perf_counter() - t0)
                for rid, m_out, _toks in finished:
                    k2, d, n, arr, dl, t_adm = inflight.pop(rid)
                    wait = t_adm - arr
                    service = tclock[k] - t_adm
                    svc_ewma[k] = service if svc_ewma[k] == 0.0 else \
                        0.8 * svc_ewma[k] + 0.2 * service
                    results[rid] = self._complete(
                        k2, d, n, m_out, service, wait, service,
                        tclock[k], dl)

        while ptr < n_req or inflight or any(queues.values()):
            cand = [tclock[k] for k in sessions
                    if queues[k] or sessions[k].live_count]
            if ptr < n_req:
                cand.append(arrival_s[order[ptr]])
            now = max(now, min(cand))

            while ptr < n_req and arrival_s[order[ptr]] <= now:
                i = order[ptr]
                ptr += 1
                toks = np.asarray(requests[i], np.int32).reshape(-1)
                n = int(len(toks))
                dl = deadlines[i]
                qd = [queue_est(j) for j in range(len(self.tiers))]
                d = self.scheduler.decide(n, now, qd)

                def cont_space(j: int, n: int = n) -> bool:
                    if j not in sessions:
                        return self._has_space(j, now)
                    s = sessions[j]
                    if n + max_new > s.max_len or n == 0:
                        return False      # cannot fit this tier's table
                    cap = self.tiers[j].queue_capacity
                    backlog = len(queues[j]) - s.free_slots
                    return cap is None or backlog < cap

                k = self._admit(d, now, dl, has_space=cont_space)
                if k < 0 or (k in sessions and not cont_space(k)):
                    # deadline-less overflow keeps _admit's "keep the
                    # choice" semantics for server tiers, but a slot
                    # table has nowhere to force-enqueue an oversized
                    # prompt — record the drop instead of crashing
                    results[i] = self._shed(n, d, dl)
                    continue
                if k in sessions:
                    vocab = sessions[k].model.cfg.vocab_size
                    dl_key = dl if dl is not None else math.inf
                    heapq.heappush(queues[k],
                                   (dl_key, seq, i, np.minimum(toks, vocab - 1),
                                    d, now, dl))
                    seq += 1
                else:
                    m_out, exec_s = self.tiers[k].run(toks, d.m_hat, self.rng)
                    wait, service_s = self._occ[k].assign(now, exec_s)
                    results[i] = self._complete(k, d, n, m_out, exec_s,
                                                wait, service_s, now, dl)

            for k in sessions:
                if tclock[k] <= now and (queues[k]
                                         or sessions[k].live_count):
                    drain(k)
        return [self._notify(r, None) for r in results]  # type: ignore[return-value]

    def _admit(self, d: MultiTierDecision, now: float,
               deadline_s: Optional[float] = None,
               pending: Optional[List[int]] = None,
               has_space: Optional[Callable[[int], bool]] = None,
               allowed: Optional[Callable[[int], bool]] = None) -> int:
        """Bounded-FIFO admission: re-route from a full tier to the
        next-best tier with space; if everything is full, keep the choice
        and count the rejection.  Deadline-carrying requests re-route
        only to tiers predicted to meet the deadline and are shed
        (returns -1) when none can — predicted-completion-vs-deadline
        instead of blind force-enqueue.

        ``pending`` (per-tier counts) charges same-slot members already
        admitted by ``submit_batch`` against the bounded queues, so one
        concurrent slot cannot oversubscribe a capacity the sequential
        ``submit`` path would have enforced.  ``has_space`` overrides the
        space predicate per tier index — ``serve_continuous`` plugs in
        slot-table occupancy (free slots + bounded wait queue) for its
        continuous tiers while keeping this exact shed/reroute rule."""
        space = has_space if has_space is not None else \
            (lambda j: self._has_space(j, now, pending))
        if allowed is not None:
            # fault-tolerant dispatch: a masked (unhealthy) tier is never
            # a re-route target, not even as deadline-less force-enqueue
            base = space
            space = lambda j: allowed(j) and base(j)   # noqa: E731
        k = d.tier
        if space(k):
            return k
        ranked = sorted(range(len(self.tiers)), key=lambda j: d.t_pred[j])
        if deadline_s is None:
            for j in ranked:
                if space(j):
                    return j
            self.rejected[k] += 1
            return k
        spaced = [j for j in ranked if space(j)]
        feasible = [j for j in spaced if d.t_pred[j] <= deadline_s]
        if feasible:
            return feasible[0]
        if not spaced and d.t_pred[k] <= deadline_s:
            self.rejected[k] += 1       # full everywhere but still on time
            return k
        self.shed_count[k] += 1
        return -1

    def _has_space(self, k: int, now: float,
                   pending: Optional[List[int]] = None) -> bool:
        cap = self.tiers[k].queue_capacity
        extra = 0 if pending is None else pending[k]
        if cap is None:
            return True
        # same-slot pending members first fill the ACTUALLY-free batch
        # slots (free servers x batch_size), then charge the bounded
        # queue — mirroring what sequential submits would enforce
        slots = (self._occ[k].free_servers(now)
                 * max(self.tiers[k].batch_size, 1))
        if slots and extra < slots:
            return True          # a server (batch slot) is free right now
        return self._occ[k].queue_len(now) + extra - slots < cap

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict[str, object]:
        """Aggregate serving stats.  Latency percentiles and routing
        fractions are over *served* requests; ``shed`` counts the
        deadline-dropped ones and ``slo_attainment`` is the fraction of
        deadline-carrying requests that completed within their deadline
        (1.0 when none carried a deadline)."""
        if not self.results:
            return {}
        served = [r for r in self.results if not r.shed]
        n_shed = len(self.results) - len(served)
        with_dl = [r for r in self.results if r.deadline_s is not None]
        slo = 1.0 if not with_dl else \
            float(sum(bool(r.slo_met) for r in with_dl)) / len(with_dl)
        if not served:
            out = {"requests": len(self.results), "shed": n_shed,
                   "slo_attainment": slo}
            if self._ft:
                out.update(self._fault_stats(0))
            return out
        lat = np.array([r.latency_s for r in served])
        wait = np.array([r.wait_s for r in served])
        dev = np.array([r.device for r in served])
        remote = np.array([t.rtt_fn is not None for t in self.tiers])
        tx = self.tx
        out = {
            "requests": len(self.results),
            "total_latency_s": float(lat.sum()),
            "mean_latency_s": float(lat.mean()),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "mean_wait_s": float(wait.mean()),
            "offload_frac": float(np.mean(remote[dev])),
            "tier_frac": {t.name: float(np.mean(dev == k))
                          for k, t in enumerate(self.tiers)},
            "rejected": int(self.rejected.sum()),
            "shed": n_shed,
            "slo_attainment": slo,
            "split": self.split_count,
            "tx_estimate_s": 0.0 if tx is None else tx.rtt(0.0),
        }
        if self._ft:
            out.update(self._fault_stats(len(served)))
        return out

    def _fault_stats(self, n_served: int) -> Dict[str, object]:
        """Fault-tolerance observability (only reported when armed)."""
        return {
            "availability": (n_served / len(self.results)
                             if self.results else 1.0),
            "fault_failures": int(self.fault_failures.sum()),
            "retries": self.retry_count,
            "failovers": self.failover_count,
            "decode_failovers": self.decode_failovers,
            "fault_lost": self.fault_lost,
            "breaker_opens": sum(b.n_opens for b in self.breakers),
            "breaker_probes": sum(b.n_probes for b in self.breakers),
            "mean_attempts": (float(np.mean([r.attempts
                                             for r in self.results]))
                              if self.results else 1.0),
        }
