"""N-tier collaborative serving engine: the C-NMT decision rule
generalized to a fleet of heterogeneous compute tiers with per-tier
queues — the production integration of ``repro.core``.

Each :class:`Tier` is one place an inference can run (on-device NPU,
edge gateway, regional pod, central cloud, ...) and carries

* a latency plane (``DeviceProfile`` — measured by ``core.calibration``
  or priced from dry-run rooflines via ``device_from_roofline``),
* optionally a REAL executor callable (a ``repro.nmt`` translate fn or a
  :class:`~repro.runtime.serving.GenerationSession`) — the engine then
  measures actual wall-clock; without one the tier is MODELLED and the
  engine simulates the latency (how TPU-pod tiers we cannot run locally
  participate, mirroring the paper's simulated network + real inference
  testbed),
* optionally a live link (``rtt_fn``) — its T_tx is tracked through
  §II-C timestamped samples of *offloaded* requests only, one
  :class:`TxEstimator` per link,
* a concurrency limit (``servers``) and a bounded FIFO queue
  (``queue_capacity``) — the engine keeps per-tier occupancy in virtual
  time, so a busy tier's queue delay enters the decision rule:

      d_tgt = argmin_k [ T_queue,k + T_tx,k + T_exe,k(N, M_hat) ]

With two tiers (local edge + one cloud behind a link) and empty queues
this reduces exactly to paper Eq. (1)/(2); the regression tests pin the
reduction bit-for-bit against the seed engine semantics.  An optional
online-feedback loop (``refit_interval``) refits the scheduler's planes
and the N->M regressor from observed completions every K requests.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.calibration import OnlineCalibrator
from repro.core.latency_model import DeviceProfile, bytes_for_tokens
from repro.core.length_regressor import LinearN2M
from repro.core.scheduler import (
    MultiTierDecision,
    MultiTierScheduler,
    SchedTier,
)
from repro.core.tx_estimator import TxEstimator


@dataclasses.dataclass
class Tier:
    """One compute tier (device NPU / edge gateway / regional pod / cloud).

    ``rtt_fn(now) -> rtt_seconds`` marks a REMOTE tier (a ConnectionProfile's
    ``rtt_at`` in experiments; a real prober in deployment); None marks a
    local tier.  ``servers`` bounds concurrent executions; up to
    ``queue_capacity`` further requests wait in FIFO order (None =
    unbounded).
    """

    profile: DeviceProfile
    executor: Optional[Callable] = None   # tokens -> (m_out, out_tokens)
    name: Optional[str] = None
    rtt_fn: Optional[Callable[[float], float]] = None
    servers: int = 1
    queue_capacity: Optional[int] = None
    bandwidth_bps: float = 100e6

    def __post_init__(self):
        if self.name is None:
            self.name = self.profile.name
        if self.servers < 1:
            raise ValueError("servers must be >= 1")

    def run(self, tokens: np.ndarray, m_hat: float,
            rng: np.random.Generator) -> tuple[int, float]:
        """Returns (output_len, execution_seconds)."""
        if self.executor is not None:
            t0 = time.perf_counter()
            m_out, _ = self.executor(tokens)
            return int(m_out), time.perf_counter() - t0
        # modelled: draw the true time around the plane at predicted M
        t = float(self.profile.true_time(float(len(tokens)), m_hat, rng))
        return int(max(round(m_hat), 1)), t


class _TierOccupancy:
    """Virtual-time FIFO bookkeeping for one tier: ``free_at`` holds each
    server's next-free time; assigned-but-not-started requests count
    against the bounded queue."""

    def __init__(self, servers: int):
        self.free_at = [0.0] * servers      # heap
        self.inflight: List[tuple] = []     # (start, finish), pruned lazily

    def _prune(self, now: float) -> None:
        self.inflight = [(s, f) for s, f in self.inflight if f > now]

    def queue_delay(self, now: float) -> float:
        d = self.free_at[0] - now
        return d if d > 0.0 else 0.0

    def queue_len(self, now: float) -> int:
        self._prune(now)
        return sum(1 for s, _ in self.inflight if s > now)

    def assign(self, now: float, exec_s: float) -> float:
        """FIFO-assign one request; returns its wait (T_queue)."""
        self._prune(now)                 # keep inflight bounded over time
        earliest = heapq.heappop(self.free_at)
        wait = earliest - now
        if wait <= 0.0:
            wait = 0.0
        start = now + wait
        finish = start + exec_s
        heapq.heappush(self.free_at, finish)
        self.inflight.append((start, finish))
        return wait


@dataclasses.dataclass
class RequestResult:
    req_id: int
    device: int           # tier index (EDGE/CLOUD for the 2-tier config)
    n: int
    m_out: int
    latency_s: float      # queue wait + execution + (tx if offloaded)
    decision: MultiTierDecision
    wait_s: float = 0.0
    tier_name: str = ""


class CollaborativeEngine:
    """Queue-aware N-tier serving under the generalized C-NMT rule.

    Construct either with ``tiers=[...]`` (each Tier carrying its own
    ``rtt_fn`` when remote) or with the paper-faithful two-tier keywords
    ``edge=Tier(...), cloud=Tier(...), rtt_fn=...`` — the latter builds a
    local edge + remote cloud pair whose empty-queue decisions reproduce
    the seed engine (CNMTScheduler + single TxEstimator) bit-for-bit.

    ``refit_interval`` (beyond paper) closes the feedback loop: every K
    completed requests an :class:`OnlineCalibrator` refits the
    scheduler's per-tier planes and the LinearN2M regressor from the
    observed (N, M_out, T_exe) samples; the scheduler then operates on
    its own model copies so ground-truth tier profiles stay untouched.
    """

    def __init__(self, *, n2m: LinearN2M,
                 tiers: Optional[Sequence[Tier]] = None,
                 edge: Optional[Tier] = None,
                 cloud: Optional[Tier] = None,
                 rtt_fn: Optional[Callable[[float], float]] = None,
                 bytes_per_token: int = 2,
                 hedge_margin_s: float = 0.0,
                 seed: int = 0,
                 refit_interval: Optional[int] = None):
        if tiers is None:
            if edge is None or cloud is None or rtt_fn is None:
                raise ValueError("pass tiers=[...] or edge/cloud/rtt_fn")
            edge = dataclasses.replace(edge, name=edge.name or "edge",
                                       rtt_fn=None)
            cloud = dataclasses.replace(cloud, name=cloud.name or "cloud",
                                        rtt_fn=rtt_fn)
            tiers = [edge, cloud]
        self.tiers: List[Tier] = list(tiers)
        if not self.tiers:
            raise ValueError("need at least one tier")

        sched_tiers = []
        for t in self.tiers:
            model = t.profile.model
            if refit_interval is not None:
                model = dataclasses.replace(model)   # scheduler-owned copy
            tx = None
            if t.rtt_fn is not None:
                tx = TxEstimator(init_rtt_s=float(t.rtt_fn(0.0)),
                                 bandwidth_bps=t.bandwidth_bps)
            sched_tiers.append(SchedTier(t.name, model, tx))
        n2m_model = dataclasses.replace(n2m) if refit_interval is not None \
            else n2m
        self.scheduler = MultiTierScheduler(
            sched_tiers, n2m_model, bytes_per_token=bytes_per_token,
            hedge_margin_s=hedge_margin_s)
        self.calibrator = None if refit_interval is None else \
            OnlineCalibrator(len(self.tiers), interval=refit_interval)

        self._occ = [_TierOccupancy(t.servers) for t in self.tiers]
        self.rng = np.random.default_rng(seed)
        self.results: List[RequestResult] = []
        self.rejected = np.zeros(len(self.tiers), np.int64)
        self._t0 = time.perf_counter()
        self._next_id = 0

    # convenience handles for the 2-tier configuration ---------------------
    @property
    def edge(self) -> Tier:
        return self.tiers[0]

    @property
    def cloud(self) -> Tier:
        return self.tiers[1]

    @property
    def tx(self) -> Optional[TxEstimator]:
        """First remote tier's link estimator (the §II-C state)."""
        for st in self.scheduler.tiers:
            if st.tx is not None:
                return st.tx
        return None

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    # ------------------------------------------------------------- submit --
    def submit(self, tokens: np.ndarray, *, now_s: Optional[float] = None
               ) -> RequestResult:
        now = self._now() if now_s is None else now_s
        n = int(len(tokens))
        qd = [occ.queue_delay(now) for occ in self._occ]
        d = self.scheduler.decide(n, now, qd)
        k = self._admit(d, now)
        tier = self.tiers[k]

        m_out, exec_s = tier.run(tokens, d.m_hat, self.rng)
        wait = self._occ[k].assign(now, exec_s)
        if tier.rtt_fn is not None:
            rtt = float(tier.rtt_fn(now))
            payload = float(bytes_for_tokens(
                n + m_out, self.scheduler.bytes_per_token))
            tx = self.scheduler.tiers[k].tx
            net = exec_s + rtt + payload * 8.0 / tx.bandwidth_bps
            tx.observe(now, rtt)       # §II-C timestamp mechanism, per link
        else:
            net = exec_s
        latency = wait + net

        res = RequestResult(self._next_id, k, n, m_out, latency, d,
                            wait_s=wait, tier_name=tier.name)
        self._next_id += 1
        self.results.append(res)
        if self.calibrator is not None:
            if self.calibrator.record(k, n, m_out, exec_s):
                self.calibrator.refit(
                    [st.model for st in self.scheduler.tiers],
                    self.scheduler.n2m)
        return res

    def _admit(self, d: MultiTierDecision, now: float) -> int:
        """Bounded-FIFO admission: re-route from a full tier to the
        next-best tier with space; if everything is full, keep the choice
        and count the rejection."""
        k = d.tier
        if self._has_space(k, now):
            return k
        for j in sorted(range(len(self.tiers)), key=lambda j: d.t_pred[j]):
            if self._has_space(j, now):
                return j
        self.rejected[k] += 1
        return k

    def _has_space(self, k: int, now: float) -> bool:
        cap = self.tiers[k].queue_capacity
        if cap is None or self._occ[k].queue_delay(now) == 0.0:
            return True          # unbounded, or a server is free right now
        return self._occ[k].queue_len(now) < cap

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict[str, object]:
        if not self.results:
            return {}
        lat = np.array([r.latency_s for r in self.results])
        wait = np.array([r.wait_s for r in self.results])
        dev = np.array([r.device for r in self.results])
        remote = np.array([t.rtt_fn is not None for t in self.tiers])
        tx = self.tx
        return {
            "requests": len(self.results),
            "total_latency_s": float(lat.sum()),
            "mean_latency_s": float(lat.mean()),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "mean_wait_s": float(wait.mean()),
            "offload_frac": float(np.mean(remote[dev])),
            "tier_frac": {t.name: float(np.mean(dev == k))
                          for k, t in enumerate(self.tiers)},
            "rejected": int(self.rejected.sum()),
            "tx_estimate_s": 0.0 if tx is None else tx.rtt(0.0),
        }
