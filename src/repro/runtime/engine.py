"""The C-NMT technique as a first-class serving feature: a tiered engine
that routes each request edge/cloud by the paper's decision rule.

This is the production integration of ``repro.core``: the same
CNMTScheduler, length regressor and TxEstimator, driving either

* REAL execution — a tier carries an executor callable (e.g. a
  ``repro.nmt`` translate fn, or a :class:`GenerationSession` for the
  big-model stack on CPU-reduced configs), and the engine measures
  actual wall-clock; or
* MODELLED execution — a tier carries only its latency plane (fitted by
  ``core.calibration`` or priced from dry-run rooflines via
  ``device_from_roofline``), and the engine simulates the latency.  This
  is how TPU-pod tiers we cannot run locally participate.

Mixed setups (real edge + modelled cloud) mirror the paper's testbed,
where the network was simulated but inference was real.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.latency_model import DeviceProfile, bytes_for_tokens
from repro.core.length_regressor import LinearN2M
from repro.core.scheduler import CLOUD, EDGE, CNMTScheduler, Decision
from repro.core.tx_estimator import TxEstimator


@dataclasses.dataclass
class Tier:
    """One compute tier (edge gateway / cloud pod)."""

    profile: DeviceProfile
    executor: Optional[Callable] = None   # tokens -> (m_out, out_tokens)

    def run(self, tokens: np.ndarray, m_hat: float,
            rng: np.random.Generator) -> tuple[int, float]:
        """Returns (output_len, execution_seconds)."""
        if self.executor is not None:
            t0 = time.perf_counter()
            m_out, _ = self.executor(tokens)
            return int(m_out), time.perf_counter() - t0
        # modelled: draw the true time around the plane at predicted M
        t = float(self.profile.true_time(float(len(tokens)), m_hat, rng))
        return int(max(round(m_hat), 1)), t


@dataclasses.dataclass
class RequestResult:
    req_id: int
    device: int           # EDGE / CLOUD
    n: int
    m_out: int
    latency_s: float      # execution + (tx if offloaded)
    decision: Decision


class CollaborativeEngine:
    """Paper Eq. (1)/(2) in the serve path.

    ``rtt_fn(now)`` models the live network (a ConnectionProfile's
    ``rtt_at`` in experiments; a real prober in deployment).  The engine
    feeds the TxEstimator exactly like §II-C: every offloaded request
    contributes a timestamped RTT sample.
    """

    def __init__(self, *, edge: Tier, cloud: Tier, n2m: LinearN2M,
                 rtt_fn: Callable[[float], float],
                 bytes_per_token: int = 2,
                 hedge_margin_s: float = 0.0,
                 seed: int = 0):
        self.edge, self.cloud = edge, cloud
        self.scheduler = CNMTScheduler(
            edge=edge.profile, cloud=cloud.profile, n2m=n2m,
            bytes_per_token=bytes_per_token, hedge_margin_s=hedge_margin_s)
        self.tx = TxEstimator(init_rtt_s=float(rtt_fn(0.0)))
        self.rtt_fn = rtt_fn
        self.rng = np.random.default_rng(seed)
        self.results: List[RequestResult] = []
        self._t0 = time.perf_counter()
        self._next_id = 0

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def submit(self, tokens: np.ndarray, *, now_s: Optional[float] = None
               ) -> RequestResult:
        now = self._now() if now_s is None else now_s
        n = int(len(tokens))
        d = self.scheduler.decide(n, now, self.tx)
        if d.device == EDGE:
            m_out, exec_s = self.edge.run(tokens, d.m_hat, self.rng)
            latency = exec_s
        else:
            m_out, exec_s = self.cloud.run(tokens, d.m_hat, self.rng)
            rtt = float(self.rtt_fn(now))
            payload = float(bytes_for_tokens(n + m_out,
                                             self.scheduler.bytes_per_token))
            latency = exec_s + rtt + payload * 8.0 / self.tx.bandwidth_bps
            self.tx.observe(now, rtt)      # §II-C timestamp mechanism
        res = RequestResult(self._next_id, d.device, n, m_out, latency, d)
        self._next_id += 1
        self.results.append(res)
        return res

    # ------------------------------------------------------------- stats --
    def stats(self) -> Dict[str, float]:
        if not self.results:
            return {}
        lat = np.array([r.latency_s for r in self.results])
        off = np.array([r.device == CLOUD for r in self.results])
        return {
            "requests": len(self.results),
            "total_latency_s": float(lat.sum()),
            "mean_latency_s": float(lat.mean()),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "offload_frac": float(off.mean()),
            "tx_estimate_s": self.tx.rtt(0.0),
        }
