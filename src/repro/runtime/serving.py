"""Serving-side compute units.

``make_prefill_step`` / ``make_serve_step`` return exactly the functions
the multi-pod dry-run lowers for the prefill/decode input shapes — one
new token against a KV cache (or SSM state) of the configured context.

:class:`GenerationSession` drives them for real CPU generation (smoke
scale).  Decode has two paths:

* **compiled scan** (default): prefill once, then ONE ``jax.lax.scan``
  over all ``max_new`` decode steps with the EOS ``done`` mask kept
  on-device — a single XLA dispatch per generate call and a single
  device->host transfer at the end, instead of one dispatch + sync per
  token.  Post-EOS positions are PAD-masked and per-sequence output
  lengths are returned (:meth:`GenerationSession.generate_with_lengths`).
* **host loop** (``host_loop=True``): the per-token dispatch loop whose
  wall-clock is linear in the generated length M — the paper-faithful
  timing path (§II-A), kept for characterization runs.

Input shapes are padded to LENGTH BUCKETS (batch -> next power of two,
prompt width -> next bucket boundary) so each (batch, width, max_new)
triple compiles exactly once; a one-line warning is logged per new
compiled shape.  Width bucketing right-pads with PAD and threads true
per-sequence ``lengths`` through ``LM.prefill`` — numerically invisible
for position-masked mixers (attn/mla/shared_attn); plans with recurrent
mixers (mamba2/rwkv6) skip width bucketing since their carried state
would fold the pad steps in.

:func:`build_executor` is the ONE factory for every executor shape a
:class:`~repro.runtime.engine.Tier` accepts: ``kind="solo"`` adapts a
session into the per-request ``tokens -> (m_out, out_tokens)`` callable,
``kind="batched"`` into its REAL batched counterpart — one drained
:class:`~repro.data.pipeline.TokenBatcher` batch in, one batched
generate, per-sequence ``(m_out, tokens)`` out — which the engine's
``submit_batch`` uses so real execution matches the batch-aware
occupancy accounting; ``kind="split"`` returns the two legs of a split
placement; ``kind="raw"`` passes an existing executor through (for
fault-wrapping).  ``faults=...`` wraps the result with deterministic
fault injection.  The PR-era names (``make_tier_executor``,
``make_batched_tier_executor``, ``make_split_tier_executors``,
``make_faulty_executor``) remain as thin aliases that emit
``DeprecationWarning``.

:class:`ContinuousGenerationSession` (continuous in-flight batching) is
the Orca/vLLM-style refactor of the block path: a PERSISTENT slot table
of ``max_slots`` sequences decodes one step per dispatch, finished rows
are EVICTED between steps, queued prompts are PREFILLED INTO the freed
slots of the live batch (bucketed ragged ``prefill(lengths=...)``, rows
scattered into the resident decode state), and tokens stream out per
step instead of one end-of-block transfer.  A drained block no longer
runs to completion — one long sequence cannot hold ``max_slots - 1``
finished rows hostage, which is the p95 lever under heavy Poisson load
(ROADMAP item 1).  EOS/done semantics come from the same
:func:`~repro.nmt.common.greedy_update` the compiled scan uses, so the
two paths cannot drift; ``serve(..., refill=False)`` degenerates to
exact block-to-completion scheduling for the parity pins.

Everything built here plugs into :class:`~repro.runtime.engine.Tier`s
of the ``CollaborativeEngine``, which the load-generation harness
(``benchmarks/loadgen.py``) drives under MLPerf-style arrival
processes, recording completions through the engine's ``on_complete``
hook — see ``docs/architecture.md`` for the request lifecycle.
"""

from __future__ import annotations

import logging
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.models.model import LM
from repro.nmt.common import greedy_update, scan_greedy_steps

_LOG = logging.getLogger(__name__)

# mixers whose decode caches are position-masked per sequence (slot ==
# position, mask idx <= pos), making right-padded ragged prefill exact
_POSITION_MASKED_MIXERS = ("attn", "mla", "shared_attn")


def _ragged_plan_ok(model: LM) -> bool:
    """True when ragged right-padded prompts are exact for this plan
    (every mixer's decode cache is position-masked per sequence)."""
    return all(g.mixer in _POSITION_MASKED_MIXERS
               for g in model.cfg.layer_plan)


def make_prefill_step(model: LM, *, max_len: Optional[int] = None) -> Callable:
    """prefill_step(params, tokens[, lengths][, frames]) ->
    (last_logits, decode_state)."""

    def prefill_step(params, tokens, lengths=None, frames=None):
        kw = {"frames": frames} if frames is not None else {}
        return model.prefill(params, tokens, max_len=max_len,
                             lengths=lengths, **kw)

    return prefill_step


def make_serve_step(model: LM) -> Callable:
    """serve_step(params, state, tokens (B,1)) -> (logits (B,V), state).

    ONE new token per sequence against the fixed-capacity decode state —
    the unit lowered for decode_32k / long_500k.
    """

    def serve_step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return serve_step


def _next_pow2(n: int, floor: int = 1) -> int:
    return max(floor, 1 << (max(n, 1) - 1).bit_length())


def _solo_executor(session: "GenerationSession", *, max_new: int = 16,
                   vocab_clip: Optional[int] = None) -> Callable:
    """Per-request ``executor(tokens) -> (m_out, out_tokens)``.

    ``vocab_clip`` guards against out-of-vocab ids when the request
    stream's tokenizer is larger than the serving model's.  ``m_out`` is
    the TRUE per-sequence output length (pre-EOS tokens) — finished
    sequences don't inflate M with post-EOS argmax junk.
    """

    def executor(tokens: np.ndarray):
        toks = np.asarray(tokens, np.int32)[None, :]
        if vocab_clip is not None:
            toks = np.minimum(toks, vocab_clip - 1)
        lens, out = session.generate_with_lengths(toks, max_new=max_new)
        m = int(lens[0])
        return m, out[0, :max(m, 1)]

    return executor


class TierFaultError(RuntimeError):
    """A tier executor crashed (or was made to crash by injection).

    The :class:`~repro.runtime.engine.CollaborativeEngine` failover loop
    treats ANY exception escaping ``Tier.run`` as a tier-down signal —
    this named type exists so fault-injection wrappers and tests can
    raise/catch something more specific than ``RuntimeError``.
    """


def _faulty_wrap(executor: Callable, should_fail,
                 *, message: str = "injected tier fault") -> Callable:
    """Wrap a REAL tier executor with deterministic fault injection.

    ``should_fail`` decides per call whether this invocation crashes:
    either a ``Callable[[int], bool]`` of the 0-based call index, or a
    collection of call indices.  A failing call raises
    :class:`TierFaultError` *instead of* executing — modelling a crash
    before useful work, which is what the engine's detection/retry
    arithmetic assumes.  The wrapper exposes ``.calls`` (``{"n": total,
    "faults": raised}``) so tests can assert the injection actually
    fired.  This is the REAL-execution twin of the modelled
    :class:`~repro.core.faults.FaultSchedule` injection: the schedule
    drives virtual-time faults inside the engine/DES, this wrapper
    drives them through the executor boundary the engine cannot see
    into.
    """
    if not callable(should_fail):
        wanted = frozenset(int(i) for i in should_fail)
        should_fail = wanted.__contains__
    calls = {"n": 0, "faults": 0}

    def faulty(tokens: np.ndarray):
        i = calls["n"]
        calls["n"] += 1
        if should_fail(i):
            calls["faults"] += 1
            raise TierFaultError(f"{message} (call {i})")
        return executor(tokens)

    faulty.calls = calls
    return faulty


def _batched_executor(session: "GenerationSession", *,
                      max_new: int = 16,
                      vocab_clip: Optional[int] = None) -> Callable:
    """REAL batched ``executor(batch, lengths=None)``.

    Returns ``executor(batch, lengths=None) -> [(m_out, tokens), ...]``:
    ``batch`` is one drained :class:`TokenBatcher` padded token block
    (b, width) — already length-bucketed by the batcher — and ``lengths``
    the true per-request prompt lengths (derived from trailing PADs when
    omitted).  One batched ``generate`` serves the whole batch; results
    come back per sequence in row order, so the engine can account each
    member of the batch individually.
    """

    def executor(batch: np.ndarray, lengths: Optional[Sequence[int]] = None):
        toks = np.asarray(batch, np.int32)
        if toks.ndim != 2:
            raise ValueError("batched executor expects a (b, width) block")
        if vocab_clip is not None:
            toks = np.minimum(toks, vocab_clip - 1)
        if lengths is None:
            real = toks != PAD_ID
            # width minus trailing pads; clamp to >= 1 for all-pad rows
            trailing = np.where(real.any(1), np.argmax(real[:, ::-1], axis=1),
                                toks.shape[1])
            lens_in = np.maximum(toks.shape[1] - trailing, 1).astype(np.int32)
        else:
            lens_in = np.asarray(lengths, np.int32)
        if session.supports_ragged or np.all(lens_in == toks.shape[1]):
            m_out, out = session.generate_with_lengths(
                toks, max_new=max_new, lengths=lens_in)
            return [(int(m), out[i, :max(int(m), 1)])
                    for i, m in enumerate(m_out)]
        # recurrent-state plans can't take ragged right-padding: run one
        # uniform (trimmed) sub-batch per distinct length instead
        results: List[Optional[tuple]] = [None] * toks.shape[0]
        for L in np.unique(lens_in):
            rows = np.flatnonzero(lens_in == L)
            m_out, out = session.generate_with_lengths(
                toks[rows, :int(L)], max_new=max_new)
            for j, r in enumerate(rows):
                results[r] = (int(m_out[j]), out[j, :max(int(m_out[j]), 1)])
        return results

    return executor


def _split_executors(model, params, *,
                     vocab_clip: Optional[int] = None
                     ) -> Tuple[Callable, Callable]:
    """Adapt an NMT model into the two LEGS of a split placement.

    Returns ``(encode_executor, decode_executor)`` for
    :class:`~repro.runtime.engine.Tier`:

    * ``encode_executor(tokens) -> EncoderStates`` runs just the encoder
      (1-D int token array in, shippable pytree out);
    * ``decode_executor(states) -> (m_out, out_tokens)`` resumes from the
      shipped states and runs the compiled scan decode.

    ``decode_executor(encode_executor(t))`` is bit-for-bit the fused
    ``make_translate_batched`` path (pinned in tests) — splitting is a
    placement choice, never a quality change.  Give the encode tier the
    first and the decode tier the second; a tier serving both legs of
    different requests can carry both.
    """
    encode_states = model.make_encode_states(params)
    decode_from_states = model.make_decode_from_states(params)

    def encode_executor(tokens: np.ndarray):
        toks = np.asarray(tokens, np.int32)[None, :]
        if vocab_clip is not None:
            toks = np.minimum(toks, vocab_clip - 1)
        return encode_states(toks)

    def decode_executor(states):
        lens, out = decode_from_states(states)
        m = int(np.asarray(lens)[0])
        return m, np.asarray(out, np.int32)[0, :max(m, 1)]

    return encode_executor, decode_executor


def build_executor(session_or_model, *, kind: str = "solo",
                   max_new: int = 16,
                   vocab_clip: Optional[int] = None,
                   params=None,
                   faults=None,
                   fault_message: str = "injected tier fault"):
    """The ONE factory for every executor shape a Tier accepts.

    ``kind`` selects the adaptation:

    * ``"solo"`` — ``session_or_model`` is a generation session; returns
      the per-request ``executor(tokens) -> (m_out, out_tokens)``.
    * ``"batched"`` — same input; returns the REAL batched
      ``executor(batch, lengths=None) -> [(m_out, tokens), ...]`` the
      engine's ``submit_batch`` drives (``Tier.batched_executor``).
    * ``"split"`` — ``session_or_model`` is an NMT *model* and
      ``params=`` its parameters; returns the ``(encode_executor,
      decode_executor)`` pair for a partitioned placement
      (``Tier.encode_executor`` / ``Tier.decode_executor``).
    * ``"raw"`` — ``session_or_model`` is already an executor callable;
      passed through untouched (useful purely to apply ``faults=``).

    ``faults`` wraps the result with deterministic fault injection (a
    ``Callable[[int], bool]`` of the call index, or a collection of call
    indices — see :class:`TierFaultError`); the wrapper exposes
    ``.calls``.  ``faults`` composes with every kind except ``"split"``
    (two legs — wrap each leg yourself via ``kind="raw"``).
    """
    if kind == "solo":
        executor = _solo_executor(session_or_model, max_new=max_new,
                                  vocab_clip=vocab_clip)
    elif kind == "batched":
        executor = _batched_executor(session_or_model, max_new=max_new,
                                     vocab_clip=vocab_clip)
    elif kind == "split":
        if params is None:
            raise ValueError("kind='split' needs params=")
        if faults is not None:
            raise ValueError(
                "faults= does not compose with kind='split' (two legs); "
                "wrap each leg via build_executor(leg, kind='raw', "
                "faults=...)")
        return _split_executors(session_or_model, params,
                                vocab_clip=vocab_clip)
    elif kind == "raw":
        if not callable(session_or_model):
            raise ValueError("kind='raw' expects an executor callable")
        executor = session_or_model
    else:
        raise ValueError(
            f"kind must be 'solo'|'batched'|'split'|'raw', got {kind!r}")
    if faults is not None:
        executor = _faulty_wrap(executor, faults, message=fault_message)
    return executor


def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new}",
        DeprecationWarning, stacklevel=3)


def make_tier_executor(session, *, max_new: int = 16,
                       vocab_clip: Optional[int] = None) -> Callable:
    """Deprecated alias for ``build_executor(session, kind='solo')``."""
    _warn_deprecated("make_tier_executor",
                     "build_executor(session, kind='solo')")
    return build_executor(session, kind="solo", max_new=max_new,
                          vocab_clip=vocab_clip)


def make_batched_tier_executor(session, *, max_new: int = 16,
                               vocab_clip: Optional[int] = None) -> Callable:
    """Deprecated alias for ``build_executor(session, kind='batched')``."""
    _warn_deprecated("make_batched_tier_executor",
                     "build_executor(session, kind='batched')")
    return build_executor(session, kind="batched", max_new=max_new,
                          vocab_clip=vocab_clip)


def make_split_tier_executors(model, params, *,
                              vocab_clip: Optional[int] = None
                              ) -> Tuple[Callable, Callable]:
    """Deprecated alias for ``build_executor(model, kind='split')``."""
    _warn_deprecated("make_split_tier_executors",
                     "build_executor(model, kind='split', params=...)")
    return build_executor(model, kind="split", params=params,
                          vocab_clip=vocab_clip)


def make_faulty_executor(executor: Callable, should_fail,
                         *, message: str = "injected tier fault") -> Callable:
    """Deprecated alias for ``build_executor(executor, kind='raw',
    faults=...)``."""
    _warn_deprecated("make_faulty_executor",
                     "build_executor(executor, kind='raw', faults=...)")
    return build_executor(executor, kind="raw", faults=should_fail,
                          fault_message=message)


class GenerationSession:
    """Greedy batched generation on CPU (reduced configs).

    ``host_loop=True`` selects the per-token dispatch loop (the
    paper-faithful, linear-in-M timing path); the default is the
    compiled-scan fast path.  ``bucket_shapes=False`` disables the
    length-bucket padding (every distinct input shape then compiles its
    own executable, the seed behaviour).
    """

    def __init__(self, model: LM, params, *, max_len: int = 64,
                 host_loop: bool = False, bucket_shapes: bool = True):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.host_loop = host_loop
        self.bucket_shapes = bucket_shapes
        self._prefill = jax.jit(make_prefill_step(model, max_len=max_len))
        self._step = jax.jit(make_serve_step(model))
        self._decode = jax.jit(self._decode_scan,
                               static_argnames=("max_new",))
        self._compiled_shapes: set = set()
        self._ragged_ok = _ragged_plan_ok(model)

    @property
    def supports_ragged(self) -> bool:
        """True when ragged right-padded prompts are exact for this plan
        (every mixer's decode cache is position-masked per sequence)."""
        return self._ragged_ok

    # ------------------------------------------------------- scan decode --
    def _decode_scan(self, params, state, tok0, max_new: int):
        """All ``max_new`` decode steps in one lax.scan (the shared
        :func:`~repro.nmt.common.scan_greedy_steps` body); done stays on
        device.  Emits the EOS token itself (``keep_eos``), PAD-masks
        everything after it, and counts pre-EOS tokens per sequence."""

        def step(st, tok):                        # LM contract adapter
            logits, st2 = self.model.decode_step(params, st, tok[:, None])
            return st2, logits

        return scan_greedy_steps(step, state, tok0[:, 0], tok0.shape[0],
                                 max_new, keep_eos=True)

    # ------------------------------------------------------------ public --
    def generate(self, tokens: np.ndarray, *, max_new: int = 16,
                 frames: Optional[np.ndarray] = None,
                 lengths: Optional[Sequence[int]] = None) -> np.ndarray:
        """tokens (B,S) int32 -> generated (B,<=max_new) int32.

        Emitted rows end with EOS where the model produced one; positions
        after it are PAD (they no longer carry post-EOS argmax junk).
        Trailing all-PAD columns are trimmed (width >= 1 kept).
        """
        lens, out = self.generate_with_lengths(
            tokens, max_new=max_new, frames=frames, lengths=lengths)
        # lens counts pre-EOS tokens; +1 keeps the emitted EOS visible
        width = int(min(max(int(lens.max()) + 1, 1), out.shape[1]))
        return out[:, :width]

    def generate_with_lengths(
            self, tokens: np.ndarray, *, max_new: int = 16,
            frames: Optional[np.ndarray] = None,
            lengths: Optional[Sequence[int]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """tokens (B,S) -> (lengths (B,), tokens (B,max_new)).

        ``lengths`` out counts each sequence's PRE-EOS tokens (the
        paper's M); the token block is PAD-masked after each EOS.
        ``lengths`` in marks true prompt lengths in a right-padded batch
        (position-masked mixer plans only).
        """
        tokens = np.asarray(tokens, np.int32)
        b, s = tokens.shape
        if s + max_new > self.max_len:
            raise ValueError("exceeds session capacity")
        lens_in = (None if lengths is None
                   else np.asarray(lengths, np.int32))
        if lens_in is not None and not self._ragged_ok:
            if np.all(lens_in == s):
                lens_in = None           # uniform full-width: nothing ragged
            else:
                raise ValueError(
                    "ragged prompt lengths need position-masked mixers "
                    f"(plan has {[g.mixer for g in self.model.cfg.layer_plan]})")
        if self.bucket_shapes and frames is None:
            tokens, lens_in = self._bucket_pad(tokens, lens_in, max_new)

        args = (self.params, jnp.asarray(tokens))
        if frames is not None:
            logits, state = self._prefill(*args, None, jnp.asarray(frames))
        elif lens_in is not None:
            logits, state = self._prefill(*args, jnp.asarray(lens_in))
        else:
            logits, state = self._prefill(*args)
        tok0 = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]

        if self.host_loop:
            lens_out, out = self._host_decode(state, tok0, max_new)
        else:
            lens_out, out = self._decode(self.params, state, tok0,
                                         max_new=max_new)
        return (np.asarray(lens_out, np.int32)[:b],
                np.asarray(out, np.int32)[:b])

    # ------------------------------------------------------------ helpers --
    def _bucket_pad(self, tokens, lens_in, max_new):
        """Pad (b, s) up to the shape bucket; returns (tokens, lengths)."""
        b, s = tokens.shape
        bb = _next_pow2(b)
        if self._ragged_ok:
            sb = min(_next_pow2(s, floor=8), self.max_len - max_new)
            sb = max(sb, s)
            if lens_in is None:
                lens_in = np.full((b,), s, np.int32)
        else:
            sb = s                       # recurrent state: exact width only
        if (bb, sb) != (b, s):
            padded = np.full((bb, sb), PAD_ID, np.int32)
            padded[:b, :s] = tokens
            tokens = padded
            if lens_in is not None:
                lens_in = np.concatenate(
                    [lens_in, np.ones((bb - b,), np.int32)])
        key = (bb, sb, max_new)
        if key not in self._compiled_shapes:
            self._compiled_shapes.add(key)
            _LOG.warning("GenerationSession: compiling new shape "
                         "batch=%d width=%d max_new=%d", bb, sb, max_new)
        return tokens, lens_in

    def _host_decode(self, state, tok0, max_new: int):
        """Per-token dispatch loop (timing path).  ``done`` stays on
        device; the early-exit check syncs ONE scalar per step instead of
        transferring the token block."""
        tok = tok0
        done = jnp.zeros((tok0.shape[0],), bool)
        emitted = []
        lens = jnp.zeros((tok0.shape[0],), jnp.int32)
        for _ in range(max_new):
            t = tok[:, 0]
            emitted.append(jnp.where(done, PAD_ID, t))
            lens = lens + (~done & (t != EOS_ID)).astype(jnp.int32)
            done = done | (t == EOS_ID)
            if bool(done.all()):                  # one scalar sync per step
                break
            logits, state = self._step(self.params, state, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out = jnp.stack(emitted, axis=1)
        if out.shape[1] < max_new:                # match scan-path width
            out = jnp.pad(out, ((0, 0), (0, max_new - out.shape[1])),
                          constant_values=PAD_ID)
        return lens, out


class ContinuousGenerationSession:
    """Continuous in-flight batching over a persistent slot table.

    ``max_slots`` sequences share ONE resident decode state (capacity
    ``max_len`` per slot).  The serving loop is re-formed *between decode
    steps*:

    * :meth:`step` runs one jitted decode dispatch over the whole slot
      table, streams each live slot's emitted token back (per-step
      transfer of ``max_slots`` scalars, not an end-of-block barrier),
      and EVICTS rows that emitted EOS or exhausted their ``max_new``
      budget — their slots free immediately;
    * :meth:`admit` PREFILLS queued prompts into the freed slots of the
      live batch: one bucketed ragged ``LM.prefill(lengths=...)`` per
      admission wave, its rows scattered into the resident state (KV
      caches at batch axis 1, ``pos`` at axis 0) with padding rows
      dropped through out-of-bounds scatter indices.

    EOS/done bookkeeping is :func:`repro.nmt.common.greedy_update` with
    ``keep_eos=True`` — the exact semantics of the compiled-scan
    :class:`GenerationSession` path, so a sequence's emitted tokens and
    pre-EOS length are identical to what a solo ``generate_with_lengths``
    call produces (the parity tests pin this row-for-row).

    Plans with recurrent mixers (mamba2/rwkv6) are admitted in
    exact-width groups (their carried state would fold right-padding in);
    position-masked plans take the bucketed ragged path.  Prompt batches
    are padded to power-of-two (batch, width) buckets so admission waves
    compile a bounded set of shapes.
    """

    def __init__(self, model: LM, params, *, max_slots: int = 8,
                 max_len: int = 64, bucket_shapes: bool = True):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if model.cfg.is_encoder_decoder:
            raise ValueError("continuous batching needs a decoder-only LM")
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.bucket_shapes = bucket_shapes
        self._ragged_ok = _ragged_plan_ok(model)
        self._prefill = jax.jit(make_prefill_step(model, max_len=max_len))
        self._step = jax.jit(self._cont_step)
        self._write = jax.jit(self._write_rows)
        self._compiled_shapes: set = set()
        self.reset()

    def reset(self) -> None:
        """Empty the slot table, KEEPING the compiled shapes — benchmarks
        warm a session once and reset between measured runs."""
        # resident device state: seeded by a dummy prefill so every leaf
        # has exactly the shape later admission prefills produce
        _, state = self._prefill(
            self.params, jnp.full((self.max_slots, 1), PAD_ID, jnp.int32))
        self._state = state
        self._tok = jnp.full((self.max_slots,), PAD_ID, jnp.int32)
        self._done = jnp.ones((self.max_slots,), bool)

        # host-side slot table
        self._live = np.zeros(self.max_slots, bool)
        self._req = [None] * self.max_slots     # caller's request id
        self._emitted: List[List[int]] = [[] for _ in range(self.max_slots)]
        self._m = np.zeros(self.max_slots, np.int64)     # pre-EOS count
        self._steps_left = np.zeros(self.max_slots, np.int64)
        self.n_steps = 0
        self.n_prefills = 0
        self.peak_live = 0

    # ---------------------------------------------------------- queries --
    @property
    def supports_ragged(self) -> bool:
        return self._ragged_ok

    @property
    def live_count(self) -> int:
        return int(self._live.sum())

    @property
    def free_slots(self) -> int:
        return self.max_slots - self.live_count

    # ------------------------------------------------------ jitted bodies --
    def _cont_step(self, params, state, tok, done):
        """One in-flight decode step over the whole slot table."""
        emit, live, done2 = greedy_update(tok, done, keep_eos=True)
        logits, state2 = self.model.decode_step(params, state, tok[:, None])
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return state2, nxt, emit, live, done2

    def _write_rows(self, state, new_state, slots, tok, done, tok0):
        """Scatter freshly prefilled rows into the resident state.

        ``slots`` may carry out-of-bounds indices (== max_slots) for the
        batch-bucket padding rows — JAX scatter drops those updates, so
        only the real admissions land."""
        caches = jax.tree.map(lambda a, b: a.at[:, slots].set(b),
                              state["caches"], new_state["caches"])
        out = {k: (caches if k == "caches"
                   else state[k].at[slots].set(new_state[k]))
               for k in state}
        return (out, tok.at[slots].set(tok0),
                done.at[slots].set(False))

    # ------------------------------------------------------------- admit --
    def admit(self, prompts: Sequence[np.ndarray], *, max_new: int = 16,
              req_ids: Optional[Sequence] = None) -> List[int]:
        """Prefill ``prompts`` into free slots of the LIVE batch.

        Returns the assigned slot indices (one per prompt, in order).
        Raises when more prompts than free slots are offered — the
        caller's admission control owns queueing, the slot table never
        oversubscribes.
        """
        if not prompts:
            return []
        free = np.flatnonzero(~self._live)
        if len(prompts) > len(free):
            raise ValueError(
                f"admit({len(prompts)}) exceeds {len(free)} free slots")
        toks = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        for t in toks:
            if len(t) + max_new > self.max_len:
                raise ValueError("exceeds session capacity")
            if len(t) == 0:
                raise ValueError("empty prompt")
        if req_ids is None:
            req_ids = list(range(len(prompts)))
        slots = [int(free[j]) for j in range(len(prompts))]

        if self._ragged_ok:
            groups = [list(range(len(toks)))]
        else:                     # recurrent state: exact width per group
            by_len: dict = {}
            for j, t in enumerate(toks):
                by_len.setdefault(len(t), []).append(j)
            groups = [by_len[L] for L in sorted(by_len)]
        for idx in groups:
            self._admit_group([toks[j] for j in idx],
                              [slots[j] for j in idx], max_new)

        for j, s in enumerate(slots):
            self._live[s] = True
            self._req[s] = req_ids[j]
            self._emitted[s] = []
            self._m[s] = 0
            self._steps_left[s] = max_new
        self.peak_live = max(self.peak_live, self.live_count)
        return slots

    def _admit_group(self, toks: List[np.ndarray], slots: List[int],
                     max_new: int) -> None:
        """One prefill wave: pad to the (batch, width) bucket, prefill,
        scatter the rows into the resident slot-table state."""
        k = len(toks)
        w = max(len(t) for t in toks)
        lens = np.asarray([len(t) for t in toks], np.int32)
        uniform = bool(np.all(lens == w))
        if self.bucket_shapes:
            kp = _next_pow2(k)
            if self._ragged_ok:
                wp = min(_next_pow2(w, floor=8), self.max_len - max_new)
                wp = max(wp, w)
            else:
                wp = w
        else:
            kp, wp = k, w
        block = np.full((kp, wp), PAD_ID, np.int32)
        for j, t in enumerate(toks):
            block[j, :len(t)] = t
        lens_in = np.concatenate([lens, np.ones(kp - k, np.int32)])
        key = (kp, wp, "prefill")
        if key not in self._compiled_shapes:
            self._compiled_shapes.add(key)
            _LOG.warning("ContinuousGenerationSession: compiling admission "
                         "shape batch=%d width=%d", kp, wp)
        if self._ragged_ok and not (uniform and kp == k and wp == w):
            logits, new_state = self._prefill(
                self.params, jnp.asarray(block), jnp.asarray(lens_in))
        else:
            logits, new_state = self._prefill(self.params,
                                              jnp.asarray(block))
        tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
        # bucket-padding rows scatter to index max_slots: out of bounds,
        # dropped — only the k real rows land in the table
        slot_idx = np.full(kp, self.max_slots, np.int32)
        slot_idx[:k] = slots
        self._state, self._tok, self._done = self._write(
            self._state, new_state, jnp.asarray(slot_idx),
            self._tok, self._done, tok0)
        self.n_prefills += 1

    # -------------------------------------------------------------- step --
    def step(self) -> Tuple[List[tuple], List[tuple]]:
        """One in-flight decode step for every live slot.

        Returns ``(stream, finished)``: ``stream`` is the per-step token
        stream ``[(req_id, token), ...]`` (EOS included when emitted) and
        ``finished`` lists the rows evicted this step as ``(req_id,
        m_out, tokens)`` — ``m_out`` counting pre-EOS tokens and
        ``tokens`` the emitted array (EOS kept, never PAD-padded).  Free
        slots are skipped; an empty table is a no-op.
        """
        if not self._live.any():
            return [], []
        state2, nxt, emit, live, done2 = self._step(
            self.params, self._state, self._tok, self._done)
        self._state, self._tok, self._done = state2, nxt, done2
        emit = np.asarray(emit)
        live_arr = np.asarray(live)
        done_h = np.asarray(done2)
        self.n_steps += 1

        stream: List[tuple] = []
        finished: List[tuple] = []
        exhausted = np.zeros(self.max_slots, bool)
        for s in np.flatnonzero(self._live):
            # every live slot entered the step with done=False (EOS and
            # budget rows evict immediately), so emit is a genuine token
            # — possibly a real token whose id equals PAD_ID
            t = int(emit[s])
            self._emitted[s].append(t)
            stream.append((self._req[s], t))
            self._m[s] += int(live_arr[s])
            self._steps_left[s] -= 1
            if done_h[s] or self._steps_left[s] <= 0:
                if not done_h[s]:      # budget out: silence the row too
                    exhausted[s] = True
                self._live[s] = False
                finished.append((self._req[s], int(self._m[s]),
                                 np.asarray(self._emitted[s], np.int32)))
                self._req[s] = None
                self._emitted[s] = []
        if exhausted.any():
            self._done = jnp.logical_or(self._done, jnp.asarray(exhausted))
        return stream, finished

    # ------------------------------------------------------------- serve --
    def serve(self, prompts: Sequence[np.ndarray], *, max_new: int = 16,
              refill: bool = True) -> List[Tuple[int, np.ndarray]]:
        """Scheduling-free driver: run ``prompts`` through the slot table.

        ``refill=True`` is continuous mode — freed slots are refilled
        from the queue between steps.  ``refill=False`` is the PR 3
        block-to-completion discipline: a block of up to ``max_slots``
        prompts is admitted only when the table is EMPTY and runs until
        every member finishes (the parity baseline).  Returns
        ``(m_out, tokens)`` per prompt, in prompt order.
        """
        results: List[Optional[Tuple[int, np.ndarray]]] = [None] * len(prompts)
        queue = list(range(len(prompts)))
        head = 0
        while head < len(queue) or self.live_count:
            can_admit = self.free_slots if (refill or self.live_count == 0) \
                else 0
            take = min(can_admit, len(queue) - head)
            if take:
                idx = queue[head:head + take]
                head += take
                self.admit([prompts[i] for i in idx], max_new=max_new,
                           req_ids=idx)
            _, finished = self.step()
            for rid, m, toks in finished:
                results[rid] = (m, toks)
        return results  # type: ignore[return-value]
