"""Serving-side compute units.

``make_prefill_step`` / ``make_serve_step`` return exactly the functions
the multi-pod dry-run lowers for the prefill/decode input shapes — one
new token against a KV cache (or SSM state) of the configured context.

:class:`GenerationSession` drives them for real CPU generation (smoke
scale): prefill once, then greedy decode with EOS handling — the serving
analog of ``repro.nmt``'s translate loop.  :func:`make_tier_executor`
adapts a session into the ``tokens -> (m_out, out_tokens)`` callable a
:class:`~repro.runtime.engine.Tier` expects, so a real model can serve as
any tier of the N-tier collaborative engine.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS_ID
from repro.models.model import LM


def make_prefill_step(model: LM, *, max_len: Optional[int] = None) -> Callable:
    """prefill_step(params, tokens[, frames]) -> (last_logits, decode_state)."""

    def prefill_step(params, tokens, frames=None):
        kw = {"frames": frames} if frames is not None else {}
        return model.prefill(params, tokens, max_len=max_len, **kw)

    return prefill_step


def make_serve_step(model: LM) -> Callable:
    """serve_step(params, state, tokens (B,1)) -> (logits (B,V), state).

    ONE new token per sequence against the fixed-capacity decode state —
    the unit lowered for decode_32k / long_500k.
    """

    def serve_step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return serve_step


def make_tier_executor(session: "GenerationSession", *, max_new: int = 16,
                       vocab_clip: Optional[int] = None) -> Callable:
    """Adapt a GenerationSession into a Tier executor.

    Returns ``executor(tokens) -> (m_out, out_tokens)`` for 1-D int token
    arrays; ``vocab_clip`` guards against out-of-vocab ids when the
    request stream's tokenizer is larger than the serving model's.
    """

    def executor(tokens: np.ndarray):
        toks = np.asarray(tokens, np.int32)[None, :]
        if vocab_clip is not None:
            toks = np.minimum(toks, vocab_clip - 1)
        out = session.generate(toks, max_new=max_new)
        return int(out.shape[1]), out[0]

    return executor


class GenerationSession:
    """Greedy batched generation on CPU (reduced configs)."""

    def __init__(self, model: LM, params, *, max_len: int = 64):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(model, max_len=max_len))
        self._step = jax.jit(make_serve_step(model))

    def generate(self, tokens: np.ndarray, *, max_new: int = 16,
                 frames: Optional[np.ndarray] = None) -> np.ndarray:
        """tokens (B,S) int32 -> generated (B,<=max_new) (EOS-truncated)."""
        b, s = tokens.shape
        if s + max_new > self.max_len:
            raise ValueError("exceeds session capacity")
        args = (self.params, jnp.asarray(tokens))
        logits, state = (self._prefill(*args, jnp.asarray(frames))
                         if frames is not None else self._prefill(*args))
        out = []
        done = np.zeros((b,), bool)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for _ in range(max_new):
            out.append(np.asarray(tok)[:, 0])
            done |= out[-1] == EOS_ID
            if done.all():
                break
            logits, state = self._step(self.params, state, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return np.stack(out, axis=1)
