"""Deterministic hash tokenizer stub.

Real NMT stacks ship a learned subword vocabulary (BPE/SentencePiece).
That artifact is orthogonal to everything this framework studies (latency
scheduling, sharding, kernels), so we provide a deterministic stand-in
with the same *interface*: text <-> int32 ids, special ids, stable across
processes (no Python hash randomization).
"""

from __future__ import annotations

import hashlib
from typing import List, Sequence

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
UNK_ID = 3
NUM_SPECIAL = 4


class HashTokenizer:
    """Whitespace-split words -> stable bucket ids in [NUM_SPECIAL, vocab)."""

    def __init__(self, vocab_size: int = 32000):
        if vocab_size <= NUM_SPECIAL:
            raise ValueError("vocab too small")
        self.vocab_size = vocab_size

    def _word_id(self, w: str) -> int:
        h = int.from_bytes(hashlib.blake2s(w.encode("utf-8"), digest_size=8).digest(), "little")
        return NUM_SPECIAL + h % (self.vocab_size - NUM_SPECIAL)

    def encode(self, text: str, *, add_bos: bool = False, add_eos: bool = True) -> List[int]:
        ids = [self._word_id(w) for w in text.split()]
        if add_bos:
            ids = [BOS_ID] + ids
        if add_eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        # hash buckets are not invertible; emit placeholder word forms
        out = []
        for i in ids:
            if i == EOS_ID:
                break
            if i in (PAD_ID, BOS_ID):
                continue
            out.append(f"<w{int(i)}>" if i != UNK_ID else "<unk>")
        return " ".join(out)
