"""Synthetic parallel corpora with the length statistics the paper exploits.

No internet access -> IWSLT'14 / OPUS-100 are not downloadable.  What the
paper *uses* from those corpora is their (N, M) joint length distribution
(Fig. 3) plus token sequences for exercising real models.  This module
generates corpora matching the published statistics:

* DE-EN (IWSLT'14): spoken-language TED-style, short sentences, German
  slightly longer than English -> gamma ~ 0.95, tight correlation.
* FR-EN (OPUS-100): French more verbose than English -> gamma ~ 0.85
  (paper: "gamma < 1 ... lower verbosity of English w.r.t. French").
* EN-ZH (OPUS-100): Chinese much more compact -> gamma ~ 0.70.

Lengths: N ~ clipped lognormal (corpus-typical right-skewed shape);
M = gamma*N + delta + heteroscedastic noise (std grows with N, matching
the widening bands in paper Fig. 3).  A configurable fraction of
wrongly-matched outlier pairs reproduces the misalignment noise the paper
pre-filters with ParaCrawl rules [21].

Token sequences are drawn i.i.d. zipf over the vocabulary — enough to
exercise/time real models (latency depends on lengths, not token values)
and to train the small NMT models on a learnable copy/stretch task.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LanguagePair:
    name: str
    gamma: float          # verbosity slope  (M ~ gamma*N + delta)
    delta: float          # offset
    noise_base: float     # M noise std at N=0
    noise_slope: float    # heteroscedastic growth of M noise with N
    mean_log_n: float     # lognormal params of N
    std_log_n: float
    min_len: int = 1
    max_len: int = 200
    outlier_frac: float = 0.01
    vocab_src: int = 32000
    vocab_tgt: int = 32000


# Calibrated to reproduce the qualitative Fig. 3 panels.
LANGUAGE_PAIRS: Dict[str, LanguagePair] = {
    "de-en": LanguagePair("de-en", gamma=0.95, delta=0.8, noise_base=1.0,
                          noise_slope=0.06, mean_log_n=2.7, std_log_n=0.55),
    "fr-en": LanguagePair("fr-en", gamma=0.85, delta=0.5, noise_base=0.8,
                          noise_slope=0.05, mean_log_n=2.9, std_log_n=0.60),
    "en-zh": LanguagePair("en-zh", gamma=0.70, delta=1.2, noise_base=1.2,
                          noise_slope=0.08, mean_log_n=2.9, std_log_n=0.60),
}


@dataclasses.dataclass
class ParallelCorpus:
    pair: LanguagePair
    n: np.ndarray        # input lengths
    m_real: np.ndarray   # ground-truth reference output lengths
    m_out: np.ndarray    # lengths the NMT model actually emits
    src: Optional[list] = None   # token id arrays (ragged), lazily built
    tgt: Optional[list] = None

    def __len__(self) -> int:
        return int(self.n.size)

    def split(self, k: int) -> Tuple["ParallelCorpus", "ParallelCorpus"]:
        """Head-k / rest split (characterization vs evaluation sets, §III)."""
        def cut(x, a, b):
            return None if x is None else x[a:b]
        return (
            ParallelCorpus(self.pair, self.n[:k], self.m_real[:k], self.m_out[:k],
                           cut(self.src, 0, k), cut(self.tgt, 0, k)),
            ParallelCorpus(self.pair, self.n[k:], self.m_real[k:], self.m_out[k:],
                           cut(self.src, k, None), cut(self.tgt, k, None)),
        )


def make_corpus(
    pair: str | LanguagePair,
    size: int,
    *,
    seed: int = 0,
    with_tokens: bool = False,
    model_len_noise: float = 1.5,
) -> ParallelCorpus:
    """Sample a corpus of ``size`` (N, M_real, M_out) triples.

    ``m_out`` deviates from ``m_real`` with std ``model_len_noise`` —
    the NMT model's translation length differs slightly from the
    reference's ("M_real may in general differ from the output length M
    produced by the NMT model", §III).
    """
    lp = LANGUAGE_PAIRS[pair] if isinstance(pair, str) else pair
    rng = np.random.default_rng(seed)

    n = np.clip(
        np.round(rng.lognormal(lp.mean_log_n, lp.std_log_n, size)),
        lp.min_len, lp.max_len,
    )
    noise_std = lp.noise_base + lp.noise_slope * n
    m_real = lp.gamma * n + lp.delta + rng.standard_normal(size) * noise_std
    m_real = np.clip(np.round(m_real), lp.min_len, lp.max_len)

    # wrongly-matched pairs: M drawn independently of N (pre-filter fodder)
    n_out = int(lp.outlier_frac * size)
    if n_out:
        idx = rng.choice(size, n_out, replace=False)
        m_real[idx] = np.clip(
            np.round(rng.lognormal(lp.mean_log_n, lp.std_log_n, n_out)),
            lp.min_len, lp.max_len,
        )

    m_out = np.clip(
        np.round(m_real + rng.standard_normal(size) * model_len_noise),
        lp.min_len, lp.max_len,
    )

    src = tgt = None
    if with_tokens:
        # zipf-ish unigram draws; reserve ids 0..3 for pad/bos/eos/unk
        def draw(lengths, vocab):
            out = []
            for L in lengths.astype(int):
                r = rng.zipf(1.3, size=L)
                out.append(np.minimum(r + 3, vocab - 1).astype(np.int32))
            return out
        src = draw(n, lp.vocab_src)
        tgt = draw(m_out, lp.vocab_tgt)

    return ParallelCorpus(lp, n, m_real, m_out, src, tgt)
