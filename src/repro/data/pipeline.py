"""Batching / bucketing pipeline for training and serving.

* :func:`bucket_by_length` — groups ragged sequences into length buckets to
  minimize padding waste (standard NMT practice; matters for the RNN
  models whose compute is linear in padded length).
* :func:`padded_batches` — seq2seq batches: (src, src_mask, tgt_in,
  tgt_out, tgt_mask) with BOS/EOS handling.
* :func:`lm_batches` — decoder-only LM batches (tokens, targets) used by
  the big-model training driver.
* :class:`TokenBatcher` — stateful length-bucketing batcher used by the
  serving engine (real padded token batches) and the discrete-event
  simulator (length-only requests) to group concurrent requests of
  similar length into sub-linear-cost decode batches.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import BOS_ID, EOS_ID, PAD_ID


def bucket_by_length(
    lengths: Sequence[int], boundaries: Sequence[int]
) -> Dict[int, List[int]]:
    """index -> bucket assignment; bucket b holds len <= boundaries[b]."""
    buckets: Dict[int, List[int]] = {b: [] for b in range(len(boundaries) + 1)}
    for i, L in enumerate(lengths):
        b = int(np.searchsorted(boundaries, L))
        buckets[b].append(i)
    return {b: idx for b, idx in buckets.items() if idx}


def _pad_to(arrs: List[np.ndarray], width: int) -> np.ndarray:
    out = np.full((len(arrs), width), PAD_ID, dtype=np.int32)
    for i, a in enumerate(arrs):
        out[i, : len(a)] = a[:width]
    return out


def padded_batches(
    src: List[np.ndarray],
    tgt: List[np.ndarray],
    *,
    batch_size: int,
    max_len: int = 256,
    boundaries: Sequence[int] = (16, 32, 64, 128),
    seed: int = 0,
    drop_remainder: bool = False,
) -> Iterator[Dict[str, np.ndarray]]:
    """Bucketed, padded seq2seq batches.

    tgt_in is BOS-shifted, tgt_out EOS-terminated; masks are 1 on real
    tokens. Yields dicts of int32/float32 arrays.
    """
    rng = np.random.default_rng(seed)
    buckets = bucket_by_length([len(s) for s in src], boundaries)
    order = []
    for b, idxs in buckets.items():
        idxs = np.asarray(idxs)
        rng.shuffle(idxs)
        for i in range(0, len(idxs), batch_size):
            chunk = idxs[i : i + batch_size]
            if drop_remainder and len(chunk) < batch_size:
                continue
            order.append(chunk)
    rng.shuffle(order)
    for chunk in order:
        s = [np.concatenate([src[i][:max_len - 1], [EOS_ID]]) for i in chunk]
        t = [tgt[i][: max_len - 1] for i in chunk]
        sw = max(len(x) for x in s)
        tw = max(len(x) + 1 for x in t)
        src_pad = _pad_to(s, sw)
        tgt_in = _pad_to([np.concatenate([[BOS_ID], x]) for x in t], tw)
        tgt_out = _pad_to([np.concatenate([x, [EOS_ID]]) for x in t], tw)
        yield {
            "src": src_pad,
            "src_mask": (src_pad != PAD_ID).astype(np.float32),
            "tgt_in": tgt_in,
            "tgt_out": tgt_out,
            "tgt_mask": (tgt_out != PAD_ID).astype(np.float32),
        }


def lm_batches(
    token_stream: np.ndarray, *, batch_size: int, seq_len: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Pack a flat token stream into (B, S) LM batches with next-token targets."""
    rng = np.random.default_rng(seed)
    tokens_per_batch = batch_size * (seq_len + 1)
    n_batches = len(token_stream) // tokens_per_batch
    starts = rng.permutation(n_batches)
    for b in starts:
        chunk = token_stream[b * tokens_per_batch : (b + 1) * tokens_per_batch]
        chunk = chunk.reshape(batch_size, seq_len + 1)
        yield {"tokens": chunk[:, :-1].astype(np.int32),
               "targets": chunk[:, 1:].astype(np.int32)}


@dataclasses.dataclass
class TokenBatcher:
    """Greedy length-aware batcher for the serving engine and simulator.

    Collects pending requests and emits batches whose padded token count
    stays under ``max_tokens_per_batch`` — the standard continuous-batching
    admission rule.  Requests can carry real token arrays (serving: the
    batch is emitted padded, ready for a batched decode) or just a length
    (discrete-event simulation: only the bucketing decision matters) —
    :meth:`next_batch_ids` serves both, :meth:`next_batch` requires
    tokens.
    """

    max_batch: int = 32
    max_tokens_per_batch: int = 8192

    def __post_init__(self):
        # (req_id, tokens-or-None, length), kept sorted lazily by length
        self._pending: List[Tuple[int, Optional[np.ndarray], int]] = []

    def add(self, req_id: int, tokens: Optional[np.ndarray] = None, *,
            length: Optional[int] = None) -> None:
        if tokens is not None:
            arr = np.asarray(tokens, np.int32)
            self._pending.append((req_id, arr, len(arr)))
        elif length is not None:
            self._pending.append((req_id, None, int(length)))
        else:
            raise ValueError("pass tokens or length")

    def __len__(self) -> int:
        return len(self._pending)

    def _take(self) -> List[Tuple[int, Optional[np.ndarray], int]]:
        """Pop the next length-bucketed batch off the pending list."""
        # sort by length so one batch pads minimally
        self._pending.sort(key=lambda kv: kv[2])
        take: List[Tuple[int, Optional[np.ndarray], int]] = []
        width = 0
        while self._pending and len(take) < self.max_batch:
            cand = self._pending[0]
            w = max(width, cand[2])
            if take and w * (len(take) + 1) > self.max_tokens_per_batch:
                break
            take.append(self._pending.pop(0))
            width = w
        return take

    def next_batch_ids(self) -> Tuple[List[int], int] | None:
        """(request ids, padded width) of the next batch; None when empty.

        Works for length-only requests — the discrete-event simulator's
        drain path, where no real token arrays exist.
        """
        if not self._pending:
            return None
        take = self._take()
        return [r for r, _, _ in take], max(L for _, _, L in take)

    def next_batch(self) -> Tuple[List[int], np.ndarray] | None:
        """(request ids, padded (b, width) token batch); None when empty."""
        if not self._pending:
            return None
        take = self._take()
        width = max(L for _, _, L in take)
        ids = [r for r, _, _ in take]
        return ids, _pad_to([t for _, t, _ in take], width)
