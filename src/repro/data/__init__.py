"""Data substrate: synthetic parallel corpora, tokenizer, batching."""

from repro.data.synthetic import (
    LanguagePair,
    LANGUAGE_PAIRS,
    ParallelCorpus,
    make_corpus,
)
from repro.data.tokenizer import HashTokenizer
from repro.data.pipeline import (
    TokenBatcher,
    padded_batches,
    bucket_by_length,
    lm_batches,
)

__all__ = [
    "LanguagePair",
    "LANGUAGE_PAIRS",
    "ParallelCorpus",
    "make_corpus",
    "HashTokenizer",
    "TokenBatcher",
    "padded_batches",
    "bucket_by_length",
    "lm_batches",
]
