"""Single-layer GRU encoder/decoder, hidden 256 (paper model #2).

The paper's FR-EN model ([18]): a minimal seq2seq without attention —
the encoder's final hidden state is the fixed-size context handed to the
decoder (the classic "context vector" architecture of Fig. 1a).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.nmt.common import (
    RNNConfig,
    build_decode_from_states,
    build_encode_states,
    build_translate_batched,
    cross_entropy,
    dense,
    dense_params,
    embed_init,
    greedy_decode,
    gru_cell,
    gru_params,
    masked_scan_rnn,
    scan_rnn,
)


class GRUSeq2Seq:
    def __init__(self, cfg: RNNConfig):
        self.cfg = cfg

    def init(self, key) -> Dict:
        cfg = self.cfg
        k = iter(jax.random.split(key, 16))
        return {
            "src_embed": embed_init(next(k), cfg.vocab_src, cfg.embed),
            "tgt_embed": embed_init(next(k), cfg.vocab_tgt, cfg.embed),
            "enc": gru_params(next(k), cfg.embed, cfg.hidden),
            "dec": gru_params(next(k), cfg.embed, cfg.hidden),
            "out": dense_params(next(k), cfg.hidden, cfg.vocab_tgt),
        }

    def encode(self, params, src_tokens, src_mask=None):
        """(N,) -> context (H,); or batched (B,N) [+ mask] -> (B,H).

        The batched path freezes the recurrence on padding steps, so a
        prefix-padded row yields the same context as its trimmed self.
        """
        x = params["src_embed"][src_tokens]
        if src_tokens.ndim == 2:
            b = src_tokens.shape[0]
            if src_mask is None:
                src_mask = jnp.ones(src_tokens.shape, jnp.float32)
            h0 = jnp.zeros((b, self.cfg.hidden))
            h, _ = masked_scan_rnn(gru_cell, params["enc"], h0, x, src_mask)
            return h
        h0 = jnp.zeros((self.cfg.hidden,))
        h, _ = scan_rnn(gru_cell, params["enc"], h0, x)
        return h  # fixed-size context = final hidden state

    def decode_step(self, params, state, token):
        """One step; batch-polymorphic (state (H,)+scalar or (B,H)+(B,))."""
        x = params["tgt_embed"][token]
        h, _ = gru_cell(params["dec"], state, x)
        return h, dense(params["out"], h)

    def make_translate(self, params):
        encode = jax.jit(lambda s: self.encode(params, s))
        step = jax.jit(lambda st, tok: self.decode_step(params, st, tok))

        def translate(src_tokens, forced_len=None):
            h = encode(jnp.asarray(src_tokens))
            return greedy_decode(step, h, self.cfg.max_decode_len,
                                 forced_len=forced_len)

        return translate

    def make_translate_batched(self, params, *, compiled: bool = True):
        """Batched translate: (B,N) [+ (B,N) mask] -> (lengths, tokens).

        ``compiled=True`` is the scan fast path (one XLA dispatch per
        call); ``compiled=False`` the paper-faithful per-sequence host
        loop (timing path).
        """
        return build_translate_batched(
            self, params,
            lambda src, mask: self.encode(params, src, mask),
            compiled=compiled)

    def make_encode_states(self, params):
        """Encode leg of a split placement: (B,N) [+ mask] ->
        :class:`EncoderStates` carrying the final hidden state (B,H) —
        the GRU's fixed-size context is the whole payload."""
        return build_encode_states(
            self, params,
            lambda src, mask: self.encode(params, src, mask))

    def make_decode_from_states(self, params):
        """Decode leg: EncoderStates -> (lengths, tokens); the shipped
        hidden state IS the decode carry, no rebuild needed."""
        return build_decode_from_states(self, params, lambda data: data)

    def forward_teacher(self, params, src, src_mask, tgt_in):
        def single(src_i, mask_i, tgt_i):
            h = self.encode(params, src_i, mask_i)
            _, logits = jax.lax.scan(
                lambda st, tok: self.decode_step(params, st, tok), h, tgt_i
            )
            return logits
        return jax.vmap(single)(src, src_mask, tgt_in)

    def loss(self, params, batch):
        logits = self.forward_teacher(
            params, batch["src"], batch["src_mask"], batch["tgt_in"]
        )
        return cross_entropy(logits, batch["tgt_out"], batch["tgt_mask"])
