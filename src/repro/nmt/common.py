"""Shared building blocks for the paper-faithful seq2seq models.

Two greedy-decode paths live here, with opposite goals:

* :func:`greedy_decode` — the HOST loop: one jitted step dispatch per
  token.  Its wall-clock is linear in M by construction, which is the
  paper-faithful timing path (§II-A, Fig. 2a) used by the offline
  characterization sweeps.
* :func:`batched_greedy_decode` — the COMPILED fast path: a single
  ``jax.lax.scan`` over decode steps with a leading batch dimension and
  on-device EOS ``done`` masking, i.e. ONE XLA dispatch per translate
  call instead of one per token.  This is what serving uses; the host
  loop stays behind the ``compiled=False`` flag of the models'
  ``make_translate_batched`` wrappers for timing studies.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import BOS_ID, EOS_ID, PAD_ID


@dataclasses.dataclass(frozen=True)
class RNNConfig:
    vocab_src: int = 8000
    vocab_tgt: int = 8000
    embed: int = 256
    hidden: int = 256
    layers: int = 1
    max_decode_len: int = 256


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab_src: int = 8000
    vocab_tgt: int = 8000
    d_model: int = 256
    heads: int = 8
    d_ff: int = 1024
    enc_layers: int = 6
    dec_layers: int = 6
    max_decode_len: int = 256
    max_src_len: int = 512


# ------------------------------------------------------------------ init --
def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def embed_init(key, vocab, dim, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, dim), dtype) * (dim ** -0.5)


def dense_params(key, d_in, d_out):
    kw, _ = jax.random.split(key)
    return {"w": glorot(kw, (d_in, d_out)), "b": jnp.zeros((d_out,))}


def dense(p, x):
    return x @ p["w"] + p["b"]


# ----------------------------------------------------------------- cells --
def lstm_params(key, d_in, hidden):
    k1, k2 = jax.random.split(key)
    return {
        "wx": glorot(k1, (d_in, 4 * hidden)),
        "wh": glorot(k2, (hidden, 4 * hidden)),
        "b": jnp.zeros((4 * hidden,)),
    }


def lstm_cell(p, carry, x):
    """Standard LSTM cell; carry = (h, c)."""
    h, c = carry
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def gru_params(key, d_in, hidden):
    k1, k2 = jax.random.split(key)
    return {
        "wx": glorot(k1, (d_in, 3 * hidden)),
        "wh": glorot(k2, (hidden, 3 * hidden)),
        "b": jnp.zeros((3 * hidden,)),
    }


def gru_cell(p, h, x):
    """Standard GRU cell; carry = h."""
    xz = x @ p["wx"] + p["b"]
    hz = h @ p["wh"]
    xr, xu, xn = jnp.split(xz, 3, axis=-1)
    hr, hu, hn = jnp.split(hz, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    u = jax.nn.sigmoid(xu + hu)
    n = jnp.tanh(xn + r * hn)
    h = (1.0 - u) * n + u * h
    return h, h


def scan_rnn(cell, params, init_carry, xs, reverse: bool = False):
    """Run a cell over the leading (time) axis of ``xs``."""
    def step(carry, x):
        return cell(params, carry, x)
    return jax.lax.scan(step, init_carry, xs, reverse=reverse)


def masked_scan_rnn(cell, params, init_carry, xs, mask,
                    reverse: bool = False):
    """Batched cell over the TIME axis of batch-major ``xs`` (B,N,...).

    ``mask`` (B,N) freezes the carry on padding steps (the ragged
    prefix-padded batches of the compiled decode path), so the final
    carry equals what the per-sequence unpadded scan would produce; pad
    positions emit zeros.  Returns ``(final_carry, outs (B,N,H))``.
    """
    xs_t = jnp.moveaxis(xs, 1, 0)
    m_t = jnp.moveaxis(mask, 1, 0)

    def step(carry, inp):
        x_t, m = inp
        new_carry, out = cell(params, carry, x_t)
        keep = m[:, None] > 0
        new_carry = jax.tree.map(
            lambda new, old: jnp.where(keep, new, old), new_carry, carry)
        return new_carry, jnp.where(keep, out, jnp.zeros_like(out))

    carry, outs = jax.lax.scan(step, init_carry, (xs_t, m_t),
                               reverse=reverse)
    return carry, jnp.moveaxis(outs, 0, 1)


# ------------------------------------------------------------- attention --
def luong_attention(query_h, enc_outs, enc_mask):
    """Dot-product (Luong) attention: (H,), (N,H), (N,) -> context (H,)."""
    scores = enc_outs @ query_h
    scores = jnp.where(enc_mask > 0, scores, -1e30)
    w = jax.nn.softmax(scores)
    return w @ enc_outs


def luong_attention_batch(query_h, enc_outs, enc_mask):
    """Batched Luong: (B,H), (B,N,H), (B,N) -> context (B,H)."""
    scores = jnp.einsum("bnh,bh->bn", enc_outs, query_h)
    scores = jnp.where(enc_mask > 0, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bn,bnh->bh", w, enc_outs)


# ----------------------------------------------------------------- decode --
def greedy_decode(decode_step, init_state, max_len: int,
                  forced_len: int | None = None):
    """Host-side greedy autoregressive loop.

    ``decode_step(state, token) -> (state, logits)`` must be jitted by the
    caller.  Returns (m_out, tokens).  The Python loop is intentional: its
    wall-clock is linear in the number of generated tokens M — the very
    property (paper §II-A, Fig. 2a) C-NMT's latency plane relies on.

    ``forced_len`` runs EXACTLY that many steps ignoring EOS — used by the
    offline characterization to sweep a controlled (N, M) grid with real
    model execution (an untrained model's natural stopping behaviour is
    degenerate; timing is what's being measured, not translation quality).
    """
    token = jnp.asarray(BOS_ID, jnp.int32)
    state = init_state
    out = []
    steps = forced_len if forced_len is not None else max_len
    for _ in range(steps):
        state, logits = decode_step(state, token)
        token = jnp.argmax(logits).astype(jnp.int32)
        tid = int(token)
        if forced_len is None and tid == EOS_ID:
            break
        out.append(tid)
    return len(out), jnp.asarray(out, jnp.int32)


def greedy_update(tok, done, *, keep_eos: bool = False,
                  forced: bool = False):
    """ONE emission step of the greedy EOS bookkeeping.

    ``tok`` (B,) is the carried token about to be emitted, ``done`` (B,)
    the rows already past their EOS.  Returns ``(emit, live, done2)``:
    the PAD-masked emission, the rows that emitted a real pre-EOS token
    this step (what ``lengths`` counts), and the updated done mask.

    This is the single source of truth for the EOS/done semantics —
    :func:`scan_greedy_steps` applies it inside its scan body and the
    continuous slot-table session
    (:class:`repro.runtime.serving.ContinuousGenerationSession`) applies
    it once per in-flight step, so block and continuous decode cannot
    drift apart.
    """
    if forced:
        return tok, jnp.ones(tok.shape, bool), done
    is_eos = tok == EOS_ID
    live = ~(done | is_eos)                  # emits a real token now
    emit = (jnp.where(done, PAD_ID, tok) if keep_eos
            else jnp.where(live, tok, PAD_ID))
    return emit, live, done | is_eos


def scan_greedy_steps(decode_step, state, token0, batch: int, steps: int, *,
                      keep_eos: bool = False, forced: bool = False):
    """The shared compiled greedy-decode scan body.

    Carry is ``(state, next_token (B,), done (B,))``; each of the
    ``steps`` iterations emits the carried token, then steps the model
    once to produce the next (``decode_step(state, tokens (B,)) ->
    (state, logits (B,V))``).  EOS bookkeeping stays on-device:

    * ``keep_eos=False`` PAD-masks the EOS slot itself (the NMT models'
      contract — emitted tokens are exactly the pre-EOS output);
    * ``keep_eos=True`` emits the EOS token and PAD-masks only the
      positions after it (the serving sessions' contract);
    * ``forced=True`` ignores EOS entirely (controlled-(N, M) grids).

    Returns ``(lengths (B,), tokens (B, steps))`` device arrays, lengths
    counting pre-EOS tokens either way.  Both
    :func:`batched_greedy_decode` and
    :class:`repro.runtime.serving.GenerationSession` build on this one
    body, so EOS/done semantics cannot drift between them.
    """
    done0 = jnp.zeros((batch,), bool)

    def step(carry, _):
        state, tok, done = carry
        emit, live, done2 = greedy_update(tok, done, keep_eos=keep_eos,
                                          forced=forced)
        state, logits = decode_step(state, tok)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (state, nxt, done2), (emit, live)

    _, (toks, live) = jax.lax.scan(step, (state, token0, done0),
                                   None, length=steps)
    lengths = jnp.sum(live.astype(jnp.int32), axis=0)
    return lengths, jnp.transpose(toks)          # (B,), (B, steps)


def batched_greedy_decode(decode_step, init_state, batch: int, max_len: int,
                          forced_len: int | None = None):
    """Compiled batched greedy decode: ONE ``lax.scan`` over decode steps.

    ``decode_step(state, tokens (B,)) -> (state, logits (B,V))`` must carry
    a leading batch dimension (the models' ``decode_step`` with batched
    state, or a ``jax.vmap`` of the per-sequence step).  EOS handling is
    on-device: a ``done`` mask freezes finished sequences (their emitted
    slots become PAD) while the scan keeps stepping the still-live ones —
    no per-token host round-trip.

    Returns ``(lengths (B,) int32, tokens (B, steps) int32)`` as device
    arrays: per-sequence output length EXCLUDING the EOS token (the
    paper's M, matching :func:`greedy_decode`'s ``m_out`` per sequence)
    and the emitted tokens, PAD-masked at and after each EOS.

    ``forced_len`` runs exactly that many steps ignoring EOS — same
    controlled-(N, M)-grid contract as :func:`greedy_decode`.
    """
    steps = forced_len if forced_len is not None else max_len
    state, logits = decode_step(init_state,
                                jnp.full((batch,), BOS_ID, jnp.int32))
    token0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return scan_greedy_steps(decode_step, state, token0, batch, steps,
                             keep_eos=False, forced=forced_len is not None)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EncoderStates:
    """The wire format of a split placement's encoder→decoder hand-off.

    ``data`` is the model-specific encoder output pytree (hidden state
    for the GRU, annotation vectors + carries for the BiLSTM, memory +
    mask for the transformer); ``src_lens`` (B,) int32 carries the true
    source lengths so the decode tier can rebuild ragged masks without
    re-reading the tokens.  Registered as a pytree so it passes through
    ``jax.jit`` boundaries and serializes leaf-by-leaf.
    """

    data: object
    src_lens: jnp.ndarray

    def tree_flatten(self):
        return (self.data, self.src_lens), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, src_lens = children
        return cls(data, src_lens)

    @property
    def batch(self) -> int:
        return int(self.src_lens.shape[0])

    def payload_bytes(self) -> int:
        """Actual wire size: sum of leaf nbytes (what a split executor
        reports to the engine, vs. the scheduler's a-priori
        ``ActivationCostModel`` estimate)."""
        leaves = jax.tree_util.tree_leaves((self.data, self.src_lens))
        return int(sum(np.asarray(leaf).size * np.asarray(leaf).dtype.itemsize
                       for leaf in leaves))


def build_encode_states(model, params, encode_data):
    """Shared scaffolding behind the models' ``make_encode_states``.

    ``encode_data(src (B,N), src_mask (B,N)) -> pytree`` is the
    model-specific encoder pass; the wrapper jits it and packs the
    result into :class:`EncoderStates` with the per-row source lengths.
    """
    @jax.jit
    def run(src, src_mask):
        data = encode_data(src, src_mask)
        lens = jnp.sum((src_mask > 0).astype(jnp.int32), axis=-1)
        return EncoderStates(data, lens)

    def encode_states(src, src_mask=None):
        src = jnp.asarray(src, jnp.int32)
        if src_mask is None:
            src_mask = jnp.ones(src.shape, jnp.float32)
        return run(src, jnp.asarray(src_mask))

    return encode_states


def build_decode_from_states(model, params, state_from_data):
    """Shared scaffolding behind the models' ``make_decode_from_states``.

    ``state_from_data(data) -> batched decode state`` rebuilds the
    model's decode-step carry from the shipped :class:`EncoderStates`
    payload (identity for the RNNs; the transformer re-derives its
    cross-attention K/V cache decoder-side so only the raw memory
    crosses the wire).  The decode itself is the exact
    :func:`batched_greedy_decode` scan the fused path runs — parity with
    ``make_translate_batched`` is pinned bit-for-bit in tests.
    """
    step = lambda st, tok: model.decode_step(params, st, tok)

    @functools.partial(jax.jit, static_argnames=("forced_len",))
    def run(states, forced_len=None):
        state = state_from_data(states.data)
        batch = states.src_lens.shape[0]
        return batched_greedy_decode(step, state, batch,
                                     model.cfg.max_decode_len, forced_len)

    def decode_from_states(states, forced_len=None):
        return run(states, forced_len=forced_len)

    return decode_from_states


def build_translate_batched(model, params, make_state, *,
                            compiled: bool = True):
    """Shared scaffolding behind the models' ``make_translate_batched``.

    ``make_state(src (B,N), src_mask (B,N)) -> batched decode state`` is
    the only model-specific piece (encode + state assembly); stepping is
    ``model.decode_step`` with a leading batch dim.  ``compiled=True``
    jits encoder + state init + the whole scan decode into ONE dispatch
    per (B, N) shape; ``compiled=False`` is the per-sequence host loop
    (the paper-faithful, linear-in-M timing path).  Both return
    ``translate(src, src_mask=None, forced_len=None) ->
    (lengths (B,), tokens (B, steps))``.
    """
    if not compiled:
        translate = model.make_translate(params)

        def translate_host(src, src_mask=None, forced_len=None):
            return host_translate_batched(translate, src, src_mask,
                                          forced_len)
        return translate_host

    step = lambda st, tok: model.decode_step(params, st, tok)

    @functools.partial(jax.jit, static_argnames=("forced_len",))
    def run(src, src_mask, forced_len=None):
        state = make_state(src, src_mask)
        return batched_greedy_decode(step, state, src.shape[0],
                                     model.cfg.max_decode_len, forced_len)

    def translate_batch(src, src_mask=None, forced_len=None):
        src = jnp.asarray(src, jnp.int32)
        if src_mask is None:
            src_mask = jnp.ones(src.shape, jnp.float32)
        return run(src, jnp.asarray(src_mask), forced_len=forced_len)

    return translate_batch


def host_translate_batched(translate, src_tokens, src_mask=None,
                           forced_len: int | None = None):
    """Paper-faithful batch fallback: per-sequence HOST-loop translate.

    Runs ``translate`` (a model's ``make_translate`` closure) row by row
    over a prefix-padded batch — one jitted dispatch per token per
    sequence, the timing-faithful slow path the compiled scan is measured
    against.  Returns ``(lengths (B,), tokens (B, width))`` numpy arrays,
    PAD-filled past each row's length, mirroring
    :func:`batched_greedy_decode`'s contract.
    """
    src = np.asarray(src_tokens, np.int32)
    b, n = src.shape
    mask = (np.ones((b, n), np.float32) if src_mask is None
            else np.asarray(src_mask))
    src_lens = mask.astype(bool).sum(axis=1)
    lengths = np.zeros((b,), np.int32)
    rows = []
    for i in range(b):
        m_out, toks = translate(src[i, :int(src_lens[i])],
                                forced_len=forced_len)
        lengths[i] = int(m_out)
        rows.append(np.asarray(toks, np.int32))
    width = max(1, max(len(r) for r in rows))
    out = np.full((b, width), PAD_ID, np.int32)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return lengths, out


def cross_entropy(logits, targets, mask):
    """Masked token-mean CE. logits (…,V), targets (…), mask (…)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
