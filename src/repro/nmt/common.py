"""Shared building blocks for the paper-faithful seq2seq models."""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.data.tokenizer import BOS_ID, EOS_ID


@dataclasses.dataclass(frozen=True)
class RNNConfig:
    vocab_src: int = 8000
    vocab_tgt: int = 8000
    embed: int = 256
    hidden: int = 256
    layers: int = 1
    max_decode_len: int = 256


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab_src: int = 8000
    vocab_tgt: int = 8000
    d_model: int = 256
    heads: int = 8
    d_ff: int = 1024
    enc_layers: int = 6
    dec_layers: int = 6
    max_decode_len: int = 256
    max_src_len: int = 512


# ------------------------------------------------------------------ init --
def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def embed_init(key, vocab, dim, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, dim), dtype) * (dim ** -0.5)


def dense_params(key, d_in, d_out):
    kw, _ = jax.random.split(key)
    return {"w": glorot(kw, (d_in, d_out)), "b": jnp.zeros((d_out,))}


def dense(p, x):
    return x @ p["w"] + p["b"]


# ----------------------------------------------------------------- cells --
def lstm_params(key, d_in, hidden):
    k1, k2 = jax.random.split(key)
    return {
        "wx": glorot(k1, (d_in, 4 * hidden)),
        "wh": glorot(k2, (hidden, 4 * hidden)),
        "b": jnp.zeros((4 * hidden,)),
    }


def lstm_cell(p, carry, x):
    """Standard LSTM cell; carry = (h, c)."""
    h, c = carry
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def gru_params(key, d_in, hidden):
    k1, k2 = jax.random.split(key)
    return {
        "wx": glorot(k1, (d_in, 3 * hidden)),
        "wh": glorot(k2, (hidden, 3 * hidden)),
        "b": jnp.zeros((3 * hidden,)),
    }


def gru_cell(p, h, x):
    """Standard GRU cell; carry = h."""
    xz = x @ p["wx"] + p["b"]
    hz = h @ p["wh"]
    xr, xu, xn = jnp.split(xz, 3, axis=-1)
    hr, hu, hn = jnp.split(hz, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    u = jax.nn.sigmoid(xu + hu)
    n = jnp.tanh(xn + r * hn)
    h = (1.0 - u) * n + u * h
    return h, h


def scan_rnn(cell, params, init_carry, xs, reverse: bool = False):
    """Run a cell over the leading (time) axis of ``xs``."""
    def step(carry, x):
        return cell(params, carry, x)
    return jax.lax.scan(step, init_carry, xs, reverse=reverse)


# ------------------------------------------------------------- attention --
def luong_attention(query_h, enc_outs, enc_mask):
    """Dot-product (Luong) attention: (H,), (N,H), (N,) -> context (H,)."""
    scores = enc_outs @ query_h
    scores = jnp.where(enc_mask > 0, scores, -1e30)
    w = jax.nn.softmax(scores)
    return w @ enc_outs


# ----------------------------------------------------------------- decode --
def greedy_decode(decode_step, init_state, max_len: int,
                  forced_len: int | None = None):
    """Host-side greedy autoregressive loop.

    ``decode_step(state, token) -> (state, logits)`` must be jitted by the
    caller.  Returns (m_out, tokens).  The Python loop is intentional: its
    wall-clock is linear in the number of generated tokens M — the very
    property (paper §II-A, Fig. 2a) C-NMT's latency plane relies on.

    ``forced_len`` runs EXACTLY that many steps ignoring EOS — used by the
    offline characterization to sweep a controlled (N, M) grid with real
    model execution (an untrained model's natural stopping behaviour is
    degenerate; timing is what's being measured, not translation quality).
    """
    token = jnp.asarray(BOS_ID, jnp.int32)
    state = init_state
    out = []
    steps = forced_len if forced_len is not None else max_len
    for _ in range(steps):
        state, logits = decode_step(state, token)
        token = jnp.argmax(logits).astype(jnp.int32)
        tid = int(token)
        if forced_len is None and tid == EOS_ID:
            break
        out.append(tid)
    return len(out), jnp.asarray(out, jnp.int32)


def cross_entropy(logits, targets, mask):
    """Masked token-mean CE. logits (…,V), targets (…), mask (…)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
