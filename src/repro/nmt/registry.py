"""Registry of the paper's three evaluated model/dataset combinations."""

from __future__ import annotations

from typing import Tuple

from repro.nmt.common import RNNConfig, TransformerConfig
from repro.nmt.gru import GRUSeq2Seq
from repro.nmt.lstm import BiLSTMSeq2Seq
from repro.nmt.transformer import MarianTransformer

# dataset -> (model family, paper hyper-params, language pair)
PAPER_MODELS = {
    # i) 2-layer BiLSTM, hidden 500, IWSLT'14 DE-EN
    "de-en": ("bilstm", dict(layers=2, hidden=500, embed=500), "de-en"),
    # ii) 1-layer GRU, hidden 256, OPUS-100 FR-EN
    "fr-en": ("gru", dict(layers=1, hidden=256, embed=256), "fr-en"),
    # iii) MarianMT transformer, OPUS-100 EN-ZH
    "en-zh": ("marian", dict(d_model=512, heads=8, d_ff=2048,
                             enc_layers=6, dec_layers=6), "en-zh"),
}


def make_paper_model(dataset: str, *, scale: float = 1.0,
                     vocab: int = 8000, max_decode_len: int = 256,
                     attn_impl: str = "xla"):
    """Instantiate the paper's model for ``dataset``.

    ``scale`` shrinks widths/layers for CPU-budget-friendly calibration
    runs (scale=1 is the paper's size). Latency *linearity* in N and M —
    the property C-NMT exploits — is scale-invariant; the fitted
    alpha/beta just shrink with it.  ``attn_impl`` selects the Marian
    attention backend for the batched paths ("xla" | "pallas"); the RNN
    models ignore it.
    """
    family, hp, pair = PAPER_MODELS[dataset]
    s = lambda v: max(8, int(v * scale))
    if family in ("bilstm", "gru"):
        cfg = RNNConfig(
            vocab_src=vocab, vocab_tgt=vocab,
            embed=s(hp["embed"]), hidden=s(hp["hidden"]),
            layers=hp["layers"], max_decode_len=max_decode_len,
        )
        model = BiLSTMSeq2Seq(cfg) if family == "bilstm" else GRUSeq2Seq(cfg)
    else:
        heads = min(8, max(2, int(8 * scale)))
        d_model = max(heads * 8, (s(hp["d_model"]) // heads) * heads)
        cfg = TransformerConfig(
            vocab_src=vocab, vocab_tgt=vocab,
            d_model=d_model, heads=heads,
            d_ff=s(hp["d_ff"]),
            enc_layers=max(1, int(hp["enc_layers"] * min(scale * 2, 1.0))),
            dec_layers=max(1, int(hp["dec_layers"] * min(scale * 2, 1.0))),
            max_decode_len=max_decode_len,
        )
        model = MarianTransformer(cfg, attn_impl=attn_impl)
    return model, pair
