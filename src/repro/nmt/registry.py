"""Registry of the paper's three evaluated model/dataset combinations.

Deprecated entry point: model construction now lives in the unified
:mod:`repro.models.registry` (``resolve("cnmt:de-en")``).
:func:`make_paper_model` remains as a thin shim that emits
``DeprecationWarning`` and delegates there.
"""

from __future__ import annotations

import warnings

# dataset -> (model family, paper hyper-params, language pair)
PAPER_MODELS = {
    # i) 2-layer BiLSTM, hidden 500, IWSLT'14 DE-EN
    "de-en": ("bilstm", dict(layers=2, hidden=500, embed=500), "de-en"),
    # ii) 1-layer GRU, hidden 256, OPUS-100 FR-EN
    "fr-en": ("gru", dict(layers=1, hidden=256, embed=256), "fr-en"),
    # iii) MarianMT transformer, OPUS-100 EN-ZH
    "en-zh": ("marian", dict(d_model=512, heads=8, d_ff=2048,
                             enc_layers=6, dec_layers=6), "en-zh"),
}


def make_paper_model(dataset: str, *, scale: float = 1.0,
                     vocab: int = 8000, max_decode_len: int = 256,
                     attn_impl: str = "xla"):
    """Deprecated alias for ``repro.models.registry.resolve(f"cnmt:{dataset}",
    ...)``; returns the legacy ``(model, pair)`` tuple."""
    warnings.warn(
        "make_paper_model is deprecated; use "
        "repro.models.registry.resolve('cnmt:<pair>', ...)",
        DeprecationWarning, stacklevel=2)
    from repro.models.registry import resolve
    r = resolve(f"cnmt:{dataset}", scale=scale, vocab=vocab,
                max_decode_len=max_decode_len, attn_impl=attn_impl)
    return r.model, r.pair
