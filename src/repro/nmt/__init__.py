"""Paper-faithful NMT models (§III): the three architectures C-NMT was
evaluated on, implemented in pure JAX and runnable on this CPU.

* :class:`BiLSTMSeq2Seq`      — 2-layer BiLSTM encoder + attention LSTM
                                decoder, hidden 500 (OpenNMT recipe,
                                IWSLT'14 DE-EN in the paper).
* :class:`GRUSeq2Seq`         — single-layer GRU encoder/decoder, hidden
                                256 (OPUS-100 FR-EN in the paper).
* :class:`MarianTransformer`  — Marian-style encoder-decoder transformer
                                (OPUS-100 EN-ZH in the paper).

All models expose the same surface:
  ``init(key)``, ``encode``, ``decode_step``, ``translate`` (greedy,
  autoregressive — the host loop whose wall-clock is linear in M),
  ``make_translate_batched`` (the compiled scan fast path: one XLA
  dispatch decodes a whole padded batch; ``compiled=False`` falls back
  to the per-sequence host loop for paper-faithful timing),
  and ``forward_teacher`` (batched teacher-forced logits for training).
"""

from repro.nmt.common import (
    RNNConfig,
    TransformerConfig,
    batched_greedy_decode,
    greedy_decode,
)
from repro.nmt.lstm import BiLSTMSeq2Seq
from repro.nmt.gru import GRUSeq2Seq
from repro.nmt.transformer import MarianTransformer
from repro.nmt.registry import PAPER_MODELS, make_paper_model

__all__ = [
    "RNNConfig",
    "TransformerConfig",
    "batched_greedy_decode",
    "greedy_decode",
    "BiLSTMSeq2Seq",
    "GRUSeq2Seq",
    "MarianTransformer",
    "PAPER_MODELS",
    "make_paper_model",
]
