"""2-layer BiLSTM encoder + attention LSTM decoder (paper model #1).

Mirrors the OpenNMT recipe the paper cites ([16]): bidirectional LSTM
encoder, unidirectional LSTM decoder with Luong (dot) global attention,
hidden size 500 on IWSLT'14 DE-EN.  Pure JAX, ``lax.scan`` recurrences —
the strict step dependency is exactly what makes T_exe linear in N and M
(paper §II-A).
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nmt.common import (
    RNNConfig,
    build_decode_from_states,
    build_encode_states,
    build_translate_batched,
    cross_entropy,
    dense,
    dense_params,
    embed_init,
    greedy_decode,
    lstm_cell,
    lstm_params,
    luong_attention,
    luong_attention_batch,
    masked_scan_rnn,
    scan_rnn,
)


class BiLSTMSeq2Seq:
    def __init__(self, cfg: RNNConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params
    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = iter(jax.random.split(key, 64))
        enc = []
        d_in = cfg.embed
        for _ in range(cfg.layers):
            enc.append({
                "fwd": lstm_params(next(keys), d_in, cfg.hidden),
                "bwd": lstm_params(next(keys), d_in, cfg.hidden),
                # project the 2H bidirectional output back to H
                "proj": dense_params(next(keys), 2 * cfg.hidden, cfg.hidden),
            })
            d_in = cfg.hidden
        dec = []
        d_in = cfg.embed
        for _ in range(cfg.layers):
            dec.append(lstm_params(next(keys), d_in, cfg.hidden))
            d_in = cfg.hidden
        return {
            "src_embed": embed_init(next(keys), cfg.vocab_src, cfg.embed),
            "tgt_embed": embed_init(next(keys), cfg.vocab_tgt, cfg.embed),
            "enc": enc,
            "dec": dec,
            "attn_combine": dense_params(next(keys), 2 * cfg.hidden, cfg.hidden),
            "out": dense_params(next(keys), cfg.hidden, cfg.vocab_tgt),
        }

    # ------------------------------------------------------------- encode
    def encode(self, params, src_tokens, src_mask=None):
        """src_tokens (N,) int32 -> enc_outs (N,H), decoder init carries.

        Batched (B,N) inputs take the masked-scan path: the recurrence
        freezes on padding steps (both directions), so each prefix-padded
        row's final states match its trimmed self; pad positions of
        ``enc_outs`` are zeros and masked out of attention downstream.
        """
        cfg = self.cfg
        x = params["src_embed"][src_tokens]
        if src_mask is None:
            src_mask = jnp.ones(src_tokens.shape, jnp.float32)
        if src_tokens.ndim == 2:
            b = src_tokens.shape[0]
            h0 = jnp.zeros((b, cfg.hidden))
            carries_for_dec = []
            for layer in params["enc"]:
                (hf, cf), outs_f = masked_scan_rnn(
                    lstm_cell, layer["fwd"], (h0, h0), x, src_mask)
                (hb, cb), outs_b = masked_scan_rnn(
                    lstm_cell, layer["bwd"], (h0, h0), x, src_mask,
                    reverse=True)
                x = dense(layer["proj"],
                          jnp.concatenate([outs_f, outs_b], axis=-1))
                x = jnp.tanh(x)
                carries_for_dec.append((0.5 * (hf + hb), 0.5 * (cf + cb)))
            return x, tuple(carries_for_dec), src_mask
        h0 = jnp.zeros((cfg.hidden,))
        carries_for_dec = []
        for layer in params["enc"]:
            (hf, cf), outs_f = scan_rnn(lstm_cell, layer["fwd"], (h0, h0), x)
            (hb, cb), outs_b = scan_rnn(lstm_cell, layer["bwd"], (h0, h0), x,
                                        reverse=True)
            x = dense(layer["proj"], jnp.concatenate([outs_f, outs_b], axis=-1))
            x = jnp.tanh(x)
            # decoder layer l starts from the mean of fwd/bwd final states
            carries_for_dec.append((0.5 * (hf + hb), 0.5 * (cf + cb)))
        return x, tuple(carries_for_dec), src_mask

    # -------------------------------------------------------- decode step
    def decode_step(self, params, state, token):
        """One autoregressive step.  state = (carries, enc_outs, enc_mask).

        Batch-polymorphic: with ``token`` (B,) and state carrying a
        leading batch dimension it advances all sequences at once (the
        compiled-scan decode path).
        """
        carries, enc_outs, enc_mask = state
        x = params["tgt_embed"][token]
        new_carries = []
        for layer_p, carry in zip(params["dec"], carries):
            carry, x = lstm_cell(layer_p, carry, x)
            new_carries.append(carry)
        attend = luong_attention_batch if jnp.ndim(token) else luong_attention
        ctx = attend(x, enc_outs, enc_mask)
        x = jnp.tanh(dense(params["attn_combine"],
                           jnp.concatenate([x, ctx], axis=-1)))
        logits = dense(params["out"], x)
        return (tuple(new_carries), enc_outs, enc_mask), logits

    # ---------------------------------------------------------- translate
    def make_translate(self, params):
        """Returns translate(src_tokens) -> (m_out, tokens), jit-backed."""
        encode = jax.jit(lambda s: self.encode(params, s))
        step = jax.jit(lambda st, tok: self.decode_step(params, st, tok))

        def translate(src_tokens, forced_len=None):
            enc_outs, carries, mask = encode(jnp.asarray(src_tokens))
            state = (carries, enc_outs, mask)
            return greedy_decode(step, state, self.cfg.max_decode_len,
                                 forced_len=forced_len)

        return translate

    def make_translate_batched(self, params, *, compiled: bool = True):
        """Batched translate: (B,N) [+ (B,N) mask] -> (lengths, tokens).

        ``compiled=True`` runs the single-dispatch scan fast path;
        ``compiled=False`` the per-sequence host loop (paper-faithful
        timing path).
        """
        def make_state(src, mask):
            enc_outs, carries, m = self.encode(params, src, mask)
            return (carries, enc_outs, m)

        return build_translate_batched(self, params, make_state,
                                       compiled=compiled)

    def make_encode_states(self, params):
        """Encode leg of a split placement: ships the decode-step state
        verbatim — (carries, annotation vectors (B,N,H), enc mask)."""
        def encode_data(src, mask):
            enc_outs, carries, m = self.encode(params, src, mask)
            return (carries, enc_outs, m)

        return build_encode_states(self, params, encode_data)

    def make_decode_from_states(self, params):
        """Decode leg: EncoderStates -> (lengths, tokens); shipped data
        is already the decode carry."""
        return build_decode_from_states(self, params, lambda data: data)

    # ------------------------------------------------------------- train
    def forward_teacher(self, params, src, src_mask, tgt_in):
        """Batched teacher-forced logits: (B,N),(B,N),(B,M) -> (B,M,V)."""
        def single(src_i, mask_i, tgt_i):
            enc_outs, carries, m = self.encode(params, src_i, mask_i)
            def step(carry_state, tok):
                state, _ = self.decode_step(params, carry_state, tok)
                return state, _
            state0 = (carries, enc_outs, m)
            _, logits = jax.lax.scan(
                lambda st, tok: self.decode_step(params, st, tok), state0, tgt_i
            )
            return logits
        return jax.vmap(single)(src, src_mask, tgt_in)

    def loss(self, params, batch):
        logits = self.forward_teacher(
            params, batch["src"], batch["src_mask"], batch["tgt_in"]
        )
        return cross_entropy(logits, batch["tgt_out"], batch["tgt_mask"])
