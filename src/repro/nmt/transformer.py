"""Marian-style encoder-decoder Transformer (paper model #3).

MarianMT ([20]) is a standard post-norm Transformer ("Attention Is All You
Need" base): sinusoidal positions, 6+6 layers, 8 heads.  The computational
profile the paper measures — parallel encoder (T ~ const in N for short
inputs on parallel hardware) vs strictly sequential masked-attention
decoding (T linear in M) — comes from this implementation's two paths:

* ``encode``      — one parallel pass over all N tokens;
* ``decode_step`` — one token at a time against a fixed-size KV cache
  (the production decode path; state carries per-layer K/V).

Both paths carry an optional leading BATCH dimension (2-D ``src_tokens``
/ 1-D ``token`` vectors) with per-sequence ``pos`` and prefix masks —
the compiled serving fast path (``make_translate_batched`` +
``batched_greedy_decode``) decodes a whole padded batch in one
``lax.scan``.

``attn_impl`` selects the attention backend for the batched paths:

* ``"xla"``    — plain einsum attention (default; XLA fuses it fine on
  CPU, and it is the bit-for-bit reference for the batched tests);
* ``"pallas"`` — routes the batched encoder and the teacher-forced
  decoder through :mod:`repro.kernels.flash_attention` and the cached
  decode step through :mod:`repro.kernels.decode_attention` (flash
  decode against the KV cache, lengths = pos+1 / source lengths).  On
  CPU the kernels run in interpret mode — validation of the production
  TPU path, not a CPU speedup.

The per-sequence (unbatched) methods keep the original einsum
implementation regardless of ``attn_impl`` — they are the
paper-faithful characterization path.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nmt.common import (
    TransformerConfig,
    build_decode_from_states,
    build_encode_states,
    build_translate_batched,
    cross_entropy,
    dense,
    dense_params,
    embed_init,
    greedy_decode,
)


def sinusoidal(max_len: int, d_model: int):
    pos = jnp.arange(max_len)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d_model, 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((max_len, d_model))
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def layer_norm(p, x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def ln_params(d):
    return {"g": jnp.ones((d,)), "b": jnp.zeros((d,))}


def mha_params(key, d_model):
    k = jax.random.split(key, 4)
    return {
        "q": dense_params(k[0], d_model, d_model),
        "k": dense_params(k[1], d_model, d_model),
        "v": dense_params(k[2], d_model, d_model),
        "o": dense_params(k[3], d_model, d_model),
    }


def _split_heads(x, heads):
    *lead, d = x.shape
    return x.reshape(*lead, heads, d // heads)


def mha(p, q_in, kv_in, heads, mask=None):
    """Full multi-head attention. q_in (Tq,D), kv_in (Tk,D)."""
    q = _split_heads(dense(p["q"], q_in), heads)        # (Tq,h,dh)
    k = _split_heads(dense(p["k"], kv_in), heads)
    v = _split_heads(dense(p["v"], kv_in), heads)
    dh = q.shape[-1]
    scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(dh)
    if mask is not None:
        scores = jnp.where(mask[None, :, :] > 0, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", w, v)
    return dense(p["o"], out.reshape(q_in.shape[0], -1))


def ffn_params(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {"in": dense_params(k1, d_model, d_ff),
            "out": dense_params(k2, d_ff, d_model)}


def ffn(p, x):
    return dense(p["out"], jax.nn.relu(dense(p["in"], x)))


class MarianTransformer:
    def __init__(self, cfg: TransformerConfig, attn_impl: str = "xla"):
        if attn_impl not in ("xla", "pallas"):
            raise ValueError(f"attn_impl must be 'xla'|'pallas', got {attn_impl!r}")
        self.cfg = cfg
        self.attn_impl = attn_impl
        self._pe = sinusoidal(max(cfg.max_src_len, cfg.max_decode_len) + 1,
                              cfg.d_model)

    # one (B,S,D) tensor -> (B,S,h,dh) heads view and back
    def _heads(self, x):
        b, s, d = x.shape
        return x.reshape(b, s, self.cfg.heads, d // self.cfg.heads)

    # ------------------------------------------------------------- params
    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = iter(jax.random.split(key, 8 * (cfg.enc_layers + cfg.dec_layers) + 8))
        enc_layers = []
        for _ in range(cfg.enc_layers):
            enc_layers.append({
                "attn": mha_params(next(keys), cfg.d_model),
                "ln1": ln_params(cfg.d_model),
                "ffn": ffn_params(next(keys), cfg.d_model, cfg.d_ff),
                "ln2": ln_params(cfg.d_model),
            })
        dec_layers = []
        for _ in range(cfg.dec_layers):
            dec_layers.append({
                "self": mha_params(next(keys), cfg.d_model),
                "ln1": ln_params(cfg.d_model),
                "cross": mha_params(next(keys), cfg.d_model),
                "ln2": ln_params(cfg.d_model),
                "ffn": ffn_params(next(keys), cfg.d_model, cfg.d_ff),
                "ln3": ln_params(cfg.d_model),
            })
        return {
            "src_embed": embed_init(next(keys), cfg.vocab_src, cfg.d_model),
            "tgt_embed": embed_init(next(keys), cfg.vocab_tgt, cfg.d_model),
            "enc": enc_layers,
            "dec": dec_layers,
            "out": dense_params(next(keys), cfg.d_model, cfg.vocab_tgt),
        }

    # ------------------------------------------------------------- encode
    def encode(self, params, src_tokens, src_mask=None):
        """(N,) -> (enc_outs (N,D), mask); batched (B,N) -> ((B,N,D), (B,N)).

        The batched path expects prefix masks (real tokens first, padding
        after) — the serving batcher's discipline — and routes attention
        through the backend selected by ``attn_impl``.
        """
        if src_tokens.ndim == 2:
            return self._encode_batch(params, src_tokens, src_mask)
        cfg = self.cfg
        n = src_tokens.shape[0]
        if src_mask is None:
            src_mask = jnp.ones((n,), jnp.float32)
        x = params["src_embed"][src_tokens] * jnp.sqrt(float(cfg.d_model))
        x = x + self._pe[:n]
        attn_mask = src_mask[None, :] * jnp.ones((n, 1))
        for layer in params["enc"]:
            x = layer_norm(layer["ln1"], x + mha(layer["attn"], x, x,
                                                 cfg.heads, attn_mask))
            x = layer_norm(layer["ln2"], x + ffn(layer["ffn"], x))
        return x, src_mask

    def _attend_batch(self, p, q_in, kv_in, lengths, *, causal: bool):
        """Batched MHA with valid-key-prefix masking, on either backend.

        q_in (B,S,D), kv_in (B,T,D), lengths (B,) -> (B,S,D).
        """
        from repro.kernels import ops as kernel_ops

        q = self._heads(dense(p["q"], q_in))
        k = self._heads(dense(p["k"], kv_in))
        v = self._heads(dense(p["v"], kv_in))
        if self.attn_impl == "pallas":
            out = kernel_ops.flash_attention(q, k, v, lengths, causal=causal)
        else:
            dh = q.shape[-1]
            s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
            t = kv_in.shape[1]
            valid = jnp.arange(t)[None, :] < lengths[:, None]     # (B,T)
            if causal:
                tri = jnp.tril(jnp.ones((q_in.shape[1], t), bool))
                keymask = valid[:, None, None, :] & tri[None, None, :, :]
            else:
                keymask = valid[:, None, None, :]
            s = jnp.where(keymask, s, -1e30)
            w = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        b, sq = q_in.shape[0], q_in.shape[1]
        return dense(p["o"], out.reshape(b, sq, -1))

    def _encode_batch(self, params, src_tokens, src_mask):
        cfg = self.cfg
        b, n = src_tokens.shape
        if src_mask is None:
            src_mask = jnp.ones((b, n), jnp.float32)
        # >= 1 valid key per row: the attention kernels' contract (an
        # all-pad row then attends slot 0 only; its output is discarded)
        lengths = jnp.maximum(
            jnp.sum(src_mask > 0, axis=-1).astype(jnp.int32), 1)
        x = params["src_embed"][src_tokens] * jnp.sqrt(float(cfg.d_model))
        x = x + self._pe[:n]
        for layer in params["enc"]:
            a = self._attend_batch(layer["attn"], x, x, lengths, causal=False)
            x = layer_norm(layer["ln1"], x + a)
            x = layer_norm(layer["ln2"], x + ffn(layer["ffn"], x))
        return x, src_mask

    # ---------------------------------------------------- decoder w/ cache
    def init_cache(self, params, enc_outs, enc_mask):
        """Pre-compute cross-attention K/V; allocate fixed-size self K/V.

        Batched ``enc_outs`` (B,N,D) yield a batched cache: per-layer
        (B, max_decode_len, D) self K/V, per-sequence ``pos`` (B,).
        """
        cfg = self.cfg
        if enc_outs.ndim == 3:
            b = enc_outs.shape[0]
            layers = []
            for layer in params["dec"]:
                layers.append({
                    "k": jnp.zeros((b, cfg.max_decode_len, cfg.d_model)),
                    "v": jnp.zeros((b, cfg.max_decode_len, cfg.d_model)),
                    "xk": dense(layer["cross"]["k"], enc_outs),
                    "xv": dense(layer["cross"]["v"], enc_outs),
                })
            return {"layers": layers, "pos": jnp.zeros((b,), jnp.int32),
                    "enc_mask": enc_mask}
        layers = []
        for layer in params["dec"]:
            layers.append({
                "k": jnp.zeros((cfg.max_decode_len, cfg.d_model)),
                "v": jnp.zeros((cfg.max_decode_len, cfg.d_model)),
                "xk": dense(layer["cross"]["k"], enc_outs),
                "xv": dense(layer["cross"]["v"], enc_outs),
            })
        return {"layers": layers, "pos": jnp.asarray(0, jnp.int32),
                "enc_mask": enc_mask}

    def _cached_attn_batch(self, q, kh, vh, lengths):
        """One-query-token attention against a (B,T,D) cache.

        q (B,D), kh/vh (B,T,D), lengths (B,) = valid slots -> (B,D).
        ``attn_impl="pallas"`` routes through the flash-decode kernel.
        """
        from repro.kernels import ops as kernel_ops

        heads = self.cfg.heads
        b, t, d = kh.shape
        dh = d // heads
        qh = q.reshape(b, heads, dh)
        if self.attn_impl == "pallas":
            out = kernel_ops.flash_decode(
                qh, kh.reshape(b, t, heads, dh), vh.reshape(b, t, heads, dh),
                lengths)
            return out.reshape(b, d)
        s = jnp.einsum("bhd,bthd->bht", qh,
                       kh.reshape(b, t, heads, dh)) / jnp.sqrt(dh)
        valid = jnp.arange(t)[None, :] < lengths[:, None]
        s = jnp.where(valid[:, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bht,bthd->bhd", w,
                          vh.reshape(b, t, heads, dh)).reshape(b, d)

    def _decode_step_batch(self, params, state, token):
        """One decode step for a whole batch: token (B,) -> logits (B,V)."""
        cfg = self.cfg
        pos = state["pos"]                                    # (B,)
        enc_mask = state["enc_mask"]                          # (B,N)
        b = token.shape[0]
        bidx = jnp.arange(b)
        src_lens = jnp.maximum(
            jnp.sum(enc_mask > 0, axis=-1).astype(jnp.int32), 1)
        x = params["tgt_embed"][token] * jnp.sqrt(float(cfg.d_model))
        x = x + self._pe[pos]                                 # (B,D)
        new_layers = []
        for layer, cache in zip(params["dec"], state["layers"]):
            # self attention against the per-sequence KV cache
            k_new = dense(layer["self"]["k"], x)
            v_new = dense(layer["self"]["v"], x)
            ck = cache["k"].at[bidx, pos].set(k_new)
            cv = cache["v"].at[bidx, pos].set(v_new)
            a = self._cached_attn_batch(dense(layer["self"]["q"], x),
                                        ck, cv, pos + 1)
            x = layer_norm(layer["ln1"], x + dense(layer["self"]["o"], a))
            # cross attention against precomputed encoder K/V
            a = self._cached_attn_batch(dense(layer["cross"]["q"], x),
                                        cache["xk"], cache["xv"], src_lens)
            x = layer_norm(layer["ln2"], x + dense(layer["cross"]["o"], a))
            x = layer_norm(layer["ln3"], x + ffn(layer["ffn"], x))
            new_layers.append({"k": ck, "v": cv, "xk": cache["xk"],
                               "xv": cache["xv"]})
        logits = dense(params["out"], x)
        return ({"layers": new_layers, "pos": pos + 1,
                 "enc_mask": enc_mask}, logits)

    def decode_step(self, params, state, token):
        """One masked-attention step against the KV cache.

        ``token`` (B,) with a batched cache advances the whole batch in
        one step (per-sequence ``pos``); scalar ``token`` keeps the
        original per-sequence path.
        """
        if jnp.ndim(token) >= 1:
            return self._decode_step_batch(params, state, token)
        cfg = self.cfg
        heads = cfg.heads
        pos = state["pos"]
        x = params["tgt_embed"][token] * jnp.sqrt(float(cfg.d_model))
        x = x + self._pe[pos]
        new_layers = []
        valid = (jnp.arange(cfg.max_decode_len) <= pos).astype(jnp.float32)
        for layer, cache in zip(params["dec"], state["layers"]):
            # self attention against cache
            k_new = dense(layer["self"]["k"], x)
            v_new = dense(layer["self"]["v"], x)
            ck = cache["k"].at[pos].set(k_new)
            cv = cache["v"].at[pos].set(v_new)
            q = _split_heads(dense(layer["self"]["q"], x), heads)      # (h,dh)
            kh = _split_heads(ck, heads)                               # (T,h,dh)
            vh = _split_heads(cv, heads)
            dh = q.shape[-1]
            s = jnp.einsum("hd,thd->ht", q, kh) / jnp.sqrt(dh)
            s = jnp.where(valid[None, :] > 0, s, -1e30)
            w = jax.nn.softmax(s, axis=-1)
            a = jnp.einsum("ht,thd->hd", w, vh).reshape(-1)
            x = layer_norm(layer["ln1"], x + dense(layer["self"]["o"], a))
            # cross attention against precomputed encoder K/V
            q = _split_heads(dense(layer["cross"]["q"], x), heads)
            kh = _split_heads(cache["xk"], heads)
            vh = _split_heads(cache["xv"], heads)
            s = jnp.einsum("hd,thd->ht", q, kh) / jnp.sqrt(dh)
            s = jnp.where(state["enc_mask"][None, :] > 0, s, -1e30)
            w = jax.nn.softmax(s, axis=-1)
            a = jnp.einsum("ht,thd->hd", w, vh).reshape(-1)
            x = layer_norm(layer["ln2"], x + dense(layer["cross"]["o"], a))
            x = layer_norm(layer["ln3"], x + ffn(layer["ffn"], x))
            new_layers.append({"k": ck, "v": cv, "xk": cache["xk"],
                               "xv": cache["xv"]})
        logits = dense(params["out"], x)
        return ({"layers": new_layers, "pos": pos + 1,
                 "enc_mask": state["enc_mask"]}, logits)

    # ---------------------------------------------------------- translate
    def make_translate(self, params):
        encode = jax.jit(lambda s: self.encode(params, s))
        step = jax.jit(lambda st, tok: self.decode_step(params, st, tok))

        def translate(src_tokens, forced_len=None):
            enc_outs, mask = encode(jnp.asarray(src_tokens))
            state = self.init_cache(params, enc_outs, mask)
            return greedy_decode(step, state, self.cfg.max_decode_len,
                                 forced_len=forced_len)

        return translate

    def make_translate_batched(self, params, *, compiled: bool = True):
        """Batched translate: (B,N) [+ (B,N) mask] -> (lengths, tokens).

        ``compiled=True`` is the scan fast path — encoder, cache init and
        the whole greedy decode compile into ONE dispatch per (B, N)
        shape; ``compiled=False`` is the per-sequence host loop whose
        wall-clock stays linear in M (the Fig. 2a timing path).
        """
        def make_state(src, mask):
            enc_outs, m = self.encode(params, src, mask)
            return self.init_cache(params, enc_outs, m)

        return build_translate_batched(self, params, make_state,
                                       compiled=compiled)

    def make_encode_states(self, params):
        """Encode leg of a split placement: ships only the encoder
        memory (B,N,D) + mask — NOT the decoder cache.  The cross-
        attention K/V projections use *decoder* parameters, so they are
        rebuilt on the decode tier (see ``make_decode_from_states``),
        keeping the wire payload at n x d_model as the scheduler's
        `ActivationCostModel` prices it."""
        return build_encode_states(
            self, params,
            lambda src, mask: self.encode(params, src, mask))

    def make_decode_from_states(self, params):
        """Decode leg: rebuilds the KV cache (cross K/V projections +
        empty self K/V) from the shipped memory, then runs the exact
        compiled scan decode of the fused path."""
        def state_from_data(data):
            enc_outs, m = data
            return self.init_cache(params, enc_outs, m)

        return build_decode_from_states(self, params, state_from_data)

    # -------------------------------------------------------------- train
    def forward_teacher(self, params, src, src_mask, tgt_in):
        """Batched parallel (causally-masked) teacher-forced logits.

        With ``attn_impl="pallas"`` the whole stack (encoder self-attn,
        decoder causal self-attn, cross-attn) runs through the flash
        kernel; the default is the vmapped einsum reference.
        """
        cfg = self.cfg
        if self.attn_impl == "pallas":
            enc_outs, m = self._encode_batch(params, src, src_mask)
            src_lens = jnp.maximum(
                jnp.sum(m > 0, axis=-1).astype(jnp.int32), 1)
            b, t = tgt_in.shape
            tgt_lens = jnp.full((b,), t, jnp.int32)
            x = params["tgt_embed"][tgt_in] * jnp.sqrt(float(cfg.d_model))
            x = x + self._pe[:t]
            for layer in params["dec"]:
                a = self._attend_batch(layer["self"], x, x, tgt_lens,
                                       causal=True)
                x = layer_norm(layer["ln1"], x + a)
                a = self._attend_batch(layer["cross"], x, enc_outs,
                                       src_lens, causal=False)
                x = layer_norm(layer["ln2"], x + a)
                x = layer_norm(layer["ln3"], x + ffn(layer["ffn"], x))
            return dense(params["out"], x)

        def single(src_i, mask_i, tgt_i):
            enc_outs, m = self.encode(params, src_i, mask_i)
            t = tgt_i.shape[0]
            x = params["tgt_embed"][tgt_i] * jnp.sqrt(float(cfg.d_model))
            x = x + self._pe[:t]
            causal = jnp.tril(jnp.ones((t, t)))
            cross_m = m[None, :] * jnp.ones((t, 1))
            for layer in params["dec"]:
                x = layer_norm(layer["ln1"],
                               x + mha(layer["self"], x, x, cfg.heads, causal))
                x = layer_norm(layer["ln2"],
                               x + mha(layer["cross"], x, enc_outs, cfg.heads,
                                       cross_m))
                x = layer_norm(layer["ln3"], x + ffn(layer["ffn"], x))
            return dense(params["out"], x)

        return jax.vmap(single)(src, src_mask, tgt_in)

    def loss(self, params, batch):
        logits = self.forward_teacher(
            params, batch["src"], batch["src_mask"], batch["tgt_in"]
        )
        return cross_entropy(logits, batch["tgt_out"], batch["tgt_mask"])
