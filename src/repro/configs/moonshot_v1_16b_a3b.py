"""moonshot-v1-16b-a3b — Moonlight-16B-A3B (DeepSeek-style MoE)
[hf:moonshotai/Moonlight-16B-A3B].

48L, d_model=2048, 16 heads (kv=16 per assignment), expert d_ff=1408,
64 experts top-6 + 2 shared experts, first layer dense (d_ff 8*1408),
vocab 163840.
"""

from repro.models.config import LayerGroup, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="dense",     # assignment lists it under dense (MoE inside)
    d_model=2048,
    vocab_size=163840,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=11264,            # first dense layer (8 * expert width)
    layer_plan=(
        LayerGroup(mixer="attn", ffn="dense", count=1),
        LayerGroup(mixer="attn", ffn="moe", count=47),
    ),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2),
    supports_long_decode=False,
    citation="hf:moonshotai/Moonlight-16B-A3B",
)
