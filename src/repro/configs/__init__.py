"""Architecture registry: the 10 assigned configs + smoke reductions +
input shapes.

``get_config(name)`` returns the exact assigned configuration;
``get_config(name, shape="long_500k")`` swaps in the documented
long-decode variant where one exists (sliding-window ring cache).
``smoke_config(cfg)`` builds the reduced same-family variant used by the
CPU smoke tests (<=2 layers per group kind, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.config import (
    EncoderConfig,
    LayerGroup,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
)

from repro.configs import (
    chameleon_34b,
    deepseek_67b,
    deepseek_v3_671b,
    moonshot_v1_16b_a3b,
    qwen3_8b,
    qwen3_32b,
    qwen3_moe_30b_a3b,
    rwkv6_3b,
    whisper_large_v3,
    zamba2_1p2b,
)

_MODULES = {
    "rwkv6-3b": rwkv6_3b,
    "whisper-large-v3": whisper_large_v3,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "zamba2-1.2b": zamba2_1p2b,
    "qwen3-32b": qwen3_32b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "deepseek-67b": deepseek_67b,
    "qwen3-8b": qwen3_8b,
    "chameleon-34b": chameleon_34b,
}

ARCH_NAMES = tuple(_MODULES)

# The four assigned input shapes: name -> (seq_len, global_batch, kind)
INPUT_SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_config(name: str, shape: Optional[str] = None) -> ModelConfig:
    mod = _MODULES[name]
    cfg: ModelConfig = mod.CONFIG
    if shape == "long_500k" and hasattr(mod, "long_decode_variant"):
        cfg = mod.long_decode_variant()
    return cfg.validate()


def shape_supported(name: str, shape: str) -> Tuple[bool, str]:
    """Whether (arch, shape) is runnable; returns (ok, reason-if-not)."""
    cfg = _MODULES[name].CONFIG
    if shape == "long_500k" and not cfg.supports_long_decode:
        return False, ("full-attention KV cache is O(context): skipped per "
                       "DESIGN.md §long_500k")
    return True, ""


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family variant: <=2 layers/group-kind, d_model<=512,
    <=4 experts — runnable on CPU in a smoke test."""
    cfg = _MODULES[name].CONFIG
    plan = []
    seen_kinds = set()
    for g in cfg.layer_plan:
        key = (g.mixer, g.ffn)
        if key in seen_kinds:
            continue
        seen_kinds.add(key)
        plan.append(dataclasses.replace(g, count=min(g.count, 2)))
    kw = dict(
        name=cfg.name + "-smoke",
        d_model=256,
        vocab_size=512,
        layer_plan=tuple(plan),
        d_ff=max(1, min(cfg.d_ff, 512)) if cfg.d_ff else 0,
        sliding_window=cfg.sliding_window and min(cfg.sliding_window, 8),
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=max(1, 4 * cfg.num_kv_heads
                                                // cfg.num_heads),
                  head_dim=64)
    if cfg.moe:
        # capacity_factor = E/k -> capacity >= group size: drop-free, so
        # decode and teacher-forced paths agree exactly in the smoke tests
        # (the full configs keep the assigned 1.25 dropping behaviour).
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_ff_expert=128,
            capacity_factor=2.0)
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
        kw.update(num_heads=4, num_kv_heads=4, head_dim=32)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=32,
                                        chunk=8)
    if cfg.rwkv:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32,
                                         decay_lora=16)
    if cfg.encoder:
        kw["encoder"] = EncoderConfig(num_layers=2, max_frames=16)
    return dataclasses.replace(cfg, **kw).validate()
