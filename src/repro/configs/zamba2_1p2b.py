"""zamba2-1.2b — Mamba2 backbone + ONE shared attention block applied at
intervals [arXiv:2411.15242].

38 layer slots: repeating (5 x mamba2, 1 x shared attn+MLP) x 6 + 2
trailing mamba2 = 32 mamba + 6 invocations of the single shared
transformer block (weights stored once).  d_model=2048, ssm_state=64,
attn 32 heads (kv=32, head_dim 64), shared-MLP d_ff=8192, vocab 32000.

supports_long_decode: mamba state is O(1); for the 500k shape the shared
attention runs with a 4096 sliding window (ring cache) — see
``long_decode_variant``.
"""

import dataclasses

from repro.models.config import LayerGroup, ModelConfig, SSMConfig

_PLAN = []
for _ in range(6):
    _PLAN.append(LayerGroup(mixer="mamba2", ffn="none", count=5))
    _PLAN.append(LayerGroup(mixer="shared_attn", ffn="dense", count=1))
_PLAN.append(LayerGroup(mixer="mamba2", ffn="none", count=2))

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    d_model=2048,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    layer_plan=tuple(_PLAN),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk=128),
    supports_long_decode=True,
    citation="arXiv:2411.15242 (Zamba2)",
)


def long_decode_variant() -> ModelConfig:
    """500k decode: shared attention gets a 4096-token sliding window."""
    return dataclasses.replace(CONFIG, sliding_window=4096,
                               name=CONFIG.name + "-swa")
