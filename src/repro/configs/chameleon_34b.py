"""chameleon-34b — early-fusion mixed-modal decoder [arXiv:2405.09818].

48L, d_model=8192, 64 heads GQA kv=8 (head_dim 128), d_ff=22016,
vocab 65536 — the vocabulary contains BOTH text tokens and VQ-VAE image
tokens (early fusion: one decoder, one token space).  qk-norm is real
Chameleon (they introduced it for training stability).

Frontend stub (per assignment): the VQ image tokenizer is not
implemented — ``input_specs`` supplies already-quantized token ids, with
image-token spans indistinguishable from text at the backbone level
(that is early fusion's point).
"""

from repro.models.config import LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    d_model=8192,
    vocab_size=65536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    d_ff=22016,
    rope_theta=1e4,
    layer_plan=(LayerGroup(mixer="attn", ffn="dense", count=48),),
    supports_long_decode=False,
    citation="arXiv:2405.09818 (Chameleon)",
)
