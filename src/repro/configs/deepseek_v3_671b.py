"""deepseek-v3-671b — MLA + 1 shared / 256 routed top-8 MoE + MTP
[arXiv:2412.19437].

61L (first 3 dense, 58 MoE), d_model=7168, 128 heads of MLA
(q_lora 1536, kv_lora 512, nope 128 + rope 64, v 128), expert d_ff=2048,
dense d_ff=18432, vocab 129280, multi-token-prediction depth 1.

The MLA decode path caches the COMPRESSED latent (512+64 per token, vs
2*128*128=32768 for dense GQA) — the 500k shape is still skipped (full
attention over the latent remains O(context) compute per token, and the
model card caps context at 128k).
"""

from repro.models.config import LayerGroup, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    d_model=7168,
    vocab_size=129280,
    num_heads=128,
    num_kv_heads=128,     # MLA: effectively MQA over the shared latent
    head_dim=128,
    d_ff=18432,           # dense layers 0..2
    layer_plan=(
        LayerGroup(mixer="mla", ffn="dense", count=3),
        LayerGroup(mixer="mla", ffn="moe", count=58),
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1),
    mtp_depth=1,
    supports_long_decode=False,
    citation="arXiv:2412.19437 (DeepSeek-V3)",
)
