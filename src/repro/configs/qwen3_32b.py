"""qwen3-32b — dense decoder with qk-norm + GQA [hf:Qwen/Qwen3-8B family].

64L, d_model=5120, 64 heads GQA kv=8 (head_dim 128), d_ff=25600,
vocab 151936.  Full attention -> long_500k skipped (DESIGN.md).
"""

from repro.models.config import LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    d_model=5120,
    vocab_size=151936,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    d_ff=25600,
    layer_plan=(LayerGroup(mixer="attn", ffn="dense", count=64),),
    supports_long_decode=False,
    citation="hf:Qwen/Qwen3-32B",
)
