"""qwen3-8b — dense decoder, qk-norm + GQA [hf:Qwen/Qwen3-8B].

36L, d_model=4096, 32 heads GQA kv=8 (head_dim 128), d_ff=12288,
vocab 151936.

``long_decode_variant`` adds a 4096 sliding window (ring KV cache) —
the dense-architecture carve-out that makes the 500k decode shape
allocatable (DESIGN.md §long_500k).
"""

import dataclasses

from repro.models.config import LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    d_model=4096,
    vocab_size=151936,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    d_ff=12288,
    layer_plan=(LayerGroup(mixer="attn", ffn="dense", count=36),),
    supports_long_decode=True,     # via the SWA variant below
    citation="hf:Qwen/Qwen3-8B",
)


def long_decode_variant() -> ModelConfig:
    return dataclasses.replace(CONFIG, sliding_window=4096,
                               name=CONFIG.name + "-swa")
