"""qwen3-moe-30b-a3b — Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L, d_model=2048, 32 heads GQA kv=4 (head_dim 128), 128 experts top-8
(expert d_ff=768, no shared expert), qk-norm, vocab 151936.
"""

from repro.models.config import LayerGroup, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    d_model=2048,
    vocab_size=151936,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    qk_norm=True,
    layer_plan=(LayerGroup(mixer="attn", ffn="moe", count=48),),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768,
                  num_shared_experts=0),
    supports_long_decode=False,
    citation="hf:Qwen/Qwen3-30B-A3B",
)
