"""rwkv6-3b — Finch, attention-free linear-attention RNN with
data-dependent decay [arXiv:2404.05892].

32L, d_model=2560, channel-mix width 3.5*d = 8960 (the assigned d_ff),
vocab 65536.  No KV cache -> O(1)-state decode: runs the 500k shape.
"""

from repro.models.config import LayerGroup, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    d_model=2560,
    vocab_size=65536,
    d_ff=8960,                       # == 3.5 * d_model (channel mix)
    layer_plan=(LayerGroup(mixer="rwkv6", ffn="rwkv_cm", count=32),),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    supports_long_decode=True,
    citation="arXiv:2404.05892 (RWKV-6 'Finch')",
)
