"""deepseek-67b — llama-architecture dense decoder [arXiv:2401.02954].

95L, d_model=8192, 64 heads GQA kv=8 (head_dim 128), d_ff=22016,
vocab 102400.  Deepest assigned model — exercises the scanned-group
lowering (one HLO while-loop for all 95 layers).
"""

from repro.models.config import LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    arch_type="dense",
    d_model=8192,
    vocab_size=102400,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    rope_theta=1e4,
    layer_plan=(LayerGroup(mixer="attn", ffn="dense", count=95),),
    supports_long_decode=False,
    citation="arXiv:2401.02954 (DeepSeek LLM 67B)",
)
