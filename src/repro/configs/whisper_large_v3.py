"""whisper-large-v3 — encoder-decoder speech model [arXiv:2212.04356].

Transformer backbone only (per assignment): the mel-spectrogram + conv
frontend is a STUB — ``input_specs`` feeds precomputed frame embeddings
(B, frames, d_model).  32+32 layers, d_model=1280, 20 heads (MHA:
kv=20), d_ff=5120, vocab 51866.

Decode shapes lower the decoder's serve_step (cross-attention KV is part
of the decode state).  long_500k skipped: the decoder is full-attention
with a 448-token design context (DESIGN.md §long_500k).
"""

from repro.models.config import EncoderConfig, LayerGroup, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    d_model=1280,
    vocab_size=51866,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    layer_plan=(LayerGroup(mixer="attn", ffn="dense", count=32,
                           cross_attn=True),),
    encoder=EncoderConfig(num_layers=32, max_frames=1500),
    is_encoder_decoder=True,
    rope_theta=1e4,
    supports_long_decode=False,
    citation="arXiv:2212.04356 (Whisper); frontend stubbed per assignment",
)
