"""repro — C-NMT: Collaborative Inference framework for NMT, in JAX.

Reproduction of Chen et al., "C-NMT: A Collaborative Inference Framework
for Neural Machine Translation" (2022), extended into a production-grade
multi-pod JAX serving/training framework.

Layers
------
- ``repro.core``      — the paper's contribution: N->M length regression,
                        linear latency planes, T_tx tracking, the CI
                        decision rule, and the request-stream simulator.
- ``repro.nmt``       — paper-faithful small seq2seq models (BiLSTM, GRU,
                        Marian-style transformer) that run on CPU.
- ``repro.models``    — the large-model stack (10 assigned architectures).
- ``repro.kernels``   — Pallas TPU kernels (flash attention, flash decode,
                        RWKV6 WKV, Mamba2 SSD) with pure-jnp oracles.
- ``repro.sharding``  — PartitionSpec policies (DP/FSDP/TP/EP).
- ``repro.runtime``   — serving engine (KV cache, prefill/decode,
                        C-NMT-routed tiered serving).
- ``repro.training``  — optimizer, train step, checkpointing.
- ``repro.data``      — synthetic parallel-corpus pipeline.
- ``repro.configs``   — per-architecture configuration registry.
- ``repro.launch``    — production meshes, multi-pod dry-run, drivers.
"""

__version__ = "1.0.0"
