"""Process-global activation-sharding hook.

Layers are sharding-agnostic; the launcher installs a constrainer here
before tracing so that large layer-internal tensors (RWKV/Mamba chunk
tensors, which XLA's propagation otherwise replicates across the mesh)
keep their batch sharding.  No-op unless installed — CPU tests and
single-device runs never touch jax.sharding.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

_CONSTRAINER: Optional[Callable] = None
# (mesh, seq_axis_name, batch_axes) for the shard_map flash-decode path
_DECODE_SEQ_SHARD: Optional[Tuple] = None


def set_batch_constrainer(fn: Optional[Callable]) -> None:
    """fn(x, batch_axis) -> x with a sharding constraint applied."""
    global _CONSTRAINER
    _CONSTRAINER = fn


def constrain_batch(x, batch_axis: int = 0):
    if _CONSTRAINER is None:
        return x
    return _CONSTRAINER(x, batch_axis)


def set_decode_seq_shard(info: Optional[Tuple]) -> None:
    """(mesh, seq_axis, batch_axes) or None.  When set, GQA decode uses
    the shard_map flash-decode path: each model-axis shard attends to its
    local cache slice and the shards combine (max, sum, weighted-acc)
    softmax stats — O(B*H*D) traffic per layer instead of gathering the
    cache/scores (EXPERIMENTS.md §Perf, decode pair)."""
    global _DECODE_SEQ_SHARD
    _DECODE_SEQ_SHARD = info


def decode_seq_shard() -> Optional[Tuple]:
    return _DECODE_SEQ_SHARD
