"""PartitionSpec policy — the baseline FSDP+TP(+EP) layout.

Axes
----
* ``model`` — tensor parallel: attention heads / FFN width / EXPERTS.
* ``data``  — batch data-parallel AND the FSDP shard axis for params &
  optimizer moments (ZeRO-3 style: params are gathered per layer by XLA
  where needed).
* ``pod``   — multi-pod: extends both the batch axis and the FSDP axis
  (so 671B + moments fits per chip at 512 devices).

Rules are name-based over tree key paths, with a divisibility guard:
an axis is only assigned if the dimension divides evenly; otherwise the
dim is replicated (GSPMD could pad uneven shardings, but keeping the
baseline clean makes the roofline collectives readable).

Layer-stacked leaves (groups scanned over L) get a leading None.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# weight names whose LAST TWO dims are (in=fsdp, out=model)
_TP_OUT = {
    "q", "k", "v", "g", "xq", "xk", "xv", "q_down", "q_up", "kv_down",
    "k_up", "v_up", "in_proj", "rk", "kk", "w_down", "w_up", "gate", "up",
}
# weight names whose LAST TWO dims are (in=model, out=fsdp)
_TP_IN = {"o", "xo", "out_proj", "down", "vv"}


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    # logical axis assignments (tuples feed PartitionSpec directly)
    batch_axes: Tuple[str, ...] = ("data",)
    fsdp_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)
    seq_axes: Tuple[str, ...] = ("model",)   # decode-cache sequence axis
    shard_batch: bool = True                 # False for batch=1 shapes

    def axis_size(self, axes: Tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def _fit(self, axes: Tuple[str, ...], dim: int) -> Optional[Tuple[str, ...]]:
        return axes if axes and dim % self.axis_size(axes) == 0 else None

    # -------------------------------------------------------------- batch --
    def batch(self, dim: int):
        if not self.shard_batch:
            return None
        return self._fit(self.batch_axes, dim)

    def fsdp(self, dim: int):
        return self._fit(self.fsdp_axes, dim)

    def model(self, dim: int):
        return self._fit(self.model_axes, dim)

    def seq(self, dim: int):
        return self._fit(self.seq_axes, dim)


def make_policy(mesh: Mesh, *, batch_size: int,
                layout: str = "tp", fsdp: bool = True) -> ShardingPolicy:
    """Baseline layouts.

    * ``tp``  — batch over (pod,data); tensor-parallel weights + vocab +
      decode-cache sequence over ``model``; FSDP over (data,pod).
    * ``ddp`` — no tensor parallelism: batch over as many axes as divide
      it (up to pod*data*model), FSDP over (data,pod).  Right for models
      whose head counts don't divide the TP axis (rwkv6's 40 heads,
      whisper's 20) and for <=3B models where TP gathers dominate —
      see EXPERIMENTS.md §Perf.
    """
    axes = set(mesh.axis_names)
    # fsdp=False: weights live TP-sharded but replicated across data —
    # right for decode, where per-token FSDP weight gathers dominate the
    # collective roofline term (EXPERIMENTS.md §Perf, decode pair).
    fsdp_axes = tuple(a for a in ("data", "pod") if a in axes) if fsdp else ()
    if layout == "tp":
        batch_axes = tuple(a for a in ("pod", "data") if a in axes)
        model_axes: Tuple[str, ...] = ("model",)
    elif layout == "ddp":
        model_axes = ()
        batch_axes = ()
        for cand in (("pod", "data", "model"), ("pod", "data"),
                     ("data", "model"), ("data",)):
            cand = tuple(a for a in cand if a in axes)
            if cand and batch_size % int(
                    np.prod([mesh.shape[a] for a in cand])) == 0:
                batch_axes = cand
                break
    else:
        raise ValueError(layout)
    pol = ShardingPolicy(
        mesh=mesh,
        batch_axes=batch_axes,
        fsdp_axes=fsdp_axes,
        model_axes=model_axes,
        seq_axes=model_axes,
        shard_batch=True,
    )
    if not batch_axes or batch_size % pol.axis_size(batch_axes):
        # batch=1 long-context shape: replicate batch, shard seq instead
        pol = dataclasses.replace(
            pol, shard_batch=False,
            seq_axes=model_axes or (tuple(a for a in ("model",)
                                          if a in axes)))
    return pol


def _path_names(path) -> list:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return names


def _spec_for_param(pol: ShardingPolicy, path, leaf) -> P:
    names = _path_names(path)
    shape = leaf.shape
    nd = len(shape)
    # leaf name = nearest containing weight name ("w" leaves live in dicts
    # named after the projection)
    owner = None
    for n in reversed(names):
        if n not in ("w", "b", "g"):
            owner = n
            break
    leafname = names[-1] if names else ""

    def pad(spec_tail):
        return P(*([None] * (nd - len(spec_tail)) + spec_tail))

    if owner == "embed" and leafname == "w":           # (V, D)
        return pad([pol.model(shape[-2]), pol.fsdp(shape[-1])])
    if owner == "lm_head" and leafname == "w":         # (D, V): V = TP axis
        return pad([pol.fsdp(shape[-2]), pol.model(shape[-1])])
    if owner == "router":
        return pad([pol.fsdp(shape[-2]), None])
    if owner in ("experts_gate", "experts_up", "experts_down") \
            and leafname == "w":
        # MoE expert-stacked weights (E, D, F)/(E, F, D): experts = model
        if owner == "experts_down":
            return pad([pol.model(shape[-3]), None, pol.fsdp(shape[-1])])
        return pad([pol.model(shape[-3]), pol.fsdp(shape[-2]), None])
    if owner in _TP_IN and nd >= 2 and leafname == "w":
        return pad([pol.model(shape[-2]), pol.fsdp(shape[-1])])
    if owner in _TP_OUT and nd >= 2 and leafname == "w":
        return pad([pol.fsdp(shape[-2]), pol.model(shape[-1])])
    if leafname == "conv_w" and nd >= 2:
        return pad([None, pol.model(shape[-1])])
    # norms, biases, scalars, mix coefficients, u/w0/a_log/...: replicate
    return P(*([None] * nd))


def param_specs(pol: ShardingPolicy, params_shape) -> Any:
    """PartitionSpec pytree mirroring an (abstract) params pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_param(pol, path, leaf), params_shape)


def batch_specs(pol: ShardingPolicy, batch_shape) -> Any:
    """Input batch: shard the leading batch dim, replicate the rest."""

    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        return P(*([pol.batch(leaf.shape[0])] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def decode_state_specs(pol: ShardingPolicy, state_shape) -> Any:
    """Decode state: batch over data; cache SEQUENCE over the model axis
    (flash-decode style — attention contracts over the sharded axis and
    XLA inserts the psum), SSM/RWKV state heads over model when they fit.
    """

    def spec(path, leaf):
        names = _path_names(path)
        leafname = names[-1] if names else ""
        shape = leaf.shape
        if leafname in ("k", "v"):        # (count,B,S,Hkv,Dh)
            return P(None, pol.batch(shape[1]), pol.seq(shape[2]), None, None)
        if leafname in ("xk", "xv"):      # (count,B,T,Hkv,Dh) cross-attn
            return P(None, pol.batch(shape[1]), None, None, None)
        if leafname in ("ckv", "kpe"):    # (count,B,S,rank)
            return P(None, pol.batch(shape[1]), pol.seq(shape[2]), None)
        if leafname == "ssm":             # (count,B,nh,P,N)
            return P(None, pol.batch(shape[1]), pol.model(shape[2]), None, None)
        if leafname == "wkv":             # (count,B,H,P,P)
            return P(None, pol.batch(shape[1]), pol.model(shape[2]), None, None)
        if leafname in ("conv", "shift_tm", "shift_cm"):
            return P(*([None, pol.batch(shape[1])] + [None] * (leaf.ndim - 2)))
        if leafname == "pos":             # (B,)
            return P(pol.batch(shape[0]))
        if leafname == "enc_mask":        # (B,T)
            return P(pol.batch(shape[0]), None)
        # fallback: shard nothing
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, state_shape)


def train_state_specs(pol: ShardingPolicy, state_shape) -> Any:
    """TrainState(params, AdamWState(step, mu, nu)): moments mirror params."""
    from repro.training.train_loop import TrainState
    from repro.training.optimizer import AdamWState

    p_spec = param_specs(pol, state_shape.params)
    return TrainState(
        params=p_spec,
        opt=AdamWState(step=P(),
                       mu=param_specs(pol, state_shape.opt.mu),
                       nu=param_specs(pol, state_shape.opt.nu)),
    )


def to_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
