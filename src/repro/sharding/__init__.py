"""Sharding policies: PartitionSpec assignment for params, optimizer
state, batches and decode states."""

from repro.sharding.policy import (
    ShardingPolicy,
    batch_specs,
    decode_state_specs,
    make_policy,
    param_specs,
    to_shardings,
    train_state_specs,
)

__all__ = [
    "ShardingPolicy",
    "make_policy",
    "param_specs",
    "batch_specs",
    "decode_state_specs",
    "train_state_specs",
    "to_shardings",
]
