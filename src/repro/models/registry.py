"""ONE model registry: paper NMT pairs and big-stack LMs by name.

Every serving entry point (benchmarks, examples, launch drivers) builds
its model through :func:`resolve`, so a tier is specified by a string:

* ``"cnmt:en-de"`` / ``"cnmt:de-en"`` / bare ``"de-en"`` — the paper's
  evaluated NMT combination for that language pair (§III); direction is
  normalized, so both orders name the same registered model.
* ``"qwen3-8b"`` / ``"qwen3_8b"`` — a big ``models/model.py`` LM from
  the architecture registry (``repro.configs``); underscores normalize
  to hyphens.  ``size="smoke"`` (default) builds the reduced CPU
  variant, ``size="full"`` the assigned production config.

The old direct import (``repro.nmt.registry.make_paper_model``) still
works but emits ``DeprecationWarning`` and delegates here.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.nmt.common import RNNConfig, TransformerConfig
from repro.nmt.gru import GRUSeq2Seq
from repro.nmt.lstm import BiLSTMSeq2Seq
from repro.nmt.transformer import MarianTransformer

# dataset -> (model family, paper hyper-params, language pair); the
# table itself still lives in nmt/registry (importing it there warns
# only on make_paper_model calls, not on the table).  repro.configs and
# models.model are imported lazily: repro.configs itself imports
# repro.models.config, so a module-level import here would be circular
# through the repro.models package init.
from repro.nmt.registry import PAPER_MODELS


@dataclasses.dataclass(frozen=True)
class ResolvedModel:
    """What :func:`resolve` hands back: the instantiated (un-initialized)
    model plus enough metadata to route it."""
    name: str                 # canonical registry name
    family: str               # "nmt" | "lm"
    model: object             # BiLSTM/GRU/Marian seq2seq or LM
    cfg: object               # its config object
    pair: Optional[str] = None   # language pair (nmt only)


def _normalize_pair(pair: str) -> str:
    if pair in PAPER_MODELS:
        return pair
    rev = "-".join(reversed(pair.split("-")))
    if rev in PAPER_MODELS:
        return rev
    raise KeyError(
        f"unknown language pair {pair!r}; have {sorted(PAPER_MODELS)}")


def _make_nmt(dataset: str, *, scale: float = 1.0, vocab: int = 8000,
              max_decode_len: int = 256, attn_impl: str = "xla"):
    """Instantiate the paper's model for ``dataset`` (§III).

    ``scale`` shrinks widths/layers for CPU-budget-friendly calibration
    runs (scale=1 is the paper's size). Latency *linearity* in N and M —
    the property C-NMT exploits — is scale-invariant; the fitted
    alpha/beta just shrink with it.  ``attn_impl`` selects the Marian
    attention backend for the batched paths ("xla" | "pallas"); the RNN
    models ignore it.
    """
    family, hp, pair = PAPER_MODELS[dataset]
    s = lambda v: max(8, int(v * scale))
    if family in ("bilstm", "gru"):
        cfg = RNNConfig(
            vocab_src=vocab, vocab_tgt=vocab,
            embed=s(hp["embed"]), hidden=s(hp["hidden"]),
            layers=hp["layers"], max_decode_len=max_decode_len,
        )
        model = BiLSTMSeq2Seq(cfg) if family == "bilstm" else GRUSeq2Seq(cfg)
    else:
        heads = min(8, max(2, int(8 * scale)))
        d_model = max(heads * 8, (s(hp["d_model"]) // heads) * heads)
        cfg = TransformerConfig(
            vocab_src=vocab, vocab_tgt=vocab,
            d_model=d_model, heads=heads,
            d_ff=s(hp["d_ff"]),
            enc_layers=max(1, int(hp["enc_layers"] * min(scale * 2, 1.0))),
            dec_layers=max(1, int(hp["dec_layers"] * min(scale * 2, 1.0))),
            max_decode_len=max_decode_len,
        )
        model = MarianTransformer(cfg, attn_impl=attn_impl)
    return model, pair


def available() -> Tuple[str, ...]:
    """Canonical names this registry resolves."""
    from repro.configs import ARCH_NAMES
    return tuple(f"cnmt:{p}" for p in PAPER_MODELS) + tuple(ARCH_NAMES)


def resolve(name: str, *, size: str = "smoke",
            # NMT knobs (ignored for LM names)
            scale: float = 1.0, vocab: int = 8000,
            max_decode_len: int = 256, attn_impl: str = "xla",
            # LM knobs (ignored for NMT names)
            shape: Optional[str] = None,
            mixer_impl: str = "xla") -> ResolvedModel:
    """Resolve a model name to an instantiated model.

    The returned model is NOT initialized — call ``.init(key)`` for
    params, as before.  For LM names ``size`` picks ``smoke_config``
    (default; CPU-runnable) vs ``get_config`` (the assigned production
    config; ``shape`` selects a documented variant), and ``mixer_impl``
    threads through to :class:`LM` ("pallas" routes rwkv6/mamba2 prefill
    through the fused kernels).
    """
    from repro.configs import ARCH_NAMES, get_config, smoke_config
    from repro.models.model import LM

    if size not in ("smoke", "full"):
        raise ValueError(f"size must be 'smoke' or 'full', got {size!r}")
    key = name.strip()
    if key.startswith("cnmt:") or key in PAPER_MODELS or (
            "-".join(reversed(key.split("-"))) in PAPER_MODELS):
        pair = _normalize_pair(key.split(":", 1)[-1])
        model, pair = _make_nmt(pair, scale=scale, vocab=vocab,
                                max_decode_len=max_decode_len,
                                attn_impl=attn_impl)
        return ResolvedModel(name=f"cnmt:{pair}", family="nmt",
                             model=model, cfg=model.cfg, pair=pair)
    arch = key.replace("_", "-")
    if arch not in ARCH_NAMES:
        raise KeyError(
            f"unknown model {name!r}; available: {', '.join(available())}")
    cfg = smoke_config(arch) if size == "smoke" else get_config(arch, shape)
    model = LM(cfg, mixer_impl=mixer_impl)
    return ResolvedModel(name=arch, family="lm", model=model, cfg=cfg)
