"""Analytic FLOPs / HBM-byte model per (architecture x input shape).

Why this exists: XLA's HloCostAnalysis counts `while` bodies ONCE — every
scanned layer group (and chunk scan) is undercounted by its trip count,
so compiled.cost_analysis() cannot provide the roofline numerator for
scan-lowered models.  This module computes the same quantities
analytically from the architecture; tests validate it against
cost_analysis on small UNROLLED configs (where XLA's numbers are exact),
and the dry-run records both (raw vs corrected) in EXPERIMENTS.md.

Conventions
-----------
* FLOPs: 2*M*N*K per matmul; attention scores+AV = 4*T*Tk*H*Dh (causal
  self-attention halves Tk on average).
* Train = 3x forward (bwd is 2x) + 1x forward recompute (remat) +
  ~25 flops/param optimizer.
* Bytes: parameter traffic + decode state traffic + O(tokens*D) activation
  traffic with a fusion-optimistic constant; decode is parameter/cache
  dominated, which is the regime that matters for the memory term.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import LayerGroup, ModelConfig
from repro.core.latency_model import ActivationCostModel


def _attn_flops(cfg: ModelConfig, t: int, tk: float, *, cross: bool = False,
                causal: bool = True) -> float:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * t * d * (h * dh + 2 * hkv * dh) + 2 * t * h * dh * d
    eff_tk = tk / 2 if (causal and not cross and t > 1) else tk
    attn = 4 * t * eff_tk * h * dh
    return proj + attn


def _mla_flops(cfg: ModelConfig, t: int, tk: float, *, decode: bool) -> float:
    m, h, d = cfg.mla, cfg.num_heads, cfg.d_model
    dn, dr, dv, r = (m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim,
                     m.kv_lora_rank)
    f = 2 * t * d * m.q_lora_rank + 2 * t * m.q_lora_rank * h * (dn + dr)
    f += 2 * t * d * (r + dr)                      # kv_down
    f += 2 * t * h * dv * d                        # o proj
    if decode:
        # absorbed: q/ouput absorb through k_up/v_up + latent-space attn
        f += 2 * t * h * dn * r + 2 * t * h * r * dv
        f += 4 * t * tk * h * (r + dr)
    else:
        f += 2 * t * r * h * (dn + dv)             # k_up + v_up expand
        eff = tk / 2 if t > 1 else tk
        f += 4 * t * eff * h * (dn + dr + dv) / (dn + dr + dv) * (dn + dr)
        f += 4 * t * eff * h * dv
    return f


def _mamba_flops(cfg: ModelConfig, t: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    gn = s.n_groups * s.state_dim
    f = 2 * t * d * (2 * di + 2 * gn + nh) + 2 * t * di * d
    f += 2 * t * s.conv_width * (di + 2 * gn)      # depthwise conv
    if t == 1:
        f += 5 * nh * s.head_dim * s.state_dim     # state update
        return f
    L = min(s.chunk, t)
    # intra: CB^T (L*L*N) + @x (L*L*P); inter/state: 2 * L*P*N per chunk
    per_chunk = (2 * L * L * s.state_dim * nh + 2 * L * L * s.head_dim * nh
                 + 4 * L * s.head_dim * s.state_dim * nh)
    f += (t // L) * per_chunk
    return f


def _rwkv_flops(cfg: ModelConfig, t: int) -> float:
    r = cfg.rwkv
    d = cfg.d_model
    h, p = d // r.head_dim, r.head_dim
    f = 2 * t * d * d * 5 + 4 * t * d * r.decay_lora       # r,k,v,g,o + lora
    if t == 1:
        f += 5 * h * p * p                                  # state update
    else:
        L = min(32, t)
        per_chunk = (4 * L * L * p * h          # A scores + @v
                     + 6 * L * p * p * h)       # state inc + inter
        f += (t // L) * per_chunk
    # channel mix: rk (d*d) + kk (d*3.5d) + vv (3.5d*d)
    f += 2 * t * (d * d + 2 * d * int(3.5 * d))
    return f


def _ffn_flops(cfg: ModelConfig, g: LayerGroup, t: int) -> float:
    if g.ffn == "dense":
        return 6 * t * cfg.d_model * cfg.d_ff
    if g.ffn == "moe":
        mo = cfg.moe
        routed = 6 * t * mo.top_k * mo.capacity_factor * cfg.d_model \
            * mo.d_ff_expert
        shared = 6 * t * cfg.d_model * mo.num_shared_experts * mo.d_ff_expert
        router = 2 * t * cfg.d_model * mo.num_experts
        return routed + shared + router
    if g.ffn == "rwkv_cm":
        return 0.0  # folded into _rwkv_flops
    return 0.0


def forward_flops(cfg: ModelConfig, *, tokens: int, context: float,
                  decode: bool, batch: int = 1) -> float:
    """Whole-model forward FLOPs for `tokens` query tokens against
    `context` keys (context==tokens for train/prefill self-attention).
    ``batch`` only matters for enc-dec models (encoder runs once/sequence).
    """
    total = 2 * tokens * cfg.d_model * cfg.vocab_size     # lm head
    for g in cfg.layer_plan:
        if g.mixer in ("attn", "shared_attn"):
            tk = min(context, cfg.sliding_window) if cfg.sliding_window \
                else context
            per = _attn_flops(cfg, tokens, tk)
            if g.cross_attn:
                per += _attn_flops(cfg, tokens, cfg.encoder.max_frames,
                                   cross=True)
        elif g.mixer == "mla":
            per = _mla_flops(cfg, tokens, context, decode=decode)
        elif g.mixer == "mamba2":
            per = _mamba_flops(cfg, tokens)
        elif g.mixer == "rwkv6":
            per = _rwkv_flops(cfg, tokens)
        per += _ffn_flops(cfg, g, tokens)
        total += per * g.count
    if cfg.is_encoder_decoder and not decode:
        # encoder runs once per sequence over max_frames (bidirectional)
        te = batch * cfg.encoder.max_frames
        per_enc = (_attn_flops(cfg, te, cfg.encoder.max_frames, causal=False)
                   + 6 * te * cfg.d_model * cfg.d_ff)
        total += per_enc * cfg.encoder.num_layers
    if cfg.mtp_depth:
        g = cfg.layer_plan[-1]
        total += (_mla_flops(cfg, tokens, context, decode=False)
                  if g.mixer == "mla" else _attn_flops(cfg, tokens, context))
        total += _ffn_flops(cfg, g, tokens)
        total += 2 * tokens * (2 * cfg.d_model) * cfg.d_model
        total += 2 * tokens * cfg.d_model * cfg.vocab_size
    return float(total)


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    """Decode-state bytes appended per generated token (all layers)."""
    total = 0.0
    for g in cfg.layer_plan:
        if g.mixer in ("attn", "shared_attn"):
            total += 2 * cfg.num_kv_heads * cfg.head_dim * g.count
        elif g.mixer == "mla":
            total += (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) \
                * g.count
        # mamba/rwkv states are O(1), not per-token
    return total * dtype_bytes


def recurrent_state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    total = 0.0
    for g in cfg.layer_plan:
        if g.mixer == "mamba2":
            s = cfg.ssm
            nh = s.expand * cfg.d_model // s.head_dim
            total += nh * s.head_dim * s.state_dim * g.count
        elif g.mixer == "rwkv6":
            r = cfg.rwkv
            h = cfg.d_model // r.head_dim
            total += (h * r.head_dim * r.head_dim + 2 * cfg.d_model) * g.count
    return total * dtype_bytes


def activation_cost_model(cfg: ModelConfig,
                          dtype_bytes: int = 2) -> ActivationCostModel:
    """Encoder-state wire size for a big-model config (bf16 default)."""
    return ActivationCostModel(d_model=cfg.d_model, dtype_bytes=dtype_bytes)


def nmt_activation_cost(model, dtype_bytes: int = 4) -> ActivationCostModel:
    """Encoder-state wire size for an NMT model (fp32 default on CPU).

    Works for any of the three seed NMT models: transformer configs
    expose ``d_model``, the RNN configs expose ``hidden``.  For the GRU
    the shipped state is a single fixed-size context vector, so
    ``n x hidden`` is a conservative upper bound rather than exact —
    fine for scheduling (it only makes the GRU's split plans look
    slightly worse than they are).
    """
    cfg = model.cfg if hasattr(model, "cfg") else model
    d = getattr(cfg, "d_model", None) or cfg.hidden
    return ActivationCostModel(d_model=int(d), dtype_bytes=dtype_bytes)


@dataclasses.dataclass(frozen=True)
class StepCost:
    flops: float           # whole-mesh per step
    hbm_bytes: float       # whole-mesh per step
    kind: str


def step_cost(cfg: ModelConfig, *, kind: str, batch: int, seq: int,
              moments_bytes: int = 8, param_bytes: int = 2) -> StepCost:
    """Analytic per-step cost for the dry-run shapes (whole mesh)."""
    pc = cfg.param_counts()
    p_total = pc["total"]
    if kind == "train":
        tokens = batch * seq
        fwd = forward_flops(cfg, tokens=tokens, context=seq, decode=False, batch=batch)
        flops = 4 * fwd + 25 * p_total            # fwd+bwd(2x)+remat + opt
        act_rw = 16 * tokens * cfg.d_model * cfg.num_layers * param_bytes
        # params: fwd read + recompute read + grad write + opt read/write
        param_traffic = p_total * (3 * param_bytes + 2 * param_bytes
                                   + 2 * moments_bytes + moments_bytes // 2)
        bytes_ = param_traffic + act_rw
    elif kind == "prefill":
        tokens = batch * seq
        flops = forward_flops(cfg, tokens=tokens, context=seq, decode=False, batch=batch)
        kv_write = tokens * kv_bytes_per_token(cfg)
        act_rw = 8 * tokens * cfg.d_model * cfg.num_layers * param_bytes
        bytes_ = p_total * param_bytes + act_rw + kv_write
    elif kind == "decode":
        tokens = batch
        flops = forward_flops(cfg, tokens=tokens, context=seq, decode=True)
        ctx_eff = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
        cache_read = batch * ctx_eff * kv_bytes_per_token(cfg)
        state_rw = 2 * batch * recurrent_state_bytes(cfg)
        bytes_ = p_total * param_bytes + cache_read + state_rw \
            + 8 * tokens * cfg.d_model * cfg.num_layers * param_bytes
    else:
        raise ValueError(kind)
    return StepCost(flops=float(flops), hbm_bytes=float(bytes_), kind=kind)
