"""The composable LM: assembles layer groups into train / prefill / decode
paths, for every assigned architecture family.

Layer groups (``cfg.layer_plan``) are scanned with stacked params — a
95-layer dense model lowers as ONE scanned block, keeping dry-run compile
times and HLO size bounded.  Heterogeneous plans (dense-then-MoE,
mamba+shared-attention) become several scanned groups executed in order.

Three entry points (the units the launcher lowers):
* ``train_logits``  — full-sequence causal forward, returns logits + MoE
                      aux loss (+ MTP logits for deepseek-v3).
* ``prefill``       — full-sequence forward that also materializes the
                      decode state (KV caches / SSM states); returns
                      last-position logits only (the (B,S,V) tensor is
                      never built in serving).
* ``decode_step``   — ONE token against the fixed-size decode state.

Decode state layout: one entry per group, every leaf has leading dim
``count`` (the group's layer count) so scans carry it uniformly.
Sliding-window attention uses a ring-buffer cache of size ``window``
(this is what makes the 500k-context decode shape allocatable for the
dense-SWA variant).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import LayerGroup, ModelConfig
from repro.models.layers import attention as att
from repro.models.layers import mamba2 as mb
from repro.models.layers import moe as moe_lib
from repro.models.layers import rwkv6 as rk
from repro.models.layers.basic import (
    embed_params,
    linear,
    linear_params,
    rmsnorm,
    rmsnorm_params,
    swiglu,
    swiglu_params,
)


# ============================================================ param init ==
def _init_block(key, cfg: ModelConfig, g: LayerGroup, dtype):
    """Params for ONE layer of group ``g`` (mixer + ffn + norms)."""
    km, kf = jax.random.split(key)
    p: Dict[str, Any] = {"ln1": rmsnorm_params(cfg.d_model)}
    if g.mixer in ("attn", "shared_attn"):
        p["mixer"] = att.gqa_params(km, cfg, cross=g.cross_attn, dtype=dtype)
    elif g.mixer == "mla":
        p["mixer"] = att.mla_params(km, cfg, dtype=dtype)
    elif g.mixer == "mamba2":
        p["mixer"] = mb.mamba2_params(km, cfg, dtype=dtype)
    elif g.mixer == "rwkv6":
        p["mixer"] = rk.rwkv6_params(km, cfg, dtype=dtype)
    else:
        raise ValueError(g.mixer)
    if g.cross_attn:
        p["ln_x"] = rmsnorm_params(cfg.d_model)
    if g.ffn != "none":
        p["ln2"] = rmsnorm_params(cfg.d_model)
    if g.ffn == "dense":
        p["ffn"] = swiglu_params(kf, cfg.d_model, cfg.d_ff, dtype)
    elif g.ffn == "moe":
        p["ffn"] = moe_lib.moe_params(kf, cfg, dtype)
    elif g.ffn == "rwkv_cm":
        p["ffn"] = rk.channel_mix_params(kf, cfg, dtype)
    return p


class LM:
    def __init__(self, cfg: ModelConfig, param_dtype=jnp.float32,
                 remat: bool = False, constrain=None,
                 mixer_impl: str = "xla"):
        """``remat=True`` checkpoints each layer body: backward recomputes
        layer internals, so training activation memory is O(layers x B x S
        x D) carries instead of every intermediate (required for the
        95-layer train_4k dry-runs to fit HBM).

        ``constrain`` (optional) is applied to the (B,S,D) residual stream
        after the embedding and after every layer — the launcher installs
        jax.lax.with_sharding_constraint here so the batch sharding
        survives scan+remat boundaries (XLA's propagation alone loses it
        and replicates activations; see EXPERIMENTS.md §Perf iteration 1).

        ``mixer_impl`` ("xla" | "pallas") selects the full-sequence mixer
        backend for the recurrent families — the PR 3 ``attn_impl``
        treatment extended to the big stack: "pallas" routes rwkv6
        through :func:`repro.kernels.ops.rwkv6_wkv` and mamba2 through
        :func:`repro.kernels.ops.ssd_scan` (interpret mode off-TPU);
        "xla" keeps the pure-jnp chunked scans.  Decode is the O(1)
        per-token recurrence either way, so the knob only affects
        prefill/train paths.
        """
        if mixer_impl not in ("xla", "pallas"):
            raise ValueError(f"mixer_impl must be 'xla' or 'pallas', "
                             f"got {mixer_impl!r}")
        self.cfg = cfg.validate()
        self.param_dtype = param_dtype
        self.remat = remat
        self.mixer_impl = mixer_impl
        self.constrain = constrain if constrain is not None else (lambda x: x)

    # ------------------------------------------------------------- init --
    def init(self, key) -> Dict:
        cfg, dtype = self.cfg, self.param_dtype
        n_groups = len(cfg.layer_plan)
        keys = jax.random.split(key, n_groups + 5)
        params: Dict[str, Any] = {
            "embed": embed_params(keys[0], cfg.padded_vocab, cfg.d_model,
                                  dtype),
            "final_norm": rmsnorm_params(cfg.d_model),
            "groups": [],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = linear_params(keys[1], cfg.d_model,
                                              cfg.padded_vocab, dtype)
        shared_params = None
        for gi, g in enumerate(cfg.layer_plan):
            kg = keys[2 + gi]
            if g.mixer == "shared_attn":
                # one param set, reused by every shared group
                if shared_params is None:
                    shared_params = _init_block(kg, cfg, g, dtype)
                params["groups"].append({})  # placeholder; weights live in params["shared_attn"]
            else:
                stacked = jax.vmap(
                    lambda k: _init_block(k, cfg, g, dtype)
                )(jax.random.split(kg, g.count))
                params["groups"].append(stacked)
        if shared_params is not None:
            params["shared_attn"] = shared_params
        if cfg.is_encoder_decoder:
            enc_g = LayerGroup(mixer="attn", ffn="dense", count=cfg.encoder.num_layers)
            params["encoder"] = {
                "layers": jax.vmap(
                    lambda k: _init_block(k, cfg, enc_g, dtype)
                )(jax.random.split(keys[-2], cfg.encoder.num_layers)),
                "final_norm": rmsnorm_params(cfg.d_model),
            }
        if cfg.mtp_depth:
            g = cfg.layer_plan[-1]
            params["mtp"] = {
                "proj": linear_params(keys[-1], 2 * cfg.d_model, cfg.d_model,
                                      dtype),
                "block": _init_block(keys[-1], cfg, g, dtype),
                "norm": rmsnorm_params(cfg.d_model),
            }
        return params

    def params_spec(self, dtype=None) -> Dict:
        """Abstract ShapeDtypeStruct pytree (used by the dry-run)."""
        dt = dtype or self.param_dtype
        model = LM(self.cfg, param_dtype=dt)
        return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    # ====================================================== full forward ==
    def _block_full(self, p, cfg, g: LayerGroup, x, *, window, enc_kv=None,
                    enc_mask=None, state_in=None, causal=True):
        """One layer, full sequence. Returns (x, cache_entry, aux)."""
        aux = jnp.zeros((), jnp.float32)
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        if g.mixer in ("attn", "shared_attn"):
            y, (k, v) = att.attn_full(p["mixer"], cfg, h, window=window,
                                      causal=causal)
            cache = {"k": k, "v": v}
            if g.cross_attn:
                xk, xv = att.encode_cross_kv(p["mixer"], cfg, enc_kv)
                x = x + y
                hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
                y = att.cross_attn(p["mixer"], cfg, hx, xk, xv, enc_mask)
                cache.update({"xk": xk, "xv": xv})
        elif g.mixer == "mla":
            y, (ckv, kpe) = att.mla_full(p["mixer"], cfg, h)
            cache = {"ckv": ckv, "kpe": kpe}
        elif g.mixer == "mamba2":
            y, st = mb.mamba2_full(p["mixer"], cfg, h, impl=self.mixer_impl)
            cache = st._asdict()
        elif g.mixer == "rwkv6":
            y, st = rk.rwkv6_full(p["mixer"], cfg, h, state_in,
                                  impl=self.mixer_impl)
            cache = st
        x = x + y
        if g.ffn != "none":
            h = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if g.ffn == "dense":
                y = swiglu(p["ffn"], h)
            elif g.ffn == "moe":
                y, aux = moe_lib.moe_ffn(p["ffn"], cfg, h)
            elif g.ffn == "rwkv_cm":
                y, cache = rk.channel_mix_full(p["ffn"], cfg, h, cache)
            x = x + y
        return x, cache, aux

    def _run_groups_full(self, params, x, *, enc_out=None, enc_mask=None,
                         window=None, with_cache: bool):
        """Scan every group over the sequence-parallel path."""
        cfg = self.cfg
        b = x.shape[0]
        caches: List[Any] = []
        aux_total = jnp.zeros((), jnp.float32)
        for gi, g in enumerate(cfg.layer_plan):
            gp = params["groups"][gi]
            w = window if window is not None else cfg.sliding_window
            if g.mixer == "shared_attn":
                sp = params["shared_attn"]
                x, cache, aux = self._block_full(
                    sp, cfg, g, x, window=w, enc_kv=enc_out, enc_mask=enc_mask)
                aux_total += aux
                caches.append(jax.tree.map(lambda c: c[None], cache)
                              if with_cache else None)
                continue
            if g.mixer == "rwkv6":
                st0 = rk.init_rwkv_state(cfg, b, x.dtype)

                def body_rwkv(carry, lp):
                    xx, auxc = carry
                    xx, st, aux = self._block_full(lp, cfg, g, xx, window=w,
                                                   state_in=st0)
                    return (self.constrain(xx), auxc + aux), st

                (x, aux_total), sts = jax.lax.scan(
                    self._maybe_remat(body_rwkv), (x, aux_total), gp)
                caches.append(sts if with_cache else None)
                continue

            def body(carry, lp):
                xx, auxc = carry
                xx, cache, aux = self._block_full(
                    lp, cfg, g, xx, window=w, enc_kv=enc_out,
                    enc_mask=enc_mask)
                return (self.constrain(xx), auxc + aux), \
                    (cache if with_cache else 0)

            (x, aux_total), sts = jax.lax.scan(self._maybe_remat(body),
                                               (x, aux_total), gp)
            caches.append(sts if with_cache else None)
        return x, caches, aux_total

    def _maybe_remat(self, fn):
        """Per-layer activation checkpointing for the scanned groups."""
        return jax.checkpoint(fn, prevent_cse=False) if self.remat else fn

    # -------------------------------------------------------- encoder ----
    def encode(self, params, frames, frame_mask=None):
        """Bidirectional encoder over precomputed frame/patch embeddings."""
        cfg = self.cfg
        b, t, _ = frames.shape
        if frame_mask is None:
            frame_mask = jnp.ones((b, t), jnp.float32)
        x = frames
        g = LayerGroup(mixer="attn", ffn="dense", count=cfg.encoder.num_layers)

        def body(xx, lp):
            xx, _, _ = self._block_full(lp, cfg, g, xx, window=None,
                                        causal=False)
            return xx, 0

        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
        return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps), frame_mask

    # ------------------------------------------------------ lm entries ---
    def _logits(self, params, x):
        cfg = self.cfg
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["w"].astype(x.dtype).T
        else:
            logits = linear(params["lm_head"], x)
        if cfg.padded_vocab != cfg.vocab_size:
            # mask Megatron-style vocab padding columns
            col = jnp.arange(cfg.padded_vocab)
            logits = jnp.where(col < cfg.vocab_size, logits,
                               jnp.asarray(-1e30, logits.dtype))
        return logits

    def train_logits(self, params, tokens, *, frames=None, frame_mask=None):
        """Full causal forward. Returns dict(logits, aux_loss[, mtp_logits])."""
        cfg = self.cfg
        x = self.constrain(params["embed"]["w"].astype(self.param_dtype)[tokens])
        enc_out = enc_mask = None
        if cfg.is_encoder_decoder:
            enc_out, enc_mask = self.encode(params, frames, frame_mask)
        x, _, aux = self._run_groups_full(params, x, enc_out=enc_out,
                                          enc_mask=enc_mask, with_cache=False)
        out = {"logits": self._logits(params, x), "aux_loss": aux}
        if cfg.mtp_depth:
            out["mtp_logits"] = self._mtp_logits(params, x, tokens)
        return out

    def _mtp_logits(self, params, h, tokens):
        """DeepSeek-V3 multi-token prediction: depth-1 extra block that
        predicts token t+2 from [h_t ; emb(token_{t+1})]."""
        cfg = self.cfg
        emb_next = params["embed"]["w"].astype(h.dtype)[
            jnp.roll(tokens, -1, axis=1)]
        g = cfg.layer_plan[-1]
        z = linear(params["mtp"]["proj"],
                   jnp.concatenate([rmsnorm(params["mtp"]["norm"], h,
                                            cfg.norm_eps), emb_next], -1))
        z, _, _ = self._block_full(params["mtp"]["block"], cfg, g, z,
                                   window=cfg.sliding_window)
        return self._logits(params, z)

    # ---------------------------------------------------------- prefill --
    def prefill(self, params, tokens, *, frames=None, frame_mask=None,
                window=None, max_len: Optional[int] = None, lengths=None):
        """Returns (last_logits (B,V), decode_state).

        ``max_len`` pads the KV caches to decode capacity so decode_step
        can append in place (slot == position discipline).

        ``lengths`` (B,) marks per-sequence TRUE prompt lengths in a
        right-padded batch: the returned logits are gathered at each
        sequence's last real token and ``state["pos"]`` starts at
        ``lengths`` so decode appends there.  Correct only for
        position-masked mixers (attn/mla/shared_attn — causal attention
        never sees the right-padding); recurrent mixers (mamba2/rwkv6)
        fold pad steps into their carried state, so callers must not pass
        ``lengths`` for those plans (GenerationSession enforces this).
        """
        cfg = self.cfg
        s = tokens.shape[1]
        x = self.constrain(params["embed"]["w"].astype(self.param_dtype)[tokens])
        enc_out = enc_mask = None
        if cfg.is_encoder_decoder:
            enc_out, enc_mask = self.encode(params, frames, frame_mask)
        x, caches, _ = self._run_groups_full(
            params, x, enc_out=enc_out, enc_mask=enc_mask, window=window,
            with_cache=True)
        if max_len is not None and max_len > s:
            pad = max_len - s

            def pad_seq(key_name, c):
                if key_name in ("k", "v", "ckv", "kpe"):
                    cfgpad = [(0, 0)] * c.ndim
                    cfgpad[2] = (0, pad)      # (count,B,S,...) seq axis
                    return jnp.pad(c, cfgpad)
                return c

            caches = [
                {kn: pad_seq(kn, cv) for kn, cv in c.items()}
                if isinstance(c, dict) else c
                for c in caches
            ]
        if lengths is None:
            pos0 = jnp.full((tokens.shape[0],), s, jnp.int32)
            last = x[:, -1, :]
        else:
            pos0 = jnp.asarray(lengths, jnp.int32)
            last = x[jnp.arange(tokens.shape[0]), pos0 - 1, :]
        state = {"caches": caches, "pos": pos0}
        if cfg.is_encoder_decoder:
            state["enc_mask"] = enc_mask
        return self._logits(params, last), state

    # ------------------------------------------------------ decode state --
    def init_decode_state(self, params_or_none, batch: int, max_len: int,
                          dtype=None) -> Dict:
        """Fresh (empty) decode state with capacity ``max_len``."""
        cfg = self.cfg
        dt = dtype or self.param_dtype
        caches: List[Any] = []
        for g in cfg.layer_plan:
            w = cfg.sliding_window
            s_alloc = min(max_len, w) if (w and g.mixer in ("attn", "shared_attn")) else max_len
            if g.mixer in ("attn", "shared_attn"):
                c = {"k": jnp.zeros((g.count, batch, s_alloc,
                                     cfg.num_kv_heads, cfg.head_dim), dt),
                     "v": jnp.zeros((g.count, batch, s_alloc,
                                     cfg.num_kv_heads, cfg.head_dim), dt)}
                if g.cross_attn:
                    t = cfg.encoder.max_frames
                    c["xk"] = jnp.zeros((g.count, batch, t, cfg.num_kv_heads,
                                         cfg.head_dim), dt)
                    c["xv"] = jnp.zeros_like(c["xk"])
                caches.append(c)
            elif g.mixer == "mla":
                m = cfg.mla
                caches.append({
                    "ckv": jnp.zeros((g.count, batch, max_len,
                                      m.kv_lora_rank), dt),
                    "kpe": jnp.zeros((g.count, batch, max_len,
                                      m.qk_rope_head_dim), dt)})
            elif g.mixer == "mamba2":
                st = mb.init_mamba_state(cfg, batch, dt)
                caches.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (g.count,) + a.shape),
                    st._asdict()))
            elif g.mixer == "rwkv6":
                st = rk.init_rwkv_state(cfg, batch, dt)
                caches.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (g.count,) + a.shape),
                    st))
        state = {"caches": caches, "pos": jnp.zeros((batch,), jnp.int32)}
        if cfg.is_encoder_decoder:
            state["enc_mask"] = jnp.ones((batch, cfg.encoder.max_frames),
                                         jnp.float32)
        return state

    # -------------------------------------------------------- decode -----
    def _block_decode(self, p, cfg, g: LayerGroup, x, cache, pos, enc_mask):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        if g.mixer in ("attn", "shared_attn"):
            w = cfg.sliding_window
            s_alloc = cache["k"].shape[1]
            # ring cache when the allocation is window-sized (long decode)
            ring = bool(w) and s_alloc == w
            from repro.sharding import ctx as shard_ctx
            seq_shard = shard_ctx.decode_seq_shard()
            if seq_shard is not None and not ring and not g.cross_attn:
                mesh, seq_axis, batch_axes = seq_shard
                y, ck, cv = att.attn_decode_seq_sharded(
                    p["mixer"], cfg, h, cache["k"], cache["v"], pos,
                    mesh=mesh, seq_axis=seq_axis, batch_axes=batch_axes)
            else:
                y, ck, cv = att.attn_decode(
                    p["mixer"], cfg, h, cache["k"], cache["v"], pos,
                    window=None if ring else w, ring=ring)
            cache = dict(cache, k=ck, v=cv)
            if g.cross_attn:
                x = x + y
                hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
                y = att.cross_attn(p["mixer"], cfg, hx, cache["xk"],
                                   cache["xv"], enc_mask)
        elif g.mixer == "mla":
            y, ckv, kpe = att.mla_decode(p["mixer"], cfg, h, cache["ckv"],
                                         cache["kpe"], pos)
            cache = {"ckv": ckv, "kpe": kpe}
        elif g.mixer == "mamba2":
            y, st = mb.mamba2_decode(p["mixer"], cfg, h,
                                     mb.MambaState(**cache))
            cache = st._asdict()
        elif g.mixer == "rwkv6":
            y, st = rk.rwkv6_decode(p["mixer"], cfg, h, cache)
            cache = st
        x = x + y
        if g.ffn != "none":
            h = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if g.ffn == "dense":
                y = swiglu(p["ffn"], h)
            elif g.ffn == "moe":
                y, _ = moe_lib.moe_ffn(p["ffn"], cfg, h)
            elif g.ffn == "rwkv_cm":
                y, cache = rk.channel_mix_decode(p["ffn"], cfg, h, cache)
            x = x + y
        return x, cache

    def decode_step(self, params, state, tokens):
        """ONE new token per sequence. tokens (B,1) -> (logits (B,V), state)."""
        cfg = self.cfg
        pos = state["pos"]
        enc_mask = state.get("enc_mask")
        x = self.constrain(params["embed"]["w"].astype(self.param_dtype)[tokens])
        new_caches: List[Any] = []
        for gi, g in enumerate(cfg.layer_plan):
            cache_g = state["caches"][gi]
            if g.mixer == "shared_attn":
                sp = params["shared_attn"]
                c0 = jax.tree.map(lambda a: a[0], cache_g)
                x, c1 = self._block_decode(sp, cfg, g, x, c0, pos, enc_mask)
                new_caches.append(jax.tree.map(lambda a: a[None], c1))
                continue

            def body(xx, scanned):
                lp, cache = scanned
                xx, cache = self._block_decode(lp, cfg, g, xx, cache, pos,
                                               enc_mask)
                return xx, cache

            x, cache_new = jax.lax.scan(body, x,
                                        (params["groups"][gi], cache_g))
            new_caches.append(cache_new)
        logits = self._logits(params, x[:, 0, :])
        return logits, {**state, "caches": new_caches, "pos": pos + 1}
