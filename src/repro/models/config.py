"""Unified model configuration covering all assigned architecture families.

A model is a stack of (mixer, ffn) layer pairs described by ``layer_plan``:
consecutive identical pairs are scanned with stacked params (compile-time
friendly for 95-layer models), heterogeneous patterns (hybrid SSM+shared
attention, dense-then-MoE) become multiple groups.

Mixer kinds : "attn" (GQA w/ optional qk-norm, optional sliding window,
              optional cross-attention for enc-dec decoders),
              "mla"  (DeepSeek multi-head latent attention),
              "mamba2" (SSD), "rwkv6" (data-dependent-decay linear attn),
              "shared_attn" (zamba-style single shared transformer block).
FFN kinds   : "dense" (SwiGLU), "moe" (top-k routed + shared experts),
              "rwkv_cm" (RWKV channel mix), "none".
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # normalize top-k gate weights to sum to 1 (deepseek/qwen3 style)
    norm_topk: bool = True


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64       # N
    head_dim: int = 64        # P
    expand: int = 2           # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128          # SSD chunk length
    n_groups: int = 1         # B/C groups


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64      # rank of the data-dependent decay MLP
    token_shift: bool = True


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    mixer: str                # attn | mla | mamba2 | rwkv6 | shared_attn
    ffn: str                  # dense | moe | rwkv_cm | none
    count: int
    cross_attn: bool = False  # decoder group attends to encoder output


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Transformer encoder for enc-dec models (whisper).

    The modality frontend (mel + conv) is a stub: ``input_specs`` feeds
    precomputed frame embeddings of shape (B, frames, d_model).
    """
    num_layers: int
    max_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    vocab_size: int
    layer_plan: Tuple[LayerGroup, ...]
    # attention geometry (used by attn/shared_attn groups)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 1e6
    sliding_window: Optional[int] = None
    # ffn geometry
    d_ff: int = 0             # dense FFN width
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    # multi-token prediction (deepseek-v3): extra depth-1 predict block
    mtp_depth: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # capability flags used by the launcher
    supports_long_decode: bool = False   # sub-quadratic decode at 500k ctx
    is_encoder_decoder: bool = False
    citation: str = ""

    # ------------------------------------------------------------- helpers
    @property
    def num_layers(self) -> int:
        return sum(g.count for g in self.layer_plan)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the lm-head/logits vocab dim shards evenly
        over a 16-way tensor-parallel axis (Megatron-style padding; the
        pad columns are masked to -inf in ``LM._logits``)."""
        return ((self.vocab_size + 127) // 128) * 128

    def validate(self) -> "ModelConfig":
        assert self.d_model > 0 and self.vocab_size > 0
        uses_attn = any(g.mixer in ("attn", "mla", "shared_attn")
                        for g in self.layer_plan)
        if uses_attn and self.mla is None:
            assert self.num_heads > 0 and self.head_dim > 0
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if any(g.ffn == "moe" for g in self.layer_plan):
            assert self.moe is not None
        if any(g.mixer == "mamba2" for g in self.layer_plan):
            assert self.ssm is not None
            d_inner = self.ssm.expand * self.d_model
            assert d_inner % self.ssm.head_dim == 0
        if any(g.mixer == "rwkv6" for g in self.layer_plan):
            assert self.rwkv is not None
            assert self.d_model % self.rwkv.head_dim == 0
        if self.is_encoder_decoder:
            assert self.encoder is not None
        return self

    # -- parameter counting (for roofline MODEL_FLOPS = 6·N·D) -------------
    def param_counts(self) -> dict:
        """Returns {"total": n, "active": n_active} parameter counts."""
        d = self.d_model
        total = d * self.vocab_size  # input embed
        if not self.tie_embeddings:
            total += d * self.vocab_size  # lm head
        active = total
        shared_attn_counted = False
        for g in self.layer_plan:
            mixer = ffn = 0
            if g.mixer in ("attn", "shared_attn") and self.mla is None:
                q = d * self.num_heads * self.head_dim
                kv = 2 * d * self.num_kv_heads * self.head_dim
                o = self.num_heads * self.head_dim * d
                mixer = q + kv + o
                if g.cross_attn:
                    mixer *= 2
            elif g.mixer == "mla":
                m = self.mla
                mixer = (d * m.q_lora_rank
                         + m.q_lora_rank * self.num_heads
                         * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                         + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                         + m.kv_lora_rank * self.num_heads
                         * (m.qk_nope_head_dim + m.v_head_dim)
                         + self.num_heads * m.v_head_dim * d)
            elif g.mixer == "mamba2":
                s = self.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                mixer = (d * (2 * d_in + 2 * s.n_groups * s.state_dim + nh)
                         + d_in * d)
            elif g.mixer == "rwkv6":
                r = self.rwkv
                mixer = 4 * d * d + d * d  # r,k,v,g + output
                mixer += 2 * d * r.decay_lora  # decay LoRA
            if g.ffn == "dense":
                ffn = 3 * d * self.d_ff
            elif g.ffn == "moe":
                mo = self.moe
                per_exp = 3 * d * mo.d_ff_expert
                ffn = mo.num_experts * per_exp + d * mo.num_experts  # + router
                ffn += mo.num_shared_experts * per_exp
                ffn_active = (mo.top_k + mo.num_shared_experts) * per_exp \
                    + d * mo.num_experts
            elif g.ffn == "rwkv_cm":
                ffn = int(3.5 * d * d)
            if g.mixer == "shared_attn":
                # weights stored once, applied g.count times
                if not shared_attn_counted:
                    total += mixer + ffn
                    shared_attn_counted = True
                active += (mixer + ffn) * g.count
                continue
            total += (mixer + ffn) * g.count
            active += (mixer + (ffn_active if g.ffn == "moe" else ffn)) * g.count
        if self.encoder is not None:
            enc_attn = 4 * d * self.num_heads * self.head_dim
            enc = self.encoder.num_layers * (enc_attn + 3 * d * self.d_ff)
            total += enc
            active += enc
        return {"total": int(total), "active": int(active)}
