"""Layer library: attention (GQA/MLA/SWA), MoE, Mamba2 SSD, RWKV6."""
