"""RWKV6 ("Finch") mixers: time-mix with data-dependent decay + channel-mix.

Per head (P = head_dim) the time-mix recurrence over state S (P_k x P_v):

    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with the *data-dependent* per-channel decay w_t = exp(-exp(w0 + lora(x)))
— Finch's contribution over RWKV5's static decay [arXiv:2404.05892].

Training/prefill uses a chunked formulation (TPU adaptation: chunk-local
matmuls instead of a 1-token/step scan).  Because the decay is per-channel
(not per-head-scalar like Mamba2), the intra-chunk term factorizes through
decay-weighted r' = r*exp(cum) and k' = k*exp(-cum); stability is
guaranteed by clamping the per-step log-decay (|log w| <= CLAMP), which is
lossless in practice since decay^chunk underflows anyway.

Decode is the O(1) recurrence — RWKV has *no KV cache*, which is why
rwkv6-3b runs the 500k-context shape.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, RWKVConfig
from repro.models.layers.basic import linear, linear_params

LOG_DECAY_CLAMP = 2.5   # per-step |log w| bound; exp(2.5*chunk) stays in f32


class RWKVState(NamedTuple):
    wkv: jnp.ndarray      # (B, H, P, P) time-mix state
    shift_tm: jnp.ndarray  # (B, D) previous token (time-mix shift)
    shift_cm: jnp.ndarray  # (B, D) previous token (channel-mix shift)


def rwkv6_params(key, cfg: ModelConfig, dtype=jnp.float32):
    r: RWKVConfig = cfg.rwkv
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    h = d // r.head_dim
    return {
        # token-shift interpolation coefficients per stream
        "mix": {name: (0.5 * jnp.ones((d,), jnp.float32))
                for name in ("r", "k", "v", "g", "w")},
        "r": linear_params(ks[0], d, d, dtype),
        "k": linear_params(ks[1], d, d, dtype),
        "v": linear_params(ks[2], d, d, dtype),
        "g": linear_params(ks[3], d, d, dtype),
        # data-dependent decay LoRA: d -> rank -> d
        "w_down": linear_params(ks[4], d, r.decay_lora, dtype),
        "w_up": linear_params(ks[5], r.decay_lora, d, dtype),
        "w0": (-1.0 * jnp.ones((d,), jnp.float32)),
        "u": (jnp.zeros((h, r.head_dim), jnp.float32)),   # bonus
        "ln_g": jnp.ones((d,), jnp.float32),              # group norm scale
        "ln_b": jnp.zeros((d,), jnp.float32),
        "o": linear_params(ks[6], d, d, dtype),
    }


def channel_mix_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    dh = int(3.5 * d)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mix": {name: 0.5 * jnp.ones((d,), jnp.float32) for name in ("r", "k")},
        "rk": linear_params(k1, d, d, dtype),
        "kk": linear_params(k2, d, dh, dtype),
        "vv": linear_params(k3, dh, d, dtype),
    }


def _token_shift(x, prev):
    """shifted[t] = x[t-1]; shifted[0] = prev (carry across calls)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(mix_coef, x, x_prev):
    c = mix_coef.astype(x.dtype)
    return x + (x_prev - x) * c


def _streams(p, cfg, x, shift_prev):
    """Project the five time-mix streams. x (B,S,D)."""
    r_cfg = cfg.rwkv
    xs = _token_shift(x, shift_prev)
    r = linear(p["r"], _mix(p["mix"]["r"], x, xs))
    k = linear(p["k"], _mix(p["mix"]["k"], x, xs))
    v = linear(p["v"], _mix(p["mix"]["v"], x, xs))
    g = linear(p["g"], _mix(p["mix"]["g"], x, xs))
    wx = _mix(p["mix"]["w"], x, xs)
    w_log = p["w0"] + linear(p["w_up"], jnp.tanh(linear(p["w_down"], wx))
                             ).astype(jnp.float32)
    # per-step log decay, clamped for chunked stability
    log_w = -jnp.clip(jnp.exp(w_log), 1e-4, LOG_DECAY_CLAMP)   # (B,S,D) <= 0
    return r, k, v, g, log_w


def _group_norm(p, y, eps, heads):
    """Per-head LayerNorm over P (RWKV's ln_x), then flatten."""
    b, s, h, pp = y.shape
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(b, s, h * pp) * p["ln_g"] + p["ln_b"]
    return yn


def rwkv6_full(p, cfg: ModelConfig, x, state: RWKVState, *,
               impl: str = "xla") -> Tuple[jnp.ndarray, RWKVState]:
    """Chunked WKV over a full sequence. Returns (y (B,S,D), final state).

    ``impl="pallas"`` dispatches the inner WKV recurrence to the
    :func:`repro.kernels.ops.rwkv6_wkv` Pallas kernel (interpret mode on
    CPU, Mosaic on TPU); ``"xla"`` keeps the pure-jnp chunked scan.  Both
    compute the identical chunk algorithm — parity is pinned in
    tests/test_bigmodel_serving.py.
    """
    rc = cfg.rwkv
    b, seq, d = x.shape
    hnum, pdim = d // rc.head_dim, rc.head_dim

    r, k, v, g, log_w = _streams(p, cfg, x, state.shift_tm)
    rh = r.reshape(b, seq, hnum, pdim)
    kh = k.reshape(b, seq, hnum, pdim)
    vh = v.reshape(b, seq, hnum, pdim)
    lw = log_w.reshape(b, seq, hnum, pdim)               # f32

    from repro.models.layers.mamba2 import pick_chunk
    L = pick_chunk(seq, 32)
    nc = seq // L

    if impl == "pallas":
        from repro.kernels.ops import rwkv6_wkv
        y, s_final = rwkv6_wkv(
            rh.astype(jnp.float32), kh.astype(jnp.float32),
            vh.astype(jnp.float32), lw, p["u"],
            state.wkv.astype(jnp.float32), chunk=L)
        y = _group_norm(p, y, cfg.norm_eps, hnum)
        y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
        y = linear(p["o"], y)
        new_state = RWKVState(wkv=s_final.astype(state.wkv.dtype),
                              shift_tm=x[:, -1, :],
                              shift_cm=state.shift_cm)
        return y, new_state

    from repro.sharding.ctx import constrain_batch

    # (NC,B,L,H,P) chunk-major for the scan
    def to_chunks(x):
        return jnp.moveaxis(x.reshape(b, nc, L, hnum, pdim), 1, 0)

    xs = (to_chunks(rh), to_chunks(kh), to_chunks(vh), to_chunks(lw))
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)         # strictly lower: j<t

    # One chunk at a time: per-chunk intermediates are (B,L,H,P)/(B,H,L,L)
    # and the remat'd body keeps backward peak memory per-chunk too (the
    # vectorized-over-NC form holds ~16 full-sequence f32 tensors during
    # backward — tens of GB/device at train_4k; see EXPERIMENTS.md §Perf).
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(s_prev, inp):
        rC, kC, vC, lwC = (t.astype(jnp.float32) for t in inp)  # (B,L,H,P)
        cum = jnp.cumsum(lwC, axis=1)                    # (B,L,H,P) <= 0
        cum_prev = cum - lwC
        # intra: A[t,j] = sum_c r_t,c k_j,c exp(cum_prev_t - cum_j), j<t
        r_dec = constrain_batch(rC * jnp.exp(cum_prev))
        k_inc = constrain_batch(kC * jnp.exp(-cum))
        a = jnp.einsum("blhp,bmhp->bhlm", r_dec, k_inc)  # (B,H,L,L)
        a = jnp.where(tri, a, 0.0)
        bonus = jnp.einsum("blhp,hp,blhp->blh", rC, p["u"], kC)
        y = jnp.einsum("bhlm,bmhp->blhp", a, vC)
        y = y + bonus[..., None] * vC
        # inter: y_t += (r_t * exp(cum_prev_t)) · S_start
        y = y + jnp.einsum("blhp,bhpq->blhq", r_dec, s_prev)
        # state: S_end = diag(exp(cum_L)) S_start + sum_j exp(cum_L-cum_j) kv
        wj = jnp.exp(cum[:, -1:, :, :] - cum)            # (B,L,H,P)
        inc = jnp.einsum("blhp,blhq->bhpq", kC * wj, vC)
        s_new = s_prev * jnp.exp(cum[:, -1, :, :])[..., None] + inc
        return s_new, y

    s_final, ys = jax.lax.scan(chunk_body, state.wkv.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, seq, hnum, pdim)

    y = _group_norm(p, y, cfg.norm_eps, hnum)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    y = linear(p["o"], y)
    new_state = RWKVState(wkv=s_final.astype(state.wkv.dtype),
                          shift_tm=x[:, -1, :],
                          shift_cm=state.shift_cm)
    return y, new_state


def rwkv6_decode(p, cfg: ModelConfig, x, state: RWKVState
                 ) -> Tuple[jnp.ndarray, RWKVState]:
    """One-token recurrence. x (B,1,D)."""
    rc = cfg.rwkv
    b, _, d = x.shape
    hnum, pdim = d // rc.head_dim, rc.head_dim
    r, k, v, g, log_w = _streams(p, cfg, x, state.shift_tm)
    rh = r.reshape(b, hnum, pdim).astype(jnp.float32)
    kh = k.reshape(b, hnum, pdim).astype(jnp.float32)
    vh = v.reshape(b, hnum, pdim).astype(jnp.float32)
    w = jnp.exp(log_w.reshape(b, hnum, pdim))            # (B,H,P)

    s_prev = state.wkv.astype(jnp.float32)               # (B,H,P,P)
    kv = jnp.einsum("bhp,bhq->bhpq", kh, vh)
    y = jnp.einsum("bhp,bhpq->bhq", rh, s_prev + p["u"][None, :, :, None] * kv)
    s_new = s_prev * w[..., None] + kv

    y = _group_norm(p, y.reshape(b, 1, hnum, pdim), cfg.norm_eps, hnum)
    y = (y * jax.nn.silu(g.reshape(b, 1, d).astype(jnp.float32))).astype(x.dtype)
    y = linear(p["o"], y)
    return y, RWKVState(wkv=s_new.astype(state.wkv.dtype),
                        shift_tm=x[:, -1, :], shift_cm=state.shift_cm)


def channel_mix_full(p, cfg: ModelConfig, x, state: RWKVState
                     ) -> Tuple[jnp.ndarray, RWKVState]:
    xs = _token_shift(x, state.shift_cm)
    r = jax.nn.sigmoid(linear(p["rk"], _mix(p["mix"]["r"], x, xs)))
    k = linear(p["kk"], _mix(p["mix"]["k"], x, xs))
    y = r * linear(p["vv"], jnp.square(jax.nn.relu(k)))
    return y, state._replace(shift_cm=x[:, -1, :])


def channel_mix_decode(p, cfg: ModelConfig, x, state: RWKVState
                       ) -> Tuple[jnp.ndarray, RWKVState]:
    xs = state.shift_cm[:, None, :]
    r = jax.nn.sigmoid(linear(p["rk"], _mix(p["mix"]["r"], x, xs)))
    k = linear(p["kk"], _mix(p["mix"]["k"], x, xs))
    y = r * linear(p["vv"], jnp.square(jax.nn.relu(k)))
    return y, state._replace(shift_cm=x[:, -1, :])


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    rc = cfg.rwkv
    d = cfg.d_model
    h = d // rc.head_dim
    return RWKVState(
        wkv=jnp.zeros((batch, h, rc.head_dim, rc.head_dim), dtype),
        shift_tm=jnp.zeros((batch, d), dtype),
        shift_cm=jnp.zeros((batch, d), dtype),
    )
