"""Attention mixers: GQA (full / decode-vs-cache), sliding window, cross
attention, and DeepSeek-style MLA with the compressed-KV decode path.

Shapes: activations (B, S, D); KV caches (B, S_max, H_kv, Dh); MLA cache
is the *compressed* latent (B, S_max, kv_lora_rank + qk_rope_head_dim) —
that compression is MLA's contribution (DeepSeek-V2/V3) and is what makes
its long-context decode memory traffic ~1/28th of dense GQA.

All masks are built from position arithmetic (no (S,S) bool materialized
for decode). The jnp paths here are the lowering targets for the dry-run;
``repro.kernels`` holds the Pallas TPU versions validated against
``repro.kernels.ref`` (same math).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import MLAConfig, ModelConfig
from repro.models.layers.basic import (
    apply_rope,
    head_rmsnorm,
    linear,
    linear_params,
    rmsnorm,
    rmsnorm_params,
)

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ====================================================================== GQA
def gqa_params(key, cfg: ModelConfig, cross: bool = False, dtype=jnp.float32):
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "q": linear_params(ks[0], d, h * dh, dtype),
        "k": linear_params(ks[1], d, hkv * dh, dtype),
        "v": linear_params(ks[2], d, hkv * dh, dtype),
        "o": linear_params(ks[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"g": jnp.ones((dh,), jnp.float32)}
        p["k_norm"] = {"g": jnp.ones((dh,), jnp.float32)}
    if cross:
        p["xq"] = linear_params(ks[4], d, h * dh, dtype)
        p["xk"] = linear_params(ks[5], d, hkv * dh, dtype)
        p["xv"] = linear_params(ks[6], d, hkv * dh, dtype)
        p["xo"] = linear_params(ks[7], h * dh, d, dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, positions, prefix=""):
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p[prefix + "q"], x).reshape(b, s, h, dh)
    k = linear(p[prefix + "k"], x).reshape(b, s, hkv, dh)
    v = linear(p[prefix + "v"], x).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = head_rmsnorm(p["q_norm"]["g"], q, cfg.norm_eps)
        k = head_rmsnorm(p["k_norm"]["g"], k, cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sdpa(q, k, v, mask, scale: Optional[float] = None):
    """Grouped scaled-dot-product attention (materialized scores).

    q (B,S,H,Dh), k/v (B,T,Hkv,Dh), mask (B,S,T) bool (True=keep).
    Used on SHORT query lengths only (decode S=1, tiny tests); long
    sequences go through :func:`blocked_sdpa`.
    """
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(b, s, hkv, rep, dh)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg, k) * jnp.asarray(scale, q.dtype)
    scores = jnp.where(mask[:, None, None, :, :], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgd->bsgrd", w, v)
    return out.reshape(b, s, h, dh)


DEFAULT_Q_BLOCK = 512


def blocked_sdpa(q, k, v, *, causal: bool = True,
                 window: Optional[int] = None, kv_mask=None,
                 q_block: int = DEFAULT_Q_BLOCK, scale: Optional[float] = None):
    """Memory-bounded attention: scan over query blocks, remat per block.

    Never materializes (S,T) score tensors — per step only
    (B, q_block, H, T) lives, and jax.checkpoint on the body makes the
    backward recompute it (flash-attention's memory discipline expressed
    in HLO; the Pallas kernel in repro.kernels is the TPU-tiled version
    of the same schedule).

    q (B,S,H,Dh); k/v (B,T,Hkv,Dh); kv_mask (B,T) optional (cross-attn).
    Query positions are the LAST S positions of the T-long key axis
    (offset = T - S), which covers self-attention (T=S) and decode-tail
    use alike.
    """
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    l = min(q_block, s)
    pad = (-s) % l
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q.shape[1] // l
    qb = q.reshape(b, nb, l, hkv, rep, dh)
    offset = t - s
    kpos = jnp.arange(t)[None, :]

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(qblk, blk_idx):
        scores = jnp.einsum("blgrd,btgd->bgrlt", qblk, k) \
            * jnp.asarray(scale, q.dtype)
        scores = scores.astype(jnp.float32)
        qpos = blk_idx * l + jnp.arange(l)[:, None] + offset   # (l,1)
        mask = jnp.ones((l, t), bool)
        if causal:
            mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        if kv_mask is not None:
            scores = jnp.where(kv_mask[:, None, None, None, :] > 0,
                               scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bgrlt,btgd->blgrd", w, v)

    def scan_body(_, inp):
        qblk, idx = inp
        return (), body(qblk, idx)

    _, out = jax.lax.scan(scan_body, (),
                          (jnp.moveaxis(qb, 1, 0), jnp.arange(nb)))
    dv = v.shape[-1]                      # may differ from q's head dim (MLA)
    out = jnp.moveaxis(out, 0, 1).reshape(b, nb * l, h, dv)
    return out[:, :s]


def attn_full(p, cfg: ModelConfig, x, *, window: Optional[int] = None,
              causal: bool = True, positions=None,
              q_block: int = DEFAULT_Q_BLOCK):
    """Full-sequence attention (train / prefill). Returns (y, (k, v))."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q, k, v = _qkv(p, cfg, x, positions)
    y = blocked_sdpa(q, k, v, causal=causal, window=window, q_block=q_block)
    y = linear(p["o"], y.reshape(b, s, -1))
    return y, (k, v)


def attn_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                window: Optional[int] = None, ring: bool = False):
    """One-token decode against a fixed-size cache.

    x (B,1,D); cache_k/v (B,S_max,Hkv,Dh); pos (B,) is the ABSOLUTE token
    position (drives RoPE).  Two cache disciplines:

    * linear (ring=False): slot == position; optional sliding ``window``
      masks out slots older than pos-window.
    * ring (ring=True): cache holds exactly the last S_max tokens, the
      write slot is pos % S_max, and once pos >= S_max every slot is valid
      history.  This is the 500k-context SWA cache: memory O(window), not
      O(context).
    """
    b, _, _ = x.shape
    s_max = cache_k.shape[1]
    positions = pos[:, None]                                  # (B,1)
    q, k, v = _qkv(p, cfg, x, positions)
    write_idx = pos % s_max if ring else pos
    oh = jax.nn.one_hot(write_idx, s_max, dtype=cache_k.dtype)  # (B,S_max)
    cache_k = cache_k * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * k
    cache_v = cache_v * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * v
    idx = jnp.arange(s_max)[None, :]                          # (1,S_max)
    if ring:
        mask = (idx <= pos[:, None]) | (pos[:, None] >= s_max)
    else:
        mask = idx <= pos[:, None]
        if window is not None:
            mask &= idx > (pos[:, None] - window)
    y = sdpa(q, cache_k, cache_v, mask[:, None, :])
    y = linear(p["o"], y.reshape(b, 1, -1))
    return y, cache_k, cache_v


def cross_attn(p, cfg: ModelConfig, x, enc_k, enc_v, enc_mask):
    """Decoder->encoder attention. enc_k/v (B,T,Hkv,Dh) precomputed."""
    b, s, _ = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    q = linear(p["xq"], x).reshape(b, s, h, dh)
    y = blocked_sdpa(q, enc_k, enc_v, causal=False, kv_mask=enc_mask)
    return linear(p["xo"], y.reshape(b, s, -1))


def encode_cross_kv(p, cfg: ModelConfig, enc_out):
    b, t, _ = enc_out.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    k = linear(p["xk"], enc_out).reshape(b, t, hkv, dh)
    v = linear(p["xv"], enc_out).reshape(b, t, hkv, dh)
    return k, v


def attn_decode_seq_sharded(p, cfg: ModelConfig, x, cache_k, cache_v, pos,
                            *, mesh, seq_axis: str, batch_axes):
    """Flash-decode over a sequence-sharded cache via shard_map.

    Each ``seq_axis`` shard updates/attends only its local cache slice and
    the shards exchange softmax statistics (running max, normalizer,
    weighted accumulator — O(B,H,Dh) per layer) instead of the baseline's
    cache/score all-gathers.  This is the TPU-native analog of
    flash-decode's split-K reduction, expressed with lax collectives.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b = x.shape[0]
    s_max = cache_k.shape[1]
    hkv, h, dh = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    rep = h // hkv
    positions = pos[:, None]
    q, k_new, v_new = _qkv(p, cfg, x, positions)      # q (B,1,H,Dh)
    nshards = mesh.shape[seq_axis]
    s_loc = s_max // nshards
    bspec = batch_axes if batch_axes else None

    def body(q_l, kn, vn, ck, cv, pos_l):
        # local shapes: ck/cv (B_l, s_loc, Hkv, Dh); q_l (B_l,1,H,Dh)
        i = jax.lax.axis_index(seq_axis)
        base = i * s_loc
        local = pos_l - base
        in_range = (local >= 0) & (local < s_loc)
        oh = (jax.nn.one_hot(jnp.clip(local, 0, s_loc - 1), s_loc,
                             dtype=ck.dtype)
              * in_range[:, None].astype(ck.dtype))
        ck = ck * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * kn
        cv = cv * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * vn

        bl = q_l.shape[0]
        qg = q_l.reshape(bl, 1, hkv, rep, dh)
        scores = jnp.einsum("bsgrd,btgd->bgrst", qg, ck) \
            * jnp.asarray(dh ** -0.5, q_l.dtype)       # (B,g,r,1,s_loc)
        idx = base + jnp.arange(s_loc)[None, :]
        mask = idx <= pos_l[:, None]
        scores = jnp.where(mask[:, None, None, None, :],
                           scores.astype(jnp.float32), NEG_INF)
        m_loc = scores.max(axis=-1)                    # (B,g,r,1)
        pexp = jnp.exp(scores - m_loc[..., None])
        pexp = jnp.where(mask[:, None, None, None, :], pexp, 0.0)
        l_loc = pexp.sum(axis=-1)
        o_loc = jnp.einsum("bgrst,btgd->bgrsd",
                           pexp.astype(ck.dtype), cv)  # (B,g,r,1,Dh)
        # combine split-cache softmax stats across the seq shards
        m_g = jax.lax.pmax(m_loc, seq_axis)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, seq_axis)
        o = jax.lax.psum(o_loc * corr[..., None].astype(o_loc.dtype),
                         seq_axis)
        o = o / jnp.maximum(l_g, 1e-30)[..., None].astype(o_loc.dtype)
        return o.reshape(bl, 1, h, dh), ck, cv

    y, ck, cv = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None),
                  P(bspec, seq_axis, None, None),
                  P(bspec, seq_axis, None, None), P(bspec)),
        out_specs=(P(bspec, None, None, None),
                   P(bspec, seq_axis, None, None),
                   P(bspec, seq_axis, None, None)),
        check_rep=False,
    )(q, k_new, v_new, cache_k, cache_v, pos)
    y = linear(p["o"], y.reshape(x.shape[0], 1, -1))
    return y, ck, cv


# ====================================================================== MLA
def mla_params(key, cfg: ModelConfig, dtype=jnp.float32):
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "q_down": linear_params(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": rmsnorm_params(m.q_lora_rank),
        "q_up": linear_params(ks[1], m.q_lora_rank,
                              h * (m.qk_nope_head_dim + m.qk_rope_head_dim),
                              dtype),
        "kv_down": linear_params(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                                 dtype),
        "kv_norm": rmsnorm_params(m.kv_lora_rank),
        "k_up": linear_params(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim,
                              dtype),
        "v_up": linear_params(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "o": linear_params(ks[5], h * m.v_head_dim, d, dtype),
    }


def _mla_q(p, cfg, x, positions):
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    cq = rmsnorm(p["q_norm"], linear(p["q_down"], x), cfg.norm_eps)
    q = linear(p["q_up"], cq).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_latent(p, cfg, x, positions):
    """Compressed KV latent: c_kv (B,S,rank) + rotated shared k_pe (B,S,dr)."""
    m = cfg.mla
    ckv_full = linear(p["kv_down"], x)
    c_kv, k_pe = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_pe


def mla_full(p, cfg: ModelConfig, x, *, positions=None):
    """Full-sequence MLA (train/prefill), expanded form. Returns (y, cache).

    cache = (c_kv, k_pe): the compressed latent is what gets cached —
    per token it is kv_lora_rank + qk_rope_head_dim floats vs
    2*H*Dh for dense GQA (DeepSeek-V3's ~28x KV reduction).
    """
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q_nope, q_pe = _mla_q(p, cfg, x, positions)
    c_kv, k_pe = _mla_latent(p, cfg, x, positions)
    k_nope = linear(p["k_up"], c_kv).reshape(b, s, h, m.qk_nope_head_dim)
    v = linear(p["v_up"], c_kv).reshape(b, s, h, m.v_head_dim)
    # fold the shared rope key into per-head effective q/k so the blocked
    # (flash-style) path applies unchanged: scores = q_eff · k_eff
    q_eff = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_eff = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                  (b, s, h, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    y = blocked_sdpa(q_eff, k_eff, v, causal=True, scale=scale)
    y = y.reshape(b, s, -1)
    return linear(p["o"], y), (c_kv, k_pe)


def mla_decode(p, cfg: ModelConfig, x, cache_ckv, cache_kpe, pos):
    """One-token MLA decode in the *absorbed* formulation.

    Attention runs directly in the compressed latent space: q_nope is
    absorbed through k_up (q_c = q_nope @ W_uk per head), scores are taken
    against the cached latent, and the weighted latent is expanded through
    v_up once per step. Per-step HBM traffic is the latent cache
    (rank+dr ~ 576 floats/token) instead of 2*H*Dh (=32768 for V3).
    """
    m, h = cfg.mla, cfg.num_heads
    b = x.shape[0]
    s_max = cache_ckv.shape[1]
    positions = pos[:, None]
    q_nope, q_pe = _mla_q(p, cfg, x, positions)           # (B,1,H,·)
    c_kv_new, k_pe_new = _mla_latent(p, cfg, x, positions)
    oh = jax.nn.one_hot(pos, s_max, dtype=cache_ckv.dtype)
    cache_ckv = cache_ckv * (1 - oh)[:, :, None] + oh[:, :, None] * c_kv_new
    cache_kpe = cache_kpe * (1 - oh)[:, :, None] + oh[:, :, None] * k_pe_new
    # absorb q through W_uk: (B,H,rank)
    w_kup = p["k_up"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_kup.astype(x.dtype))
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bhr,btr->bht", q_c, cache_ckv)
              + jnp.einsum("bhd,btd->bht", q_pe[:, 0], cache_kpe)) * scale
    mask = jnp.arange(s_max)[None, :] <= pos[:, None]
    scores = jnp.where(mask[:, None, :], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    lat = jnp.einsum("bht,btr->bhr", w, cache_ckv)        # (B,H,rank)
    w_vup = p["v_up"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    y = jnp.einsum("bhr,rhd->bhd", lat, w_vup.astype(x.dtype)).reshape(b, 1, -1)
    return linear(p["o"], y), cache_ckv, cache_kpe
