"""Norms, RoPE, embeddings, dense (SwiGLU) FFN — shared across the stack."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ norms --
def rmsnorm_params(d):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    # norm statistics in f32 regardless of activation dtype
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * p["g"]).astype(x.dtype)


def head_rmsnorm(g, x, eps: float = 1e-5):
    """qk-norm (qwen3/chameleon): RMS over the head dim of q/k."""
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * g).astype(x.dtype)


# ------------------------------------------------------------------- rope --
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, Dh), positions (..., S) -> rotated x (same dtype).

    Written as elementwise-mul + roll, with the duplicated cos/sin tables
    built from a full-width iota rather than the textbook
    concat-of-slices rotate-half: under GSPMD, `concatenate` along an
    axis that ends up sharded (e.g. a GQA k/v projection whose
    num_kv_heads < TP degree leaves Dh carrying the `model` axis) is
    miscompiled by XLA CPU 0.4.x, silently producing per-shard-local
    results.  This formulation is bitwise identical on replicated inputs
    (the freq/sign tables take the same float32 values, and
    a*c - b*s == a*c + b*(-s) in IEEE) and contains no concat at all,
    so it partitions correctly under any sharding of Dh.
    """
    dh = x.shape[-1]
    # full-width tables via index arithmetic: entry i and i + dh/2 carry
    # the same frequency; the sign flips across the halfway boundary.
    idx = jnp.arange(dh, dtype=jnp.float32)
    freqs_full = 1.0 / (theta ** ((idx % (dh // 2)) * 2.0 / dh))   # (Dh,)
    sign_full = jnp.where(idx < dh // 2, -1.0, 1.0)                # (Dh,)
    angles = positions[..., None].astype(jnp.float32) * freqs_full  # (...,S,Dh)
    cos_full = jnp.cos(angles)[..., None, :]                       # (...,S,1,Dh)
    sin_full = jnp.sin(angles)[..., None, :] * sign_full
    xf = x.astype(jnp.float32)
    out = xf * cos_full + jnp.roll(xf, dh // 2, axis=-1) * sin_full
    return out.astype(x.dtype)


# ------------------------------------------------------------ projections --
def linear_params(key, d_in, d_out, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
                  * scale).astype(dtype)}


def linear(p, x):
    return x @ p["w"].astype(x.dtype)


def embed_params(key, vocab, d, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (vocab, d), jnp.float32)
                  * d ** -0.5).astype(dtype)}


# ------------------------------------------------------------------- ffn --
def swiglu_params(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_params(k1, d_model, d_ff, dtype),
        "up": linear_params(k2, d_model, d_ff, dtype),
        "down": linear_params(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))
