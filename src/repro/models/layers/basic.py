"""Norms, RoPE, embeddings, dense (SwiGLU) FFN — shared across the stack."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ norms --
def rmsnorm_params(d):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    # norm statistics in f32 regardless of activation dtype
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * p["g"]).astype(x.dtype)


def head_rmsnorm(g, x, eps: float = 1e-5):
    """qk-norm (qwen3/chameleon): RMS over the head dim of q/k."""
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * g).astype(x.dtype)


# ------------------------------------------------------------------- rope --
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, Dh), positions (..., S) -> rotated x (same dtype)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                     # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,Dh/2)
    cos = jnp.cos(angles)[..., None, :]               # (...,S,1,Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : dh // 2], xf[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ projections --
def linear_params(key, d_in, d_out, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
                  * scale).astype(dtype)}


def linear(p, x):
    return x @ p["w"].astype(x.dtype)


def embed_params(key, vocab, d, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (vocab, d), jnp.float32)
                  * d ** -0.5).astype(dtype)}


# ------------------------------------------------------------------- ffn --
def swiglu_params(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_params(k1, d_model, d_ff, dtype),
        "up": linear_params(k2, d_model, d_ff, dtype),
        "down": linear_params(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))
