"""Mamba2 (SSD — state-space duality) mixer.

Recurrence per head h with state H (P, N):
    H_t = a_t * H_{t-1} + dt_t * x_t ⊗ B_t        a_t = exp(dt_t * A_h) ∈ (0,1)
    y_t = H_t @ C_t + D_h * x_t

Training/prefill uses the *chunked* SSD algorithm (TPU-idiomatic: chunk
matmuls hit the MXU; the sequential dependency is reduced to one scan over
S/chunk inter-chunk states instead of S steps).  Decode is the O(1) state
update — the property that makes 500k-token contexts feasible
(DESIGN.md §Arch-applicability).

Shapes: x (B,S,D); inner width d_in = expand*D split into nh = d_in/P
heads; B/C are shared across heads within n_groups groups.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMConfig
from repro.models.layers.basic import linear, linear_params, rmsnorm


def pick_chunk(seq: int, chunk: int) -> int:
    """Largest divisor of ``seq`` that is <= ``chunk`` (production shapes
    divide exactly; odd smoke/prefill lengths degrade gracefully)."""
    l = min(chunk, seq)
    while seq % l:
        l -= 1
    return max(l, 1)


class MambaState(NamedTuple):
    ssm: jnp.ndarray     # (B, nh, P, N)
    conv: jnp.ndarray    # (B, conv_width-1, conv_channels) rolling buffer


def mamba2_params(key, cfg: ModelConfig, dtype=jnp.float32):
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.state_dim
    ks = jax.random.split(key, 4)
    # in_proj emits [z, x, B, C, dt]
    return {
        "in_proj": linear_params(ks[0], d, 2 * d_in + 2 * s.n_groups * s.state_dim + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch), jnp.float32)
                   * (s.conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_g": {"g": jnp.ones((d_in,), jnp.float32)},
        "out_proj": linear_params(ks[2], d_in, d, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    gn = s.n_groups * s.state_dim
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv_full(p, xbc):
    """Depthwise causal conv over (B,S,C) with window W; silu activation."""
    w = p["conv_w"].astype(xbc.dtype)                  # (W, C)
    wwidth = w.shape[0]
    pads = jnp.pad(xbc, ((0, 0), (wwidth - 1, 0), (0, 0)))
    # sum_k x[t-W+1+k] * w[k]
    out = sum(pads[:, k:k + xbc.shape[1], :] * w[k] for k in range(wwidth))
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _heads(cfg, x_in, b_in, c_in):
    s = cfg.ssm
    b_, seq = x_in.shape[0], x_in.shape[1]
    nh = (s.expand * cfg.d_model) // s.head_dim
    x = x_in.reshape(b_, seq, nh, s.head_dim)
    bb = b_in.reshape(b_, seq, s.n_groups, s.state_dim)
    cc = c_in.reshape(b_, seq, s.n_groups, s.state_dim)
    # broadcast groups over heads
    rep = nh // s.n_groups
    bb = jnp.repeat(bb, rep, axis=2)
    cc = jnp.repeat(cc, rep, axis=2)
    return x, bb, cc


def mamba2_full(p, cfg: ModelConfig, x, *,
                impl: str = "xla") -> Tuple[jnp.ndarray, MambaState]:
    """Chunked SSD over a full sequence. Returns (y (B,S,D), final state).

    ``impl="pallas"`` dispatches the inner SSD scan to the
    :func:`repro.kernels.ops.ssd_scan` Pallas kernel (interpret mode on
    CPU, Mosaic on TPU); ``"xla"`` keeps the pure-jnp chunked scan.  Both
    compute the identical chunk algorithm — parity is pinned in
    tests/test_bigmodel_serving.py.
    """
    s = cfg.ssm
    b, seq, _ = x.shape
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim

    zxbcdt = linear(p["in_proj"], x)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv_full(p, xbc)
    x_in, b_in, c_in = jnp.split(
        xbc, [d_in, d_in + s.n_groups * s.state_dim], axis=-1)
    xh, bh, ch = _heads(cfg, x_in, b_in, c_in)          # (B,S,nh,P),(B,S,nh,N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                # (B,S,nh)
    a = -jnp.exp(p["a_log"])                            # (nh,) negative
    log_decay = dt * a                                  # (B,S,nh)  <= 0

    L = pick_chunk(seq, s.chunk)
    nc = seq // L

    if impl == "pallas":
        from repro.kernels.ops import ssd_scan
        y, h_final = ssd_scan(
            xh.astype(jnp.float32), dt, p["a_log"],
            bh.astype(jnp.float32), ch.astype(jnp.float32), chunk=L)
        y = y.astype(xh.dtype)
        h_final = h_final.astype(xh.dtype)
        y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
        y = y.reshape(b, seq, d_in)
        y = rmsnorm(p["norm_g"], y * jax.nn.silu(z), cfg.norm_eps)
        y = linear(p["out_proj"], y)
        zxbcdt_tail = _split_proj(
            cfg, linear(p["in_proj"], x[:, -(s.conv_width - 1):, :]))[1]
        return y, MambaState(ssm=h_final, conv=zxbcdt_tail)

    from repro.sharding.ctx import constrain_batch

    def chunked(xh, bh, ch, dt, log_decay):
        # chunk-major (NC,B,L,...) for a scan over chunks: per-chunk
        # intermediates only (the vectorized-over-NC form made backward
        # hold full-sequence (B,NC,nh,L,L) tensors; see §Perf iter 2).
        def toc(t):
            return jnp.moveaxis(t.reshape(b, nc, L, *t.shape[2:]), 1, 0)

        xs = (toc(xh), toc(bh), toc(ch), toc(dt), toc(log_decay))
        tri = jnp.tril(jnp.ones((L, L), bool))

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def chunk_body(h_prev, inp):
            xc, bc, cc, dtc, ld = inp                   # (B,L,nh,·)
            cum = jnp.cumsum(ld, axis=1)                # (B,L,nh)
            # intra: scores[t,j] = C_t·B_j exp(cum_t-cum_j) dt_j, j<=t
            cb = jnp.einsum("blhs,bmhs->bhlm", cc, bc)  # (B,nh,L,L)
            seg = cum[:, :, None, :] - cum[:, None, :, :]   # (B,L,L,nh)
            seg = jnp.moveaxis(seg, -1, 1)              # (B,nh,L,L)
            # mask BEFORE exp: for j>t seg>0 overflows -> 0*inf NaN grads
            seg = jnp.where(tri, seg, -jnp.inf)
            scores = constrain_batch(cb * jnp.exp(seg).astype(cb.dtype))
            scores = scores * jnp.moveaxis(dtc, -1, 1)[:, :, None, :] \
                .astype(cb.dtype)
            y = jnp.einsum("bhlm,bmhp->blhp", scores, xc)
            # inter: y += C_t · (exp(cum_t) * H_start)
            wi = jnp.exp(cum)                           # (B,L,nh)
            y = y + jnp.einsum("blhs,bhps,blh->blhp", cc, h_prev,
                               wi.astype(cc.dtype))
            # state: H_end = exp(cum_L) H_start + sum_j exp(cum_L-cum_j) dt_j B_j x_j
            wj = jnp.exp(cum[:, -1:, :] - cum) * dtc    # (B,L,nh)
            hc = jnp.einsum("blh,blhs,blhp->bhps", wj.astype(xc.dtype),
                            bc, xc)
            h_new = h_prev * jnp.exp(cum[:, -1, :])[:, :, None, None] \
                .astype(h_prev.dtype) + hc
            return h_new, y

        h0 = jnp.zeros((b, nh, s.head_dim, s.state_dim), xh.dtype)
        h_final, ys = jax.lax.scan(chunk_body, h0, xs)
        return jnp.moveaxis(ys, 0, 1).reshape(b, seq, nh, s.head_dim), h_final

    y, h_final = chunked(xh, bh, ch, dt, log_decay)
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, seq, d_in)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rmsnorm(p["norm_g"], y * jax.nn.silu(z), cfg.norm_eps)
    y = linear(p["out_proj"], y)

    # rolling conv buffer = last (W-1) pre-activation conv inputs
    zxbcdt_tail = _split_proj(cfg, linear(p["in_proj"], x[:, -(s.conv_width - 1):, :]))[1]
    state = MambaState(ssm=h_final, conv=zxbcdt_tail)
    return y, state


def mamba2_decode(p, cfg: ModelConfig, x, state: MambaState
                  ) -> Tuple[jnp.ndarray, MambaState]:
    """Single-token state update. x (B,1,D)."""
    s = cfg.ssm
    b = x.shape[0]
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim

    zxbcdt = linear(p["in_proj"], x)
    z, xbc_new, dt_raw = _split_proj(cfg, zxbcdt)       # (B,1,·)

    # causal conv against rolling buffer
    window = jnp.concatenate([state.conv, xbc_new], axis=1)   # (B,W,C)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(conv_out)[:, None, :]

    x_in, b_in, c_in = jnp.split(
        xbc, [d_in, d_in + s.n_groups * s.state_dim], axis=-1)
    xh, bh, ch = _heads(cfg, x_in, b_in, c_in)
    xh, bh, ch = xh[:, 0], bh[:, 0], ch[:, 0]           # (B,nh,P),(B,nh,N)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                             # (B,nh)

    h = state.ssm * decay[:, :, None, None].astype(state.ssm.dtype)
    h = h + jnp.einsum("bh,bhp,bhs->bhps",
                       dt.astype(xh.dtype), xh, bh)
    y = jnp.einsum("bhps,bhs->bhp", h, ch)
    y = y + xh * p["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(b, 1, d_in)
    y = rmsnorm(p["norm_g"], y * jax.nn.silu(z), cfg.norm_eps)
    y = linear(p["out_proj"], y)

    new_conv = window[:, 1:, :]
    return y, MambaState(ssm=h, conv=new_conv)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.state_dim
    return MambaState(
        ssm=jnp.zeros((batch, nh, s.head_dim, s.state_dim), dtype),
        conv=jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    )
