"""Mixture-of-Experts FFN: top-k routing with sort-based dropping dispatch.

TPU adaptation (DESIGN.md §6): instead of a GPU-style dynamic scatter or
the GShard one-hot dispatch einsum (whose FLOPs explode as S*E*C*D for
large E), tokens are *sorted by expert id* and packed into a fixed
(E, capacity, D) buffer — all static shapes, gather/scatter only, so the
matmul FLOPs stay ~capacity_factor * (top_k * S * 3 * D * F * 2), i.e.
the honest active-expert compute.  This is the "dropping" strategy used
by production TPU MoE stacks; with expert parallelism the (E, C, D)
buffer shards over the model axis and XLA inserts the all-to-all.

Routing: softmax router, exact top-k (jax.lax.top_k), optional gate
re-normalization (DeepSeek/Qwen3 style), optional always-on shared
experts (DeepSeek-V3 / Moonlight), and the switch-style load-balance
auxiliary loss.

Group semantics: dispatch happens within groups to bound sort sizes and
keep the batch dim shardable — one group per batch row for sequence
shapes, one global group for single-token decode.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers.basic import linear, linear_params, swiglu, swiglu_params


def moe_params(key, cfg: ModelConfig, dtype=jnp.float32):
    mo: MoEConfig = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.num_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, e), jnp.float32)
                         * scale).astype(jnp.float32)},  # router math in f32
        "experts_gate": {"w": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                               * scale).astype(dtype)},
        "experts_up": {"w": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                             * scale).astype(dtype)},
        "experts_down": {"w": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                               * (f ** -0.5)).astype(dtype)},
    }
    if mo.num_shared_experts:
        # shared experts fused into one wide SwiGLU
        p["shared"] = swiglu_params(ks[4], d, f * mo.num_shared_experts, dtype)
    return p


def _route(p, mo: MoEConfig, tokens):
    """tokens (T,D) -> (top_w (T,k) f32, top_i (T,k) i32, probs (T,E) f32)."""
    logits = (tokens.astype(jnp.float32) @ p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, mo.top_k)
    if mo.norm_topk:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_i, probs


def _build_dispatch(p, mo: MoEConfig, tokens, capacity: int):
    """Sort-based dropping dispatch for one token group (vmapped).

    tokens (T, D) -> (buf (E, C, D), metadata for the combine step).
    The expert matmuls happen OUTSIDE the vmap (see moe_ffn) so the
    launcher can pin the buffer's sharding — XLA otherwise shards the
    buffer over the expert axis and turns these local gathers into
    full-buffer collectives (EXPERIMENTS.md §Perf, MoE pair).
    """
    t, d = tokens.shape
    k, e = mo.top_k, mo.num_experts
    top_w, top_i, probs = _route(p, mo, tokens)

    flat_e = top_i.reshape(t * k)                       # expert of assignment
    flat_w = top_w.reshape(t * k)
    order = jnp.argsort(flat_e)                         # stable, groups experts
    es = flat_e[order]
    # rank of each assignment within its expert
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts                # exclusive prefix
    rank = jnp.arange(t * k) - starts[es]
    keep = (rank < capacity).astype(tokens.dtype)
    slot = es * capacity + jnp.clip(rank, 0, capacity - 1)

    tok_of = order // k                                 # source token index
    buf = jnp.zeros((e * capacity, d), tokens.dtype)
    buf = buf.at[slot].add(tokens[tok_of] * keep[:, None])
    meta = {"slot": slot, "keep": keep, "tok_of": tok_of,
            "w": flat_w[order], "probs": probs, "top_i": top_i}
    return buf.reshape(e, capacity, d), meta


def _combine_group(out_flat, meta, t: int):
    """out_flat (E*C, D) + metadata -> y (T, D) (vmapped)."""
    contrib = out_flat[meta["slot"]] * (
        meta["w"].astype(out_flat.dtype) * meta["keep"])[:, None]
    return jax.ops.segment_sum(contrib, meta["tok_of"], num_segments=t)


def load_balance_loss(probs, top_i, num_experts: int) -> jnp.ndarray:
    """Switch-transformer aux loss: E * sum_e f_e * P_e (f32 scalar)."""
    t = probs.shape[0]
    assign = jax.nn.one_hot(top_i[:, 0], num_experts, dtype=jnp.float32)
    f = assign.mean(0)                  # fraction routed (primary expert)
    pbar = probs.mean(0)
    return num_experts * jnp.sum(f * pbar)


def moe_ffn(p, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> (y (B,S,D), aux_loss scalar f32).

    Three phases so the sharding stays clean under pjit:
      1. per-group dispatch (vmap over batch rows): local sort/pack;
      2. expert SwiGLU on the packed (G, E, C, D) buffer with the batch
         dim pinned (ctx.constrain_batch) — experts shard over `model`,
         groups over `data`, no buffer collectives;
      3. per-group combine (vmap): local gather + weighted segment sum.
    """
    from repro.sharding.ctx import constrain_batch

    mo = cfg.moe
    b, s, d = x.shape
    if s == 1:
        groups = x.reshape(1, b, d)     # decode: whole batch is one group
    else:
        groups = x                      # one group per batch row
    tg = groups.shape[1]
    capacity = max(1, int(tg * mo.top_k * mo.capacity_factor
                          / mo.num_experts + 0.999))

    bufs, meta = jax.vmap(
        lambda tok: _build_dispatch(p, mo, tok, capacity)
    )(groups)                            # (G,E,C,D)
    bufs = constrain_batch(bufs)

    dt = bufs.dtype
    gg = jnp.einsum("gecd,edf->gecf", bufs, p["experts_gate"]["w"].astype(dt))
    uu = jnp.einsum("gecd,edf->gecf", bufs, p["experts_up"]["w"].astype(dt))
    out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gg) * uu,
                     p["experts_down"]["w"].astype(dt))
    out = constrain_batch(out)
    out = out.reshape(out.shape[0], mo.num_experts * capacity, d)

    y = jax.vmap(lambda o, m: _combine_group(o, m, tg))(out, meta)
    y = y.reshape(b, s, d)

    aux = load_balance_loss(meta["probs"].reshape(-1, mo.num_experts),
                            meta["top_i"].reshape(-1, mo.top_k),
                            mo.num_experts)
    if mo.num_shared_experts:
        y = y + swiglu(p["shared"], x)
    return y, aux
