"""Large-model stack: unified config + composable LM over layer groups."""

from repro.models.config import (
    EncoderConfig,
    LayerGroup,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
)
from repro.models.model import LM
from repro.models.registry import ResolvedModel, available, resolve

__all__ = [
    "EncoderConfig",
    "LayerGroup",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "RWKVConfig",
    "SSMConfig",
    "LM",
    "ResolvedModel",
    "available",
    "resolve",
]
