"""Fault injection + fault-tolerance primitives for collaborative serving.

C-NMT's premise is offloading across an unreliable edge–cloud boundary,
yet the baseline engine and DES assume tiers never crash and links never
flap.  This module is the shared vocabulary both consume:

* :class:`FaultSchedule` — a deterministic, declarative description of
  what goes wrong and when: tier outage windows (crash → restart), link
  degradation episodes (RTT spikes, bandwidth collapse, blackhole →
  timeout) and straggler windows (execution-time multipliers).  The
  schedule is *ground truth* for injection — the serving system never
  routes on it; it only experiences it through timeouts and failures.
  :meth:`FaultSchedule.random` draws a seeded random schedule so sweeps
  are reproducible.
* :class:`RetryPolicy` — per-request timeouts plus bounded retry with
  exponential backoff and deterministic jitter.  ``retry=None`` is the
  no-retry baseline: a failed request is simply lost, which is exactly
  what the pre-fault-tolerance engine did implicitly.
* :class:`CircuitBreaker` — the per-tier health belief the dispatcher
  *does* route on: open after ``failure_threshold`` consecutive
  failures, half-open probe after ``reset_timeout_s``, close again on a
  probe success.  Open breakers feed the scheduler's candidate mask
  (``decide(..., exclude=...)``), which yields the degradation ladder
  split → whole-remote → edge-only → shed for free: excluding unhealthy
  tiers from the argmin leaves the best *reachable* placement, and when
  every tier is dark the caller sheds with a ``retry_after_s`` hint.

Everything here is plain float arithmetic over virtual time — the real
engine and the discrete-event simulator consume the same objects, so a
failover policy tuned in the DES transfers to the engine unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

# circuit-breaker states
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


@dataclasses.dataclass(frozen=True)
class TierOutage:
    """Tier ``tier`` is dead (crashed / unreachable) on [start_s, end_s):
    in-flight work there fails, new dispatches are refused."""

    tier: int
    start_s: float
    end_s: float

    def __post_init__(self):
        if self.end_s <= self.start_s:
            raise ValueError("outage needs end_s > start_s")
        if self.tier < 0:
            raise ValueError("tier must be >= 0")

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s


@dataclasses.dataclass(frozen=True)
class LinkFault:
    """Degradation episode on tier ``tier``'s client link.

    ``rtt_factor``/``bandwidth_factor`` scale the true link during the
    window (RTT spike = factor > 1, bandwidth collapse = factor < 1);
    ``blackhole=True`` means packets vanish silently — a dispatch over
    the link only fails after the full request ``timeout_s`` elapses
    (the most expensive failure mode to detect).
    """

    tier: int
    start_s: float
    end_s: float
    rtt_factor: float = 1.0
    bandwidth_factor: float = 1.0
    blackhole: bool = False

    def __post_init__(self):
        if self.end_s <= self.start_s:
            raise ValueError("link fault needs end_s > start_s")
        if self.rtt_factor <= 0 or self.bandwidth_factor <= 0:
            raise ValueError("link factors must be positive")

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Tier ``tier`` runs ``slowdown``x slower on [start_s, end_s)
    (thermal throttling, noisy neighbor) — degraded, not failed."""

    tier: int
    start_s: float
    end_s: float
    slowdown: float = 1.0

    def __post_init__(self):
        if self.end_s <= self.start_s:
            raise ValueError("straggler window needs end_s > start_s")
        if self.slowdown < 1.0:
            raise ValueError("slowdown must be >= 1")

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """The full injected-fault timeline for one run (immutable).

    An empty schedule is valid and injects nothing — the fault-tolerant
    code paths are pinned bit-for-bit identical to the fault-free ones
    under it (tests enforce this), so arming the machinery is free.
    """

    outages: Tuple[TierOutage, ...] = ()
    link_faults: Tuple[LinkFault, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "outages", tuple(self.outages))
        object.__setattr__(self, "link_faults", tuple(self.link_faults))
        object.__setattr__(self, "stragglers", tuple(self.stragglers))

    @property
    def empty(self) -> bool:
        return not (self.outages or self.link_faults or self.stragglers)

    # ---------------------------------------------------------- queries --
    def tier_down(self, tier: int, t: float) -> bool:
        return any(o.tier == tier and o.active(t) for o in self.outages)

    def link_blackhole(self, tier: int, t: float) -> bool:
        return any(f.tier == tier and f.blackhole and f.active(t)
                   for f in self.link_faults)

    def link_factors(self, tier: int, t: float) -> Tuple[float, float]:
        """(rtt_factor, bandwidth_factor) of the active degradation
        episodes on tier's client link (compounded when they overlap)."""
        rtt_f, bw_f = 1.0, 1.0
        for f in self.link_faults:
            if f.tier == tier and f.active(t) and not f.blackhole:
                rtt_f *= f.rtt_factor
                bw_f *= f.bandwidth_factor
        return rtt_f, bw_f

    def slowdown(self, tier: int, t: float) -> float:
        s = 1.0
        for w in self.stragglers:
            if w.tier == tier and w.active(t):
                s *= w.slowdown
        return s

    def outage_events(self) -> List[Tuple[float, str, int]]:
        """Sorted (time, 'down'|'up', tier) crash/restart edges — what a
        discrete-event simulator schedules to fail in-flight work."""
        ev = []
        for o in self.outages:
            ev.append((o.start_s, "down", o.tier))
            ev.append((o.end_s, "up", o.tier))
        for f in self.link_faults:
            if f.blackhole:        # recovery edge re-arms half-open probes
                ev.append((f.start_s, "link_down", f.tier))
                ev.append((f.end_s, "link_up", f.tier))
        ev.sort()
        return ev

    def horizon_s(self) -> float:
        """Last fault edge (0.0 for an empty schedule)."""
        ends = [w.end_s for w in
                (*self.outages, *self.link_faults, *self.stragglers)]
        return max(ends) if ends else 0.0

    # ------------------------------------------------------ constructors --
    @staticmethod
    def random(n_tiers: int, duration_s: float, *, seed: int = 0,
               outage_rate_hz: float = 1.0 / 600.0,
               mean_outage_s: float = 30.0,
               blackhole_rate_hz: float = 0.0,
               mean_blackhole_s: float = 20.0,
               protect_tiers: Sequence[int] = (0,)) -> "FaultSchedule":
        """Seeded random schedule: per-tier Poisson outage starts with
        exponential durations (and optionally blackhole link episodes),
        skipping ``protect_tiers`` (default: tier 0, the local edge —
        the degradation ladder needs somewhere to land)."""
        rng = np.random.default_rng(seed)
        outages, links = [], []
        for k in range(n_tiers):
            if k in protect_tiers:
                continue
            t = float(rng.exponential(1.0 / outage_rate_hz)) \
                if outage_rate_hz > 0 else math.inf
            while t < duration_s:
                dur = float(rng.exponential(mean_outage_s))
                outages.append(TierOutage(k, t, t + max(dur, 1.0)))
                t += dur + float(rng.exponential(1.0 / outage_rate_hz))
            if blackhole_rate_hz > 0:
                t = float(rng.exponential(1.0 / blackhole_rate_hz))
                while t < duration_s:
                    dur = float(rng.exponential(mean_blackhole_s))
                    links.append(LinkFault(k, t, t + max(dur, 1.0),
                                           blackhole=True))
                    t += dur + float(rng.exponential(1.0 / blackhole_rate_hz))
        return FaultSchedule(outages=tuple(outages),
                             link_faults=tuple(links))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + deterministic jitter.

    ``timeout_s`` is the per-attempt response timeout — how long a
    blackholed dispatch hangs before the client gives up.  A crashed
    tier refuses the connection much faster (``fail_fast_s``, the RST
    path).  ``backoff(attempt, rng)`` returns the wait before re-try
    number ``attempt`` (0-based): base · factor^attempt, capped, with
    ±``jitter_frac`` multiplicative jitter drawn from ``rng`` so
    synchronized retry storms decorrelate (seed the rng to keep runs
    deterministic).  ``replay_shed`` lets the DES model clients that
    honor the ``retry_after_s`` backpressure hint by re-submitting.
    """

    max_retries: int = 3
    timeout_s: float = 1.0
    fail_fast_s: float = 0.05
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_max_s: float = 2.0
    jitter_frac: float = 0.1
    replay_shed: bool = True

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.timeout_s <= 0 or self.fail_fast_s <= 0:
            raise ValueError("timeouts must be positive")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError("jitter_frac must be in [0, 1)")

    def detect_s(self, blackhole: bool) -> float:
        """Time to *notice* a failed attempt: a silent blackhole costs
        the full timeout; a refused connection fails fast."""
        return self.timeout_s if blackhole else self.fail_fast_s

    def backoff(self, attempt: int, rng: np.random.Generator) -> float:
        b = min(self.backoff_base_s * self.backoff_factor ** attempt,
                self.backoff_max_s)
        if self.jitter_frac > 0.0:
            b *= 1.0 + self.jitter_frac * (2.0 * float(rng.random()) - 1.0)
        return b


@dataclasses.dataclass
class CircuitBreaker:
    """Per-tier health belief: CLOSED → (k consecutive failures) → OPEN
    → (reset_timeout_s) → HALF_OPEN probe → CLOSED on success, OPEN on
    failure.  ``allow(now)`` is the dispatch gate; exactly one request
    passes in HALF_OPEN (the probe) until it resolves."""

    failure_threshold: int = 3
    reset_timeout_s: float = 1.0

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if self.reset_timeout_s <= 0:
            raise ValueError("reset_timeout_s must be positive")
        self.state = CLOSED
        self._consecutive = 0
        self._opened_at = -math.inf
        self.n_opens = 0
        self.n_probes = 0

    def allow(self, now_s: float) -> bool:
        """May a request be dispatched to this tier right now?  An OPEN
        breaker whose cool-down elapsed transitions to HALF_OPEN and
        admits the caller as the probe."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN and \
                now_s - self._opened_at >= self.reset_timeout_s:
            self.state = HALF_OPEN
            self.n_probes += 1
            return True
        return False      # OPEN cooling down, or HALF_OPEN probe in flight

    def record_failure(self, now_s: float) -> bool:
        """Ingest one failed attempt; True when this trips the breaker
        (CLOSED past the threshold, or a failed HALF_OPEN probe)."""
        self._consecutive += 1
        if self.state == HALF_OPEN or (
                self.state == CLOSED
                and self._consecutive >= self.failure_threshold):
            self.state = OPEN
            self._opened_at = now_s
            self.n_opens += 1
            return True
        if self.state == OPEN:
            self._opened_at = now_s      # refresh cool-down under load
        return False

    def record_success(self) -> bool:
        """Ingest one successful completion; True when it *recovers* the
        tier (HALF_OPEN/OPEN → CLOSED) — the caller's cue to invalidate
        stale link state (``TxEstimator.invalidate``)."""
        recovered = self.state != CLOSED
        self.state = CLOSED
        self._consecutive = 0
        return recovered

    def time_to_probe(self, now_s: float) -> float:
        """Seconds until a half-open probe would be admitted (0 when
        dispatch is already allowed) — feeds ``retry_after_s``."""
        if self.state != OPEN:
            return 0.0
        return max(self._opened_at + self.reset_timeout_s - now_s, 0.0)


def make_breakers(n_tiers: int,
                  template: Optional[CircuitBreaker] = None
                  ) -> List[CircuitBreaker]:
    """One independent breaker per tier, cloned from ``template``."""
    t = template if template is not None else CircuitBreaker()
    return [CircuitBreaker(failure_threshold=t.failure_threshold,
                           reset_timeout_s=t.reset_timeout_s)
            for _ in range(n_tiers)]
