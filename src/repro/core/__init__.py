"""The paper's contribution: collaborative-inference scheduling for NMT.

Pipeline (paper §II):
  1. ``length_regressor``  — linear N->M output-length estimate (Fig. 3).
  2. ``latency_model``     — linear T_exe(N, M) plane per device (Fig. 2).
  3. ``tx_estimator``      — online round-trip-time tracking (§II-C).
  4. ``scheduler``         — the CI decision rule, Eq. (1)+(2).
  5. ``simulator``         — the 100k-request experiment of §III.
  6. ``profiles``          — RIPE-Atlas-like RTT connection profiles (Fig. 4).
  7. ``calibration``       — offline T_exe characterization (measured or
                             roofline-derived).
  8. ``faults``            — deterministic fault injection + retry/circuit
                             breaker policies for fault-tolerant serving
                             (beyond paper).
"""

from repro.core.length_regressor import (
    LinearN2M,
    RidgeN2M,
    HuberN2M,
    BucketN2M,
    MeanN2M,
    prefilter_pairs,
)
from repro.core.latency_model import (
    ActivationCostModel,
    DeviceProfile,
    LinearLatencyModel,
)
from repro.core.tx_estimator import LinkModel, TxEstimator
from repro.core.calibration import OnlineCalibrator
from repro.core.scheduler import (
    CNMTScheduler,
    MultiTierScheduler,
    MultiTierDecision,
    NaiveScheduler,
    OracleScheduler,
    PlacementPlan,
    SchedTier,
    StaticScheduler,
    EDGE,
    CLOUD,
)
from repro.core.faults import (
    CircuitBreaker,
    FaultSchedule,
    LinkFault,
    RetryPolicy,
    Straggler,
    TierOutage,
)
from repro.core.profiles import ConnectionProfile, make_profile
from repro.core.simulator import (
    DESResult,
    SimTier,
    SimulationResult,
    make_poisson_stream,
    make_stream,
    simulate,
    simulate_des,
    table1_row,
)

__all__ = [
    "LinearN2M",
    "RidgeN2M",
    "HuberN2M",
    "BucketN2M",
    "MeanN2M",
    "prefilter_pairs",
    "ActivationCostModel",
    "LinearLatencyModel",
    "DeviceProfile",
    "LinkModel",
    "TxEstimator",
    "OnlineCalibrator",
    "PlacementPlan",
    "CNMTScheduler",
    "MultiTierScheduler",
    "MultiTierDecision",
    "NaiveScheduler",
    "OracleScheduler",
    "SchedTier",
    "StaticScheduler",
    "EDGE",
    "CLOUD",
    "CircuitBreaker",
    "FaultSchedule",
    "LinkFault",
    "RetryPolicy",
    "Straggler",
    "TierOutage",
    "ConnectionProfile",
    "make_profile",
    "DESResult",
    "SimTier",
    "SimulationResult",
    "make_poisson_stream",
    "make_stream",
    "simulate",
    "simulate_des",
    "table1_row",
]
