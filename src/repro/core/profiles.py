"""Connection profiles: time-varying round-trip-time traces (paper Fig. 4).

The paper replays two real RIPE-Atlas RTT traces (meas 1437285, probe 6222,
2018-05-03; CP1 = 3-7 pm, CP2 = 7:30-12:30 am) with a constant symmetric
100 Mbps bandwidth.  RIPE Atlas is not reachable offline, so this module
*generates* traces with the same qualitative structure the paper relies on:

* a slowly-wandering baseline (mean-reverting Ornstein-Uhlenbeck process —
  models congestion drift over hours),
* sporadic heavy-tailed spikes (lognormal bursts — models transient
  congestion / route flaps),
* CP1 has a higher mean and heavier spikes than CP2 (the paper notes CP1
  "is slower on average", making cloud offload sub-optimal more often).

Traces are deterministic given the seed, making experiments repeatable —
the property the paper obtained by replaying recorded traces.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass
class ConnectionProfile:
    """A replayable RTT trace + constant symmetric bandwidth.

    ``rtt_s``/``times_s`` sample the RTT (seconds) on a uniform grid;
    lookups interpolate.  ``bandwidth_bps`` is the paper's constant
    100 Mbps unless overridden.
    """

    name: str
    times_s: np.ndarray
    rtt_s: np.ndarray
    bandwidth_bps: float = 100e6

    def rtt_at(self, t) -> np.ndarray:
        """RTT seen by a request issued at simulation time ``t`` (seconds).

        Wraps around the trace end so arbitrarily long request streams can
        be replayed against a finite trace, as the paper does with its
        4-5 hour windows.
        """
        t = np.asarray(t, np.float64)
        period = float(self.times_s[-1])
        return np.interp(np.mod(t, period), self.times_s, self.rtt_s)

    def tx_time(self, t, payload_bytes) -> np.ndarray:
        """T_tx for a request at time t: RTT + serialization delay.

        The paper models T_tx as dominated by the RTT (token payloads are
        ~2 bytes/token, §II-B); we keep the exact bandwidth term anyway.
        """
        return self.rtt_at(t) + np.asarray(payload_bytes, np.float64) * 8.0 / self.bandwidth_bps

    @property
    def mean_rtt(self) -> float:
        return float(self.rtt_s.mean())


def _ou_trace(
    rng: np.random.Generator,
    *,
    duration_s: float,
    dt_s: float,
    mean: float,
    reversion: float,
    vol: float,
    spike_rate_hz: float,
    spike_scale: float,
    floor: float,
) -> np.ndarray:
    n = int(duration_s / dt_s) + 1
    x = np.empty(n)
    x[0] = mean
    sq = vol * np.sqrt(dt_s)
    noise = rng.standard_normal(n - 1)
    for i in range(1, n):
        x[i] = x[i - 1] + reversion * (mean - x[i - 1]) * dt_s + sq * noise[i - 1]
    # heavy-tailed congestion spikes with exponential decay (~30 s)
    n_spikes = rng.poisson(spike_rate_hz * duration_s)
    t_grid = np.arange(n) * dt_s
    for _ in range(n_spikes):
        t0 = rng.uniform(0, duration_s)
        amp = spike_scale * rng.lognormal(0.0, 0.75)
        tau = rng.uniform(10.0, 45.0)
        x += amp * np.exp(-np.maximum(t_grid - t0, 0.0) / tau) * (t_grid >= t0)
    return np.maximum(x, floor)


def make_profile(name: str, *, seed: int = 0, duration_s: float = 4 * 3600.0,
                 dt_s: float = 1.0, bandwidth_bps: float = 100e6) -> ConnectionProfile:
    """Build CP1/CP2 analogs of the paper's Fig. 4.

    CP1 (afternoon, 3-7 pm): congested — mean RTT ~90 ms, frequent heavy
    spikes to several hundred ms.
    CP2 (morning, 7:30-12:30 am): clean — mean RTT ~35 ms, rare mild spikes.
    """
    # crc32, not hash(): Python string hashing is salted per process, which
    # silently broke the "deterministic given the seed" contract across runs
    rng = np.random.default_rng(
        np.uint32(zlib.crc32(f"{name}:{seed}".encode()) % (2**32)))
    if name.lower() in ("cp1", "profile1"):
        rtt = _ou_trace(
            rng, duration_s=duration_s, dt_s=dt_s,
            mean=0.090, reversion=0.02, vol=0.004,
            spike_rate_hz=1.5 / 60.0, spike_scale=0.120, floor=0.015,
        )
    elif name.lower() in ("cp2", "profile2"):
        rtt = _ou_trace(
            rng, duration_s=duration_s, dt_s=dt_s,
            mean=0.035, reversion=0.05, vol=0.0015,
            spike_rate_hz=0.3 / 60.0, spike_scale=0.040, floor=0.008,
        )
    else:
        raise ValueError(f"unknown profile {name!r} (use 'cp1' or 'cp2')")
    times = np.arange(rtt.size) * dt_s
    return ConnectionProfile(name=name.lower(), times_s=times, rtt_s=rtt,
                             bandwidth_bps=bandwidth_bps)
