"""Online transmission-latency tracking (paper §II-C).

T_tx varies over time with connection quality.  The paper attaches
timestamps to every request/response exchanged with the cloud and keeps a
recent estimate; because single end-nodes translate sporadically, the edge
device is assumed to be a *gateway* aggregating many end-nodes, so samples
arrive almost continuously.

:class:`TxEstimator` implements that mechanism: it ingests timestamped RTT
observations (obtained for free from offloaded requests) and serves the
current estimate.  Two modes:

* ``ewma`` (default) — exponentially-weighted moving average, the usual
  network-RTT smoother; robust to single spikes.
* ``last``           — most recent sample (what a bare timestamp scheme
  gives you); kept as the paper-minimal variant.

A staleness guard (beyond paper): if no sample arrived for
``max_age_s``, the estimator injects a cheap synthetic probe sample —
modelling the gateway pinging the server — so decisions never rely on an
arbitrarily old estimate.  The simulator can disable probing to reproduce
the paper-faithful behaviour exactly.

Causal ordering: responses from concurrently offloaded requests can
return out of order (a short request issued later completes before a
long one issued earlier).  ``observe`` drops any sample timestamped
before the newest one already ingested (counted in ``n_stale``), so the
EWMA only ever moves forward in time and ``_last_update`` — which gates
the staleness probe — never runs backwards.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional


@dataclasses.dataclass
class TxEstimator:
    mode: str = "ewma"
    alpha: float = 0.3            # EWMA weight of the newest sample
    init_rtt_s: float = 0.050     # estimate before any sample arrives
    max_age_s: Optional[float] = None  # None = paper-faithful (no probing)
    bandwidth_bps: float = 100e6

    def __post_init__(self):
        if self.mode not in ("ewma", "last"):
            raise ValueError(f"unknown mode {self.mode!r}")
        self._estimate = self.init_rtt_s
        self._last_update: Optional[float] = None
        self.n_samples = 0
        self.n_probes = 0
        self.n_stale = 0
        self.n_invalidations = 0

    # -- ingestion ---------------------------------------------------------
    def observe(self, timestamp_s: float, rtt_s: float) -> None:
        """Record a timestamped RTT measurement from an offloaded request.

        Samples older than the newest already ingested are dropped (see
        module docstring): out-of-order completions must not rewind the
        estimator's notion of "now".
        """
        if rtt_s <= 0:
            raise ValueError("rtt must be positive")
        if self._last_update is not None and timestamp_s < self._last_update:
            self.n_stale += 1
            return
        if self.mode == "last" or self._last_update is None:
            self._estimate = rtt_s if self.mode == "last" else (
                rtt_s if self.n_samples == 0
                else (1 - self.alpha) * self._estimate + self.alpha * rtt_s
            )
        else:
            self._estimate = (1 - self.alpha) * self._estimate + self.alpha * rtt_s
        self._last_update = timestamp_s
        self.n_samples += 1

    def invalidate(self) -> None:
        """Forget accumulated link state after a known discontinuity
        (an outage episode ended, the route changed).

        The ``n_stale`` causal guard protects against out-of-ORDER
        samples; it cannot help when in-order *pre-outage* samples
        poison the estimate for the recovered link — an EWMA warmed on a
        congested route keeps predicting congestion long after failover
        ends.  Invalidation keeps the current estimate as the best
        available guess for queries, but resets the sample history so
        the FIRST post-recovery observation replaces it wholesale (the
        ``n_samples == 0`` bootstrap branch) instead of being blended at
        weight ``alpha``.  Callers: circuit-breaker recovery
        (OPEN→CLOSED) in the engine and the DES.
        """
        self._last_update = None
        self.n_samples = 0
        self.n_invalidations += 1

    # -- queries -----------------------------------------------------------
    def rtt(self, now_s: float, probe_fn=None) -> float:
        """Current RTT estimate; optionally refresh via probe when stale."""
        if (
            self.max_age_s is not None
            and probe_fn is not None
            and (self._last_update is None or now_s - self._last_update > self.max_age_s)
        ):
            self.observe(now_s, float(probe_fn(now_s)))
            self.n_probes += 1
        return self._estimate

    def tx_time(self, now_s: float, payload_bytes: float, probe_fn=None,
                *, one_way: bool = False) -> float:
        """T_tx estimate = RTT + payload serialization at the known bandwidth.

        ``one_way=True`` prices a single direction (``rtt/2`` + the same
        serialization term) — the cost of SHIPPING a payload to the
        other end without waiting for a response, which is what an
        inter-tier activation transfer pays (the decode leg continues on
        the receiving tier; nothing comes back over this link).
        """
        rtt = self.rtt(now_s, probe_fn)
        if one_way:
            rtt = rtt / 2.0
        return rtt + payload_bytes * 8.0 / self.bandwidth_bps


class LinkModel:
    """Pairwise tier-to-tier link matrix (ROADMAP 5d).

    The single gateway→cloud :class:`TxEstimator` of the paper covers
    exactly one hop.  Cross-tier model partitioning (encoder on tier i,
    decoder on tier j) needs the i→j leg priced too, and hierarchical
    topologies (device→edge→cloud) must pay *both* hops when no direct
    link exists.  ``LinkModel`` keeps one :class:`TxEstimator` per
    registered directed pair and composes multi-hop paths:

    * ``tx_time(i, j, ...)`` — 0.0 for ``i == j``; the direct link's
      estimate when registered; otherwise the cheapest relay path over
      registered links (each hop paying its own RTT + serialization);
      ``math.inf`` when no path exists (callers treat that plan as
      infeasible).
    * ``observe(i, j, now, rtt)`` — feed a timestamped RTT sample into
      the direct link's estimator (§II-C, per link).

    Estimators are per *direction*; ``add_link(..., symmetric=True)``
    (the default) registers the reverse direction with its own
    independent estimator so asymmetric routes can drift apart.
    """

    def __init__(self, n_tiers: int):
        if n_tiers < 1:
            raise ValueError("need at least one tier")
        self.n_tiers = n_tiers
        self._links: dict = {}

    def add_link(self, i: int, j: int, estimator: TxEstimator, *,
                 symmetric: bool = True) -> "LinkModel":
        if i == j:
            raise ValueError("a tier has no link to itself")
        for k in (i, j):
            if not (0 <= k < self.n_tiers):
                raise ValueError(f"tier index {k} out of range")
        self._links[(i, j)] = estimator
        if symmetric and (j, i) not in self._links:
            self._links[(j, i)] = dataclasses.replace(estimator)
        return self

    def link(self, i: int, j: int) -> Optional[TxEstimator]:
        return self._links.get((i, j))

    def has_path(self, i: int, j: int) -> bool:
        return math.isfinite(self.tx_time(i, j, 0.0, 0.0))

    def tx_time(self, i: int, j: int, now_s: float, payload_bytes: float,
                *, one_way: bool = False) -> float:
        """Predicted transfer time i→j; composes relay hops when no
        direct link is registered (device→edge→cloud pays both hops —
        each hop's RTT *and* a re-serialization of the payload)."""
        if i == j:
            return 0.0
        direct = self._links.get((i, j))
        if direct is not None:
            return direct.tx_time(now_s, payload_bytes, one_way=one_way)
        # Dijkstra over registered directed links (tiny K: fine)
        dist = {i: 0.0}
        frontier = [(0.0, i)]
        while frontier:
            d, u = heapq.heappop(frontier)
            if u == j:
                return d
            if d > dist.get(u, math.inf):
                continue
            for (a, b), est in self._links.items():
                if a != u:
                    continue
                nd = d + est.tx_time(now_s, payload_bytes, one_way=one_way)
                if nd < dist.get(b, math.inf):
                    dist[b] = nd
                    heapq.heappush(frontier, (nd, b))
        return math.inf

    def observe(self, i: int, j: int, now_s: float, rtt_s: float) -> None:
        est = self._links.get((i, j))
        if est is not None:
            est.observe(now_s, rtt_s)

    def invalidate(self, tier: int) -> int:
        """Invalidate every registered link touching ``tier`` (either
        direction) after its outage/recovery — see
        :meth:`TxEstimator.invalidate`.  Returns how many links reset."""
        n = 0
        for (a, b), est in self._links.items():
            if a == tier or b == tier:
                est.invalidate()
                n += 1
        return n
