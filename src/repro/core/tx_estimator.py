"""Online transmission-latency tracking (paper §II-C).

T_tx varies over time with connection quality.  The paper attaches
timestamps to every request/response exchanged with the cloud and keeps a
recent estimate; because single end-nodes translate sporadically, the edge
device is assumed to be a *gateway* aggregating many end-nodes, so samples
arrive almost continuously.

:class:`TxEstimator` implements that mechanism: it ingests timestamped RTT
observations (obtained for free from offloaded requests) and serves the
current estimate.  Two modes:

* ``ewma`` (default) — exponentially-weighted moving average, the usual
  network-RTT smoother; robust to single spikes.
* ``last``           — most recent sample (what a bare timestamp scheme
  gives you); kept as the paper-minimal variant.

A staleness guard (beyond paper): if no sample arrived for
``max_age_s``, the estimator injects a cheap synthetic probe sample —
modelling the gateway pinging the server — so decisions never rely on an
arbitrarily old estimate.  The simulator can disable probing to reproduce
the paper-faithful behaviour exactly.

Causal ordering: responses from concurrently offloaded requests can
return out of order (a short request issued later completes before a
long one issued earlier).  ``observe`` drops any sample timestamped
before the newest one already ingested (counted in ``n_stale``), so the
EWMA only ever moves forward in time and ``_last_update`` — which gates
the staleness probe — never runs backwards.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class TxEstimator:
    mode: str = "ewma"
    alpha: float = 0.3            # EWMA weight of the newest sample
    init_rtt_s: float = 0.050     # estimate before any sample arrives
    max_age_s: Optional[float] = None  # None = paper-faithful (no probing)
    bandwidth_bps: float = 100e6

    def __post_init__(self):
        if self.mode not in ("ewma", "last"):
            raise ValueError(f"unknown mode {self.mode!r}")
        self._estimate = self.init_rtt_s
        self._last_update: Optional[float] = None
        self.n_samples = 0
        self.n_probes = 0
        self.n_stale = 0

    # -- ingestion ---------------------------------------------------------
    def observe(self, timestamp_s: float, rtt_s: float) -> None:
        """Record a timestamped RTT measurement from an offloaded request.

        Samples older than the newest already ingested are dropped (see
        module docstring): out-of-order completions must not rewind the
        estimator's notion of "now".
        """
        if rtt_s <= 0:
            raise ValueError("rtt must be positive")
        if self._last_update is not None and timestamp_s < self._last_update:
            self.n_stale += 1
            return
        if self.mode == "last" or self._last_update is None:
            self._estimate = rtt_s if self.mode == "last" else (
                rtt_s if self.n_samples == 0
                else (1 - self.alpha) * self._estimate + self.alpha * rtt_s
            )
        else:
            self._estimate = (1 - self.alpha) * self._estimate + self.alpha * rtt_s
        self._last_update = timestamp_s
        self.n_samples += 1

    # -- queries -----------------------------------------------------------
    def rtt(self, now_s: float, probe_fn=None) -> float:
        """Current RTT estimate; optionally refresh via probe when stale."""
        if (
            self.max_age_s is not None
            and probe_fn is not None
            and (self._last_update is None or now_s - self._last_update > self.max_age_s)
        ):
            self.observe(now_s, float(probe_fn(now_s)))
            self.n_probes += 1
        return self._estimate

    def tx_time(self, now_s: float, payload_bytes: float, probe_fn=None) -> float:
        """T_tx estimate = RTT + payload serialization at the known bandwidth."""
        return self.rtt(now_s, probe_fn) + payload_bytes * 8.0 / self.bandwidth_bps
