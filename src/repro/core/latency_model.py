"""Per-device execution-time planes: T_exe,i(N, M) of paper Eq. (2).

The paper models inference latency of a seq2seq model on device *i* as a
plane over input length N and output length M:

    T_exe,i = alpha_N,i * N + alpha_M,i * M + beta_i

* RNN encoder/decoder: both slopes positive (strict step dependency).
* Transformer on a parallel device: alpha_N ~ 0 for short inputs (encoder
  parallelizes), alpha_M > 0 and dominant (autoregressive masked decode).

Coefficients come from a once-for-all offline characterization (paper
§II-B last para).  Two calibration paths are provided:

* measured   — fit on (N, M, T) samples from real runs
               (``repro.core.calibration`` produces them on this CPU);
* analytical — beyond paper: derive the plane from a roofline cost model
               (FLOPs/byte terms per token) so the scheduler can target
               hardware we cannot execute on (TPU pods); see
               :meth:`LinearLatencyModel.from_roofline`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LinearLatencyModel:
    """T(N, M) = alpha_n * N + alpha_m * M + beta   (seconds)."""

    alpha_n: float = 0.0
    alpha_m: float = 0.0
    beta: float = 0.0

    def fit(self, n, m, t) -> "LinearLatencyModel":
        """Least-squares fit on characterization samples (paper: 10k/device)."""
        n = jnp.asarray(n, jnp.float32)
        m = jnp.asarray(m, jnp.float32)
        t = jnp.asarray(t, jnp.float32)
        a = jnp.stack([n, m, jnp.ones_like(n)], axis=1)
        coef, *_ = jnp.linalg.lstsq(a, t)
        self.alpha_n = float(coef[0])
        self.alpha_m = float(coef[1])
        self.beta = float(coef[2])
        return self

    def predict(self, n, m):
        n = jnp.asarray(n, jnp.float32)
        m = jnp.asarray(m, jnp.float32)
        return self.alpha_n * n + self.alpha_m * m + self.beta

    def r2(self, n, m, t) -> float:
        t = jnp.asarray(t, jnp.float32)
        pred = self.predict(n, m)
        ss_res = jnp.sum((t - pred) ** 2)
        ss_tot = jnp.sum((t - jnp.mean(t)) ** 2)
        return float(1.0 - ss_res / jnp.maximum(ss_tot, 1e-12))

    def scaled(self, factor: float) -> "LinearLatencyModel":
        """A device `factor`x faster (e.g. cloud = edge / speedup)."""
        return LinearLatencyModel(
            self.alpha_n / factor, self.alpha_m / factor, self.beta / factor
        )

    @classmethod
    def from_roofline(
        cls,
        *,
        prefill_flops_per_token: float,
        decode_flops_per_token: float,
        decode_bytes_per_token: float,
        peak_flops: float,
        hbm_bw: float,
        overhead_s: float = 0.0,
        mfu: float = 0.4,
    ) -> "LinearLatencyModel":
        """Beyond paper: build the plane analytically from roofline terms.

        Per input token the encoder/prefill is compute-bound:
            alpha_n = prefill_flops_per_token / (mfu * peak_flops)
        Per output token the autoregressive decode step is
        max(compute, memory)-bound:
            alpha_m = max(decode_flops / (mfu*peak), decode_bytes / hbm_bw)

        This is how the tiered-serving engine prices TPU pods it cannot
        measure: the terms come from ``compiled.cost_analysis()`` of the
        dry-run (see launch/dryrun.py).
        """
        alpha_n = prefill_flops_per_token / (mfu * peak_flops)
        alpha_m = max(
            decode_flops_per_token / (mfu * peak_flops),
            decode_bytes_per_token / hbm_bw,
        )
        return cls(alpha_n=alpha_n, alpha_m=alpha_m, beta=overhead_s)


@dataclasses.dataclass
class DeviceProfile:
    """A compute tier the scheduler can map an inference onto.

    ``noise_frac`` models run-to-run latency variation (load, DVFS, ...):
    the *true* execution time drawn in the simulator is
    ``T * (1 + noise_frac * eps)`` with eps ~ N(0,1) truncated at +-3.
    The paper's Fig. 2a shows exactly such bands around the linear fit.
    """

    name: str
    model: LinearLatencyModel
    noise_frac: float = 0.05

    def true_time(self, n, m, rng: np.random.Generator) -> np.ndarray:
        base = np.asarray(self.model.predict(n, m))
        eps = np.clip(rng.standard_normal(base.shape), -3.0, 3.0)
        return np.maximum(base * (1.0 + self.noise_frac * eps), 1e-6)


def bytes_for_tokens(n_tokens, bytes_per_token: int = 2) -> np.ndarray:
    """Paper §II: dictionary-index encoding needs <= 2 bytes/token."""
    return np.asarray(n_tokens) * bytes_per_token
