"""Per-device execution-time planes: T_exe,i(N, M) of paper Eq. (2).

The paper models inference latency of a seq2seq model on device *i* as a
plane over input length N and output length M:

    T_exe,i = alpha_N,i * N + alpha_M,i * M + beta_i

* RNN encoder/decoder: both slopes positive (strict step dependency).
* Transformer on a parallel device: alpha_N ~ 0 for short inputs (encoder
  parallelizes), alpha_M > 0 and dominant (autoregressive masked decode).

Coefficients come from a once-for-all offline characterization (paper
§II-B last para).  Two calibration paths are provided:

* measured   — fit on (N, M, T) samples from real runs
               (``repro.core.calibration`` produces them on this CPU);
* analytical — beyond paper: derive the plane from a roofline cost model
               (FLOPs/byte terms per token) so the scheduler can target
               hardware we cannot execute on (TPU pods); see
               :meth:`LinearLatencyModel.from_roofline`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LinearLatencyModel:
    """T(N, M) = alpha_n * N + alpha_m * M + beta   (seconds)."""

    alpha_n: float = 0.0
    alpha_m: float = 0.0
    beta: float = 0.0

    def fit(self, n, m, t) -> "LinearLatencyModel":
        """Least-squares fit on characterization samples (paper: 10k/device)."""
        n = jnp.asarray(n, jnp.float32)
        m = jnp.asarray(m, jnp.float32)
        t = jnp.asarray(t, jnp.float32)
        a = jnp.stack([n, m, jnp.ones_like(n)], axis=1)
        coef, *_ = jnp.linalg.lstsq(a, t)
        self.alpha_n = float(coef[0])
        self.alpha_m = float(coef[1])
        self.beta = float(coef[2])
        return self

    def predict(self, n, m):
        n = jnp.asarray(n, jnp.float32)
        m = jnp.asarray(m, jnp.float32)
        return self.alpha_n * n + self.alpha_m * m + self.beta

    def predict_legs(self, n, m):
        """Split the plane into (encode, decode) leg predictions.

        The alpha_n·N term is encoder work, the alpha_m·M term is
        autoregressive decode work, and beta (framework/dispatch
        overhead) is paid once per leg when the legs run on different
        tiers — so each leg carries half of it.  By construction
        ``sum(predict_legs(n, m)) == predict(n, m)`` up to float
        association: a whole-request placement prices identically
        whether viewed as one plane or two legs on the same tier.
        """
        n = np.asarray(n, np.float64)
        m = np.asarray(m, np.float64)
        t_enc = self.alpha_n * n + 0.5 * self.beta
        t_dec = self.alpha_m * m + 0.5 * self.beta
        return t_enc, t_dec

    def r2(self, n, m, t) -> float:
        t = jnp.asarray(t, jnp.float32)
        pred = self.predict(n, m)
        ss_res = jnp.sum((t - pred) ** 2)
        ss_tot = jnp.sum((t - jnp.mean(t)) ** 2)
        return float(1.0 - ss_res / jnp.maximum(ss_tot, 1e-12))

    def scaled(self, factor: float) -> "LinearLatencyModel":
        """A device `factor`x faster (e.g. cloud = edge / speedup)."""
        return LinearLatencyModel(
            self.alpha_n / factor, self.alpha_m / factor, self.beta / factor
        )

    @classmethod
    def from_roofline(
        cls,
        *,
        prefill_flops_per_token: float,
        decode_flops_per_token: float,
        decode_bytes_per_token: float,
        peak_flops: float,
        hbm_bw: float,
        overhead_s: float = 0.0,
        mfu: float = 0.4,
    ) -> "LinearLatencyModel":
        """Beyond paper: build the plane analytically from roofline terms.

        Per input token the encoder/prefill is compute-bound:
            alpha_n = prefill_flops_per_token / (mfu * peak_flops)
        Per output token the autoregressive decode step is
        max(compute, memory)-bound:
            alpha_m = max(decode_flops / (mfu*peak), decode_bytes / hbm_bw)

        This is how the tiered-serving engine prices TPU pods it cannot
        measure: the terms come from ``compiled.cost_analysis()`` of the
        dry-run (see launch/dryrun.py).
        """
        alpha_n = prefill_flops_per_token / (mfu * peak_flops)
        alpha_m = max(
            decode_flops_per_token / (mfu * peak_flops),
            decode_bytes_per_token / hbm_bw,
        )
        return cls(alpha_n=alpha_n, alpha_m=alpha_m, beta=overhead_s)


@dataclasses.dataclass
class DeviceProfile:
    """A compute tier the scheduler can map an inference onto.

    ``noise_frac`` models run-to-run latency variation (load, DVFS, ...):
    the *true* execution time drawn in the simulator is
    ``T * (1 + noise_frac * eps)`` with eps ~ N(0,1) truncated at +-3.
    The paper's Fig. 2a shows exactly such bands around the linear fit.
    """

    name: str
    model: LinearLatencyModel
    noise_frac: float = 0.05

    def true_time(self, n, m, rng: np.random.Generator) -> np.ndarray:
        base = np.asarray(self.model.predict(n, m))
        eps = np.clip(rng.standard_normal(base.shape), -3.0, 3.0)
        return np.maximum(base * (1.0 + self.noise_frac * eps), 1e-6)

    def true_leg_times(self, n, m, rng: np.random.Generator):
        """Noisy (encode, decode) leg times for a split placement.

        Each leg draws its own truncated-normal perturbation — the two
        legs of a partitioned request run at different wall-clock times
        (often on different tiers), so their load/DVFS noise is
        independent, unlike :meth:`true_time`'s single draw.
        """
        enc, dec = self.model.predict_legs(n, m)
        enc = np.asarray(enc, np.float64)
        dec = np.asarray(dec, np.float64)
        eps_e = np.clip(rng.standard_normal(enc.shape), -3.0, 3.0)
        eps_d = np.clip(rng.standard_normal(dec.shape), -3.0, 3.0)
        return (np.maximum(enc * (1.0 + self.noise_frac * eps_e), 1e-6),
                np.maximum(dec * (1.0 + self.noise_frac * eps_d), 1e-6))


def bytes_for_tokens(n_tokens, bytes_per_token: int = 2) -> np.ndarray:
    """Paper §II: dictionary-index encoding needs <= 2 bytes/token."""
    return np.asarray(n_tokens) * bytes_per_token


@dataclasses.dataclass(frozen=True)
class ActivationCostModel:
    """Wire size of a model's encoder states for cross-tier shipping.

    Whole-request offload ships *tokens* (~2 bytes each, see
    :func:`bytes_for_tokens`); a split placement ships *activations* —
    the encoder's output states, ``n x d_model`` floats plus a small
    per-sequence overhead (source lengths, masks).  That is 3-4 orders
    of magnitude fatter per token, which is exactly why the scheduler
    must price it per model instead of reusing the token byte count.
    """

    d_model: int
    dtype_bytes: int = 4
    per_seq_overhead_bytes: int = 0

    def payload_bytes(self, n) -> np.ndarray:
        return (np.asarray(n, np.float64) * self.d_model * self.dtype_bytes
                + self.per_seq_overhead_bytes)
