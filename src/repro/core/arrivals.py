"""Arrival-process generators for the load-generation harness.

The paper evaluates C-NMT by replaying a recorded request stream; the
MLPerf-loadgen-shaped harness (``benchmarks/loadgen.py``) needs the
arrival *process* itself to be a first-class, swappable object.  This
module holds the generators shared by the harness, the DES
(:func:`repro.core.simulator.make_trace_stream`) and the tests:

* :func:`poisson_arrivals`    — open-loop Poisson (MLPerf "Server"):
  i.i.d. exponential inter-arrival gaps at a constant rate.
* :func:`bursty_arrivals`     — open-loop nonhomogeneous Poisson with a
  sinusoidal (diurnal-shaped) rate modulation, sampled by thinning:
  candidate arrivals are drawn at the peak rate and accepted with
  probability rate(t)/peak — the standard exact method for
  time-varying Poisson processes.
* :func:`save_trace` / :func:`load_trace` — JSON persistence for
  recorded or synthetic arrival traces, so a trace-replay run is
  reproducible bit-for-bit from a file (Python's ``json`` round-trips
  float64 exactly).

Closed-loop arrivals have no generator here by design: the next issue
time *is* the previous completion, so the harness derives them from the
engine's completion callback (``CollaborativeEngine.on_complete``) and
can record the realized times as a trace for the DES twin.

Every generator is deterministic given its ``seed`` (NumPy
``default_rng``; no global state), which the tests pin: same seed ⇒
bit-identical trace.  All times are in seconds from the start of the
run, strictly increasing.
"""

from __future__ import annotations

import json
import math
from typing import Optional

import numpy as np


def poisson_arrivals(rate_hz: float, size: int, *,
                     seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """Open-loop Poisson arrival times (seconds, strictly increasing).

    ``rate_hz`` is the mean arrival rate; gaps are i.i.d.
    ``Exponential(1/rate_hz)`` starting from ``t0``.  Deterministic
    given ``seed``.
    """
    if rate_hz <= 0:
        raise ValueError("rate_hz must be positive")
    if size < 0:
        raise ValueError("size must be >= 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=size)
    return t0 + np.cumsum(gaps)


def diurnal_rate(t, base_rate_hz: float, peak_factor: float,
                 period_s: float) -> np.ndarray:
    """Instantaneous rate of the bursty process at time ``t`` (seconds).

    A raised-cosine modulation between ``base_rate_hz`` (trough, at
    t = 0 mod period) and ``base_rate_hz * peak_factor`` (peak, at
    t = period/2 mod period) — one "day" per ``period_s``.  Exposed so
    tests can check the thinning sampler actually tracks it.
    """
    t = np.asarray(t, np.float64)
    shape = 0.5 * (1.0 - np.cos(2.0 * math.pi * t / period_s))
    return base_rate_hz * (1.0 + (peak_factor - 1.0) * shape)


def bursty_arrivals(size: int, *, base_rate_hz: float,
                    peak_factor: float = 4.0, period_s: float = 60.0,
                    seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """Bursty/diurnal arrivals: nonhomogeneous Poisson via thinning.

    Candidates are drawn as a homogeneous Poisson process at the peak
    rate ``base_rate_hz * peak_factor`` and accepted with probability
    ``diurnal_rate(t)/peak`` — exact sampling of the modulated process.
    Returns the first ``size`` accepted arrival times (seconds,
    strictly increasing).  Deterministic given ``seed``.
    """
    if base_rate_hz <= 0:
        raise ValueError("base_rate_hz must be positive")
    if peak_factor < 1.0:
        raise ValueError("peak_factor must be >= 1 (1 = plain Poisson)")
    if period_s <= 0:
        raise ValueError("period_s must be positive")
    rng = np.random.default_rng(seed)
    lam_max = base_rate_hz * peak_factor
    out = np.empty(size, np.float64)
    got = 0
    t = t0
    while got < size:
        # draw candidate gaps in blocks; thinning keeps the accepted ones
        block = max(size - got, 64)
        gaps = rng.exponential(1.0 / lam_max, size=block)
        u = rng.random(block)
        for g, ui in zip(gaps, u):
            t += g
            if ui * lam_max < diurnal_rate(t - t0, base_rate_hz,
                                           peak_factor, period_s):
                out[got] = t
                got += 1
                if got == size:
                    break
    return out


# ------------------------------------------------------------- trace I/O --
_TRACE_VERSION = 1


def save_trace(path, arrival_s, *, meta: Optional[dict] = None) -> None:
    """Persist an arrival trace as JSON (``{"version", "arrival_s",
    "meta"}``).  Float64 values round-trip exactly through ``json``, so
    ``load_trace(save_trace(...))`` is bit-identical — the trace-replay
    exactness contract the tests pin."""
    arr = np.asarray(arrival_s, np.float64)
    if arr.ndim != 1:
        raise ValueError("arrival_s must be 1-D")
    if arr.size and np.any(np.diff(arr) < 0):
        raise ValueError("arrival times must be non-decreasing")
    payload = {"version": _TRACE_VERSION,
               "arrival_s": arr.tolist(),
               "meta": meta or {}}
    with open(path, "w") as f:
        json.dump(payload, f)


def load_trace(path) -> np.ndarray:
    """Load a trace written by :func:`save_trace`; returns the float64
    arrival times exactly as saved."""
    with open(path) as f:
        payload = json.load(f)
    if not isinstance(payload, dict) or "arrival_s" not in payload:
        raise ValueError(f"{path}: not an arrival trace file")
    arr = np.asarray(payload["arrival_s"], np.float64)
    if arr.size and np.any(np.diff(arr) < 0):
        raise ValueError(f"{path}: arrival times must be non-decreasing")
    return arr
