"""The C-NMT mapping decision — paper Eq. (1) and Eq. (2).

Per request with input length N, choose the execution tier:

    d_tgt = edge   if  T_exe,e(N, M_hat) <= T_tx + T_exe,c(N, M_hat)
            cloud  otherwise

with M_hat = gamma*N + delta from the length regressor.  The schedulers
here are *policies* over (request, online state); the actual experiment
loop lives in ``repro.core.simulator`` and the production serving path in
``repro.runtime.engine``.

Implemented policies
--------------------
* :class:`CNMTScheduler`   — the paper's technique (Eq. 1 + 2).
* :class:`NaiveScheduler`  — same rule but M_hat = corpus mean (paper §III).
* :class:`OracleScheduler` — lower bound: sees the *true* per-request times.
* :class:`StaticScheduler` — pure-edge ("GW") / pure-cloud ("Server").

Beyond paper
------------
* ``hedge_margin``: when the predicted edge/cloud gap is within ±margin of
  the break-even point, prefer the tier with lower variance (the edge —
  no network) — a cheap uncertainty-aware refinement of Eq. (1).
* batched vectorized ``decide_batch`` used by the analytic simulator.
* :class:`MultiTierScheduler` — the N-tier generalization used by the
  queue-aware serving engine and the discrete-event simulator:

      d_tgt = argmin_k [ T_queue,k + T_tx,k + T_exe,k(N, M_hat) ]

  Each :class:`SchedTier` carries its own latency plane and (for remote
  tiers) its own :class:`TxEstimator`; ``T_queue`` comes from the
  caller's occupancy bookkeeping, made batch-aware by
  :meth:`MultiTierScheduler.queue_delay` when a tier serves requests in
  length-bucketed batches (predicted backlog ÷ effective service rate).
  With exactly two tiers (local edge + remote cloud), empty queues and
  ``batch_size=1`` this reduces *bit-for-bit* to
  :meth:`CNMTScheduler.decide` — the paper's Eq. (1) is the N=2 special
  case, and the regression tests pin that equivalence.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.latency_model import (
    ActivationCostModel,
    DeviceProfile,
    LinearLatencyModel,
    bytes_for_tokens,
)
from repro.core.length_regressor import LinearN2M, MeanN2M
from repro.core.tx_estimator import LinkModel, TxEstimator

EDGE = 0
CLOUD = 1


@dataclasses.dataclass
class Decision:
    """One two-tier routing decision (paper Eq. (1)).

    ``t_edge_pred``/``t_cloud_pred`` are the scheduler's *predicted*
    totals in seconds (estimator outputs — plane at (N, M̂) plus, for
    the cloud, the estimated T_tx), not measured ground truth; ``m_hat``
    is the N→M regressor's predicted output length in tokens.
    """

    device: int           # EDGE or CLOUD
    t_edge_pred: float    # seconds, predicted
    t_cloud_pred: float   # seconds, predicted (includes predicted T_tx)
    m_hat: float          # tokens, predicted output length


class BaseScheduler:
    name = "base"

    def decide(self, n: int, now_s: float, tx: TxEstimator) -> Decision:
        """Route one request of ``n`` input tokens arriving at ``now_s``
        seconds, reading the link only through ``tx`` (the §II-C
        estimator state)."""
        raise NotImplementedError


@dataclasses.dataclass
class CNMTScheduler(BaseScheduler):
    """Paper Eq. (1): compare edge plane vs cloud plane + T_tx at (N, M_hat)."""

    edge: DeviceProfile
    cloud: DeviceProfile
    n2m: LinearN2M
    bytes_per_token: int = 2
    hedge_margin_s: float = 0.0   # 0 => paper-faithful
    name: str = "c-nmt"

    def decide(self, n: int, now_s: float, tx: TxEstimator) -> Decision:
        """Paper Eq. (1) for one request: edge plane vs cloud plane +
        estimated T_tx at (N, M̂), all in seconds.  This exact float op
        order is the compatibility contract the N=2
        :class:`MultiTierScheduler` reduction is pinned against
        bit-for-bit (tests/test_multitier.py)."""
        m_hat = float(np.asarray(self.n2m.predict(float(n))))
        m_hat = max(m_hat, 1.0)
        t_e = float(np.asarray(self.edge.model.predict(float(n), m_hat)))
        payload = float(bytes_for_tokens(n + m_hat, self.bytes_per_token))
        t_c = float(np.asarray(self.cloud.model.predict(float(n), m_hat)))
        t_c_tot = t_c + tx.tx_time(now_s, payload)
        gap = t_c_tot - t_e  # >0 => edge wins
        if abs(gap) <= self.hedge_margin_s:
            device = EDGE  # hedge: local execution has no network variance
        else:
            device = EDGE if t_e <= t_c_tot else CLOUD
        return Decision(device, t_e, t_c_tot, m_hat)

    def decide_batch(self, n: np.ndarray, rtt: np.ndarray,
                     bandwidth_bps: float = 100e6) -> np.ndarray:
        """Vectorized Eq. (1) for the analytic simulator.

        ``rtt`` is the scheduler's RTT estimate per request; the payload
        serialization term is added here at ``bandwidth_bps``.  Both are
        link properties, so they travel together as arguments (the
        stateful paths read them from the TxEstimator instead — pass the
        link's configured bandwidth, e.g. ``profile.bandwidth_bps``, to
        stay consistent with them; the default is the paper's 100 Mbps).
        Returns an int array of EDGE/CLOUD.
        """
        n = np.asarray(n, np.float64)
        m_hat = np.maximum(np.asarray(self.n2m.predict(n), np.float64), 1.0)
        t_e = np.asarray(self.edge.model.predict(n, m_hat), np.float64)
        payload = bytes_for_tokens(n + m_hat, self.bytes_per_token)
        t_tx = np.asarray(rtt, np.float64) + payload * 8.0 / bandwidth_bps
        t_c = np.asarray(self.cloud.model.predict(n, m_hat), np.float64) + t_tx
        gap = t_c - t_e
        dev = np.where(t_e <= t_c, EDGE, CLOUD)
        if self.hedge_margin_s > 0:
            dev = np.where(np.abs(gap) <= self.hedge_margin_s, EDGE, dev)
        return dev.astype(np.int32)


def NaiveScheduler(edge: DeviceProfile, cloud: DeviceProfile, n_corpus, m_corpus,
                   **kw) -> CNMTScheduler:
    """Paper §III 'Naive': identical mapping rule, M_hat = corpus average."""
    s = CNMTScheduler(edge=edge, cloud=cloud,
                      n2m=MeanN2M().fit(n_corpus, m_corpus), **kw)
    s.name = "naive"
    return s


@dataclasses.dataclass
class SchedTier:
    """What the scheduler *believes* about one tier.

    ``model`` is the T_exe,k(N, M) plane (measured, roofline-priced, or
    online-refit); ``tx`` is the tier's link estimator — ``None`` marks a
    local tier (no network hop, no T_tx term, lowest variance).

    ``batch_size``/``per_seq_overhead_s`` describe the tier's believed
    batched-service behaviour: each server drains up to ``batch_size``
    queued requests per decode pass, a batch of b similar requests taking

        T_batch = T_exe(max N, max M_hat) + per_seq_overhead_s * (b - 1)

    (sub-linear in b; ``per_seq_overhead_s`` is calibratable from batched
    timing grids, see ``repro.core.calibration.fit_batch_overhead``).
    These feed the batch-aware T_queue term in
    :meth:`MultiTierScheduler.queue_delay`; ``batch_size=1`` reduces to
    the unbatched PR-1 behaviour exactly.
    """

    name: str
    model: LinearLatencyModel
    tx: Optional[TxEstimator] = None
    batch_size: int = 1
    per_seq_overhead_s: float = 0.0

    @property
    def is_local(self) -> bool:
        return self.tx is None


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Where each leg of a request runs — the generalized decision space.

    The paper's Eq. (1) picks *one* tier per request; the plan
    abstraction grows that to "which cut point": ``whole(k)`` runs both
    legs on tier k (the paper's case), ``split(e, d)`` runs the encoder
    on tier e, ships the encoder states over the e→d link, and decodes
    on tier d.  ``split(k, k)`` *is* ``whole(k)`` — same frozen
    dataclass value, zero transfer cost — so the whole-request rule is
    literally the diagonal of the plan space.
    """

    encode_tier: int
    decode_tier: int

    @classmethod
    def whole(cls, tier: int) -> "PlacementPlan":
        return cls(tier, tier)

    @classmethod
    def split(cls, encode_tier: int, decode_tier: int) -> "PlacementPlan":
        return cls(encode_tier, decode_tier)

    @property
    def is_split(self) -> bool:
        return self.encode_tier != self.decode_tier


@dataclasses.dataclass
class MultiTierDecision:
    """One N-tier routing decision.

    ``t_pred`` holds the scheduler's per-tier predicted totals in
    seconds (T_queue + T_tx + T_exe at (N, M̂) — estimator outputs, with
    excluded tiers priced at ``inf``); admission/reroute logic ranks on
    it downstream.  ``m_hat`` is the predicted output length in tokens.
    """

    tier: int                  # index into the scheduler's tier list
    t_pred: Tuple[float, ...]  # per-tier predicted T_queue + T_tx + T_exe (s)
    m_hat: float               # tokens, predicted output length
    # Plan-aware extensions (None on the scalar decide paths): the chosen
    # placement, and the predicted total per evaluated plan.  ``tier``
    # stays the *decode* tier of the plan so existing per-tier admission
    # and reroute logic keeps working unchanged.
    plan: Optional[PlacementPlan] = None
    plan_t_pred: Optional[Dict[PlacementPlan, float]] = None


class MultiTierScheduler(BaseScheduler):
    """N-tier generalization of Eq. (1):

        d_tgt = argmin_k [ T_queue,k + T_tx,k + T_exe,k(N, M_hat) ]

    ``hedge_margin_s`` generalizes the 2-tier hedge: among tiers whose
    predicted total is within the margin of the minimum, prefer the
    fastest *local* tier (no network variance).  With tiers
    ``[edge(local), cloud(remote)]`` and zero queue delays this picks the
    same device as :meth:`CNMTScheduler.decide` bit-for-bit (same jnp
    prediction path, same float op order).
    """

    def __init__(self, tiers: Sequence[SchedTier], n2m: LinearN2M, *,
                 bytes_per_token: int = 2, hedge_margin_s: float = 0.0,
                 links: Optional[LinkModel] = None,
                 activation: Optional[ActivationCostModel] = None,
                 allow_split: bool = False,
                 explore_eps: float = 0.0, explore_seed: int = 0,
                 name: str = "c-nmt-ntier"):
        if not tiers:
            raise ValueError("need at least one tier")
        self.tiers = list(tiers)
        self.n2m = n2m
        self.bytes_per_token = bytes_per_token
        self.hedge_margin_s = hedge_margin_s
        self.links = links
        self.activation = activation
        self.allow_split = allow_split
        self.explore_eps = explore_eps
        self._explore_rng = np.random.default_rng(explore_seed)
        self._since_pick = [0] * len(self.tiers)
        self.n_explored = 0
        self.name = name

    # ------------------------------------------------------------ helpers --
    def _split_ready(self) -> bool:
        """Split plans need a link matrix to price the inter-tier hop and
        an activation model to price the encoder-state payload."""
        return (self.allow_split and self.links is not None
                and self.activation is not None)

    def _explore_override(self, chosen: int,
                          exclude: Optional[frozenset] = None) -> int:
        """ε-greedy cold-start probing of starved tiers (ROADMAP 5a).

        A tier whose believed plane is too slow never wins the argmin,
        so `OnlineCalibrator` never sees samples from it and can never
        correct the belief — a self-sealing mis-calibration.  With
        probability ``explore_eps`` route the request to the tier that
        has gone longest without traffic instead of the argmin winner.
        With ``explore_eps == 0`` (the default) this returns immediately
        without touching the RNG or any counter, so all existing
        bit-for-bit decision pins are unaffected.
        """
        if self.explore_eps <= 0.0 or len(self.tiers) < 2:
            return chosen
        for i in range(len(self._since_pick)):
            self._since_pick[i] += 1
        if self._explore_rng.random() < self.explore_eps:
            # never probe an excluded (unhealthy) tier — exploration is
            # for mis-calibration recovery, not for hammering dead tiers
            cands = [i for i in range(len(self._since_pick))
                     if not exclude or i not in exclude]
            starved = max(cands, key=self._since_pick.__getitem__)
            if starved != chosen:
                self.n_explored += 1
                chosen = starved
        self._since_pick[chosen] = 0
        return chosen

    def _select(self, totals: Sequence[float]) -> int:
        """argmin with the local-preference hedge (see class docstring)."""
        best = 0
        for k in range(1, len(totals)):
            if totals[k] < totals[best]:
                best = k
        best_local = None
        for k in range(len(totals)):
            if self.tiers[k].is_local and (
                    best_local is None or totals[k] < totals[best_local]):
                best_local = k
        if best_local is not None and (
                totals[best_local] <= totals[best] + self.hedge_margin_s):
            return best_local
        return best

    def m_hat(self, n: float) -> float:
        """Predicted output length in tokens for ``n`` input tokens
        (N→M regressor, floored at 1 so plane predictions stay
        positive) — the estimator every T_exe term is priced at."""
        return max(float(np.asarray(self.n2m.predict(float(n)))), 1.0)

    def queue_delay(self, k: int, backlog_s: float, in_system: int,
                    servers: int) -> float:
        """Batch-aware T_queue,k: predicted backlog ÷ effective service rate.

        ``backlog_s`` is the sum of predicted per-sequence T_exe for the
        ``in_system`` requests queued or running at tier k, ``servers``
        its concurrency.  An unbatched tier drains one sequence per
        server at a time, so T_queue = backlog / servers (PR-1 semantics,
        bit-for-bit).  A tier with batch size b amortizes a decode pass
        over up to b sequences: a batch costs roughly one mean sequence
        time T1 plus ``per_seq_overhead_s`` per extra member, so the
        effective work-drain speedup is  b·T1 / (T1 + o·(b−1))  and

            T_queue = backlog / (servers * speedup).
        """
        backlog = float(backlog_s)
        tier = self.tiers[k]
        b = tier.batch_size
        if b <= 1 or in_system <= 0 or backlog <= 0.0:
            return backlog / servers
        t1 = backlog / in_system
        t_batch = t1 + tier.per_seq_overhead_s * (b - 1)
        if t_batch <= 0.0:
            return 0.0
        speedup = b * t1 / t_batch
        return backlog / (servers * speedup)

    @staticmethod
    def _mask_totals(totals: List[float],
                     exclude: Optional[frozenset]) -> List[float]:
        """Candidate mask for fault-tolerant routing: excluded tiers
        (open circuit breakers, tiers that already failed this request)
        price at infinity so the argmin — and every downstream
        feasibility check ranked on ``t_pred`` — skips them.  ``exclude``
        falsy returns ``totals`` untouched (the bit-for-bit default)."""
        if not exclude:
            return totals
        return [math.inf if k in exclude else t
                for k, t in enumerate(totals)]

    # ----------------------------------------------------------- decisions --
    def decide(self, n: int, now_s: float,
               queue_delay_s: Optional[Sequence[float]] = None,
               *, exclude: Optional[frozenset] = None
               ) -> MultiTierDecision:
        """Single-request rule; ``queue_delay_s`` is the caller's per-tier
        T_queue estimate (0.0 for every tier when omitted).  ``exclude``
        removes unhealthy tiers from the candidate set (their predicted
        totals become ``inf``); the caller guarantees at least one tier
        stays eligible."""
        m_hat = self.m_hat(n)
        payload = float(bytes_for_tokens(n + m_hat, self.bytes_per_token))
        totals: List[float] = []
        for k, tier in enumerate(self.tiers):
            t_exe = float(np.asarray(tier.model.predict(float(n), m_hat)))
            t_tx = 0.0 if tier.tx is None else tier.tx.tx_time(now_s, payload)
            q = 0.0 if queue_delay_s is None else float(queue_delay_s[k])
            totals.append(t_exe + t_tx + q)
        totals = self._mask_totals(totals, exclude)
        pick = self._explore_override(self._select(totals), exclude)
        return MultiTierDecision(pick, tuple(totals), m_hat)

    def decide_fast(self, n: float, m_hat: float, now_s: float,
                    queue_delay_s: Optional[Sequence[float]] = None,
                    *, exclude: Optional[frozenset] = None
                    ) -> MultiTierDecision:
        """float64 closed-form fast path (no jnp dispatch) for the
        discrete-event simulator — the same coefficient arithmetic as
        ``simulator._simulate_online``, so the empty-queue DES replay
        matches the analytic replay exactly."""
        totals = self._mask_totals(
            self._whole_totals_fast(n, m_hat, now_s, queue_delay_s), exclude)
        pick = self._explore_override(self._select(totals), exclude)
        return MultiTierDecision(pick, tuple(totals), m_hat)

    def _whole_totals_fast(self, n: float, m_hat: float, now_s: float,
                           queue_delay_s: Optional[Sequence[float]]
                           ) -> List[float]:
        """Per-tier whole-request totals, closed-form float64 — the exact
        arithmetic `decide_fast` has always used (op order pinned by the
        DES-vs-analytic equivalence tests)."""
        payload = (n + m_hat) * self.bytes_per_token
        totals: List[float] = []
        for k, tier in enumerate(self.tiers):
            m = tier.model
            t_exe = m.alpha_n * n + m.alpha_m * m_hat + m.beta
            t_tx = 0.0 if tier.tx is None else tier.tx.tx_time(now_s, payload)
            q = 0.0 if queue_delay_s is None else float(queue_delay_s[k])
            totals.append(t_exe + t_tx + q)
        return totals

    # -------------------------------------------------- placement plans --
    def plan_cost_fast(self, plan: PlacementPlan, n: float, m_hat: float,
                       now_s: float,
                       queue_delay_s: Optional[Sequence[float]] = None
                       ) -> float:
        """Predicted total latency of one placement plan (closed form).

        ``whole(k)`` (and therefore ``split(k, k)``) reproduces the
        `decide_fast` per-tier total bit-for-bit: same plane arithmetic,
        same token payload, same full-RTT tx term — the plan space's
        diagonal IS the paper's rule.  A genuine split pays:

            T_queue,e + up + T_enc,e + ship(e→d) + T_queue,d + T_dec,d + down

        where `up` ships N source tokens one-way over tier e's client
        link, `ship` moves the encoder states (n × d_model × dtype
        bytes) one-way over the e→d link (``math.inf`` when no path is
        registered, making the plan infeasible), and `down` returns
        M_hat output tokens one-way over tier d's client link.
        """
        if not plan.is_split:
            k = plan.decode_tier
            tier = self.tiers[k]
            m = tier.model
            t_exe = m.alpha_n * n + m.alpha_m * m_hat + m.beta
            payload = (n + m_hat) * self.bytes_per_token
            t_tx = 0.0 if tier.tx is None else tier.tx.tx_time(now_s, payload)
            q = 0.0 if queue_delay_s is None else float(queue_delay_s[k])
            return t_exe + t_tx + q
        e, d = plan.encode_tier, plan.decode_tier
        enc_tier, dec_tier = self.tiers[e], self.tiers[d]
        t_enc = enc_tier.model.alpha_n * n + 0.5 * enc_tier.model.beta
        t_dec = dec_tier.model.alpha_m * m_hat + 0.5 * dec_tier.model.beta
        up = 0.0 if enc_tier.tx is None else enc_tier.tx.tx_time(
            now_s, n * self.bytes_per_token, one_way=True)
        down = 0.0 if dec_tier.tx is None else dec_tier.tx.tx_time(
            now_s, m_hat * self.bytes_per_token, one_way=True)
        ship = self.links.tx_time(
            e, d, now_s, float(self.activation.payload_bytes(n)),
            one_way=True)
        q_e = 0.0 if queue_delay_s is None else float(queue_delay_s[e])
        q_d = 0.0 if queue_delay_s is None else float(queue_delay_s[d])
        return q_e + up + t_enc + ship + q_d + t_dec + down

    def _plan_decision(self, n: float, m_hat: float, now_s: float,
                       queue_delay_s: Optional[Sequence[float]],
                       totals: List[float],
                       exclude: Optional[frozenset] = None
                       ) -> MultiTierDecision:
        """Shared tail of the plan-aware decide paths: run the whole-
        request selection (hedge + exploration, unchanged), then let a
        split plan take over only when strictly cheaper.  Split plans
        touching an ``exclude``d tier are never considered — a leg on an
        unhealthy tier is a guaranteed failover."""
        k0 = self._select(totals)
        k = self._explore_override(k0, exclude)
        whole = PlacementPlan.whole(k)
        if not self._split_ready() or k != k0:
            # splits off, or exploration forced a tier: whole-request plan
            return MultiTierDecision(k, tuple(totals), m_hat, plan=whole)
        n_tiers = len(self.tiers)
        plan_costs: Dict[PlacementPlan, float] = {
            PlacementPlan.whole(j): totals[j] for j in range(n_tiers)}
        best_plan, best_cost = whole, totals[k]
        for e in range(n_tiers):
            for d in range(n_tiers):
                if e == d or (exclude and (e in exclude or d in exclude)):
                    continue
                p = PlacementPlan.split(e, d)
                c = self.plan_cost_fast(p, n, m_hat, now_s, queue_delay_s)
                plan_costs[p] = c
                if c < best_cost:      # strict: ties keep the whole plan
                    best_plan, best_cost = p, c
        return MultiTierDecision(best_plan.decode_tier, tuple(totals), m_hat,
                                 plan=best_plan, plan_t_pred=plan_costs)

    def decide_plan(self, n: int, now_s: float,
                    queue_delay_s: Optional[Sequence[float]] = None,
                    *, exclude: Optional[frozenset] = None
                    ) -> MultiTierDecision:
        """Plan-aware single-request rule (jnp prediction path).

        Whole-request totals use the exact `decide` arithmetic, so with
        splits disabled this is `decide` bit-for-bit (plus the chosen
        ``plan`` attached).  ``tier`` is always the plan's decode tier —
        per-tier admission/reroute logic downstream is unchanged.
        """
        m_hat = self.m_hat(n)
        payload = float(bytes_for_tokens(n + m_hat, self.bytes_per_token))
        totals: List[float] = []
        for k, tier in enumerate(self.tiers):
            t_exe = float(np.asarray(tier.model.predict(float(n), m_hat)))
            t_tx = 0.0 if tier.tx is None else tier.tx.tx_time(now_s, payload)
            q = 0.0 if queue_delay_s is None else float(queue_delay_s[k])
            totals.append(t_exe + t_tx + q)
        totals = self._mask_totals(totals, exclude)
        return self._plan_decision(float(n), m_hat, now_s, queue_delay_s,
                                   totals, exclude)

    def decide_plan_fast(self, n: float, m_hat: float, now_s: float,
                         queue_delay_s: Optional[Sequence[float]] = None,
                         *, exclude: Optional[frozenset] = None
                         ) -> MultiTierDecision:
        """Plan-aware closed-form rule for the DES: `decide_fast`
        bit-for-bit when splits are disabled."""
        totals = self._mask_totals(
            self._whole_totals_fast(n, m_hat, now_s, queue_delay_s), exclude)
        return self._plan_decision(n, m_hat, now_s, queue_delay_s, totals,
                                   exclude)

    def decide_batch(self, n: np.ndarray, rtt: np.ndarray) -> np.ndarray:
        """Vectorized empty-queue rule (analytic-simulator counterpart of
        :meth:`CNMTScheduler.decide_batch`): ``rtt`` is the per-request
        RTT estimate applied to every remote tier's link."""
        n = np.asarray(n, np.float64)
        m_hat = np.maximum(np.asarray(self.n2m.predict(n), np.float64), 1.0)
        payload = bytes_for_tokens(n + m_hat, self.bytes_per_token)
        totals = []
        for tier in self.tiers:
            t = np.asarray(tier.model.predict(n, m_hat), np.float64)
            if tier.tx is not None:
                t = t + (np.asarray(rtt, np.float64)
                         + payload * 8.0 / tier.tx.bandwidth_bps)
            totals.append(t)
        stack = np.stack(totals, axis=0)              # (K, R)
        tmin = stack.min(axis=0)
        pick = stack.argmin(axis=0)
        local_idx = [k for k, t in enumerate(self.tiers) if t.is_local]
        if local_idx:
            loc = stack[local_idx]                    # (L, R)
            lbest = loc.argmin(axis=0)
            use_local = loc.min(axis=0) <= tmin + self.hedge_margin_s
            pick = np.where(use_local, np.asarray(local_idx)[lbest], pick)
        return pick.astype(np.int32)

    # ------------------------------------------------------------ feedback --
    def observe_rtt(self, tier: int, now_s: float, rtt_s: float) -> None:
        """Feed a timestamped RTT sample from an offloaded completion into
        the tier's link estimator (§II-C, per link)."""
        tx = self.tiers[tier].tx
        if tx is not None:
            tx.observe(now_s, rtt_s)

    @classmethod
    def from_pair(cls, edge: DeviceProfile, cloud: DeviceProfile,
                  n2m: LinearN2M, tx: TxEstimator, *,
                  bytes_per_token: int = 2, hedge_margin_s: float = 0.0
                  ) -> "MultiTierScheduler":
        """The paper-faithful N=2 configuration: local edge + remote cloud
        sharing the caller's TxEstimator (regression-tested against
        :class:`CNMTScheduler`)."""
        return cls(
            [SchedTier(edge.name, edge.model, None),
             SchedTier(cloud.name, cloud.model, tx)],
            n2m, bytes_per_token=bytes_per_token,
            hedge_margin_s=hedge_margin_s)


@dataclasses.dataclass
class OracleScheduler(BaseScheduler):
    """Ideal lower bound (paper §III): picks the truly fastest device.

    Sees true execution times and the true T_tx of each request — immune to
    regression error, plane mis-fit and stale RTT estimates.
    """

    name: str = "oracle"

    def decide_batch(self, t_edge_true: np.ndarray, t_cloud_true_with_tx: np.ndarray) -> np.ndarray:
        return np.where(t_edge_true <= t_cloud_true_with_tx, EDGE, CLOUD).astype(np.int32)

    @staticmethod
    def decide_batch_multi(t_true_totals: np.ndarray) -> np.ndarray:
        """N-tier oracle: ``t_true_totals`` is (K, R) true per-tier latency
        (execution + tx) per request; picks the per-request argmin."""
        return np.argmin(np.asarray(t_true_totals), axis=0).astype(np.int32)


@dataclasses.dataclass
class StaticScheduler(BaseScheduler):
    """Pure-edge (GW) or pure-cloud (Server) baselines of paper Table I."""

    device: int = EDGE

    @property
    def name(self) -> str:
        return "gw" if self.device == EDGE else "server"

    def decide_batch(self, n: np.ndarray, rtt: np.ndarray) -> np.ndarray:
        return np.full(np.shape(n), self.device, dtype=np.int32)
