"""The C-NMT mapping decision — paper Eq. (1) and Eq. (2).

Per request with input length N, choose the execution tier:

    d_tgt = edge   if  T_exe,e(N, M_hat) <= T_tx + T_exe,c(N, M_hat)
            cloud  otherwise

with M_hat = gamma*N + delta from the length regressor.  The schedulers
here are *policies* over (request, online state); the actual experiment
loop lives in ``repro.core.simulator`` and the production serving path in
``repro.runtime.engine``.

Implemented policies
--------------------
* :class:`CNMTScheduler`   — the paper's technique (Eq. 1 + 2).
* :class:`NaiveScheduler`  — same rule but M_hat = corpus mean (paper §III).
* :class:`OracleScheduler` — lower bound: sees the *true* per-request times.
* :class:`StaticScheduler` — pure-edge ("GW") / pure-cloud ("Server").

Beyond paper
------------
* ``hedge_margin``: when the predicted edge/cloud gap is within ±margin of
  the break-even point, prefer the tier with lower variance (the edge —
  no network) — a cheap uncertainty-aware refinement of Eq. (1).
* batched vectorized ``decide_batch`` used by the analytic simulator.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.latency_model import DeviceProfile, bytes_for_tokens
from repro.core.length_regressor import LinearN2M, MeanN2M
from repro.core.tx_estimator import TxEstimator

EDGE = 0
CLOUD = 1


@dataclasses.dataclass
class Decision:
    device: int           # EDGE or CLOUD
    t_edge_pred: float
    t_cloud_pred: float   # includes predicted T_tx
    m_hat: float


class BaseScheduler:
    name = "base"

    def decide(self, n: int, now_s: float, tx: TxEstimator) -> Decision:
        raise NotImplementedError


@dataclasses.dataclass
class CNMTScheduler(BaseScheduler):
    """Paper Eq. (1): compare edge plane vs cloud plane + T_tx at (N, M_hat)."""

    edge: DeviceProfile
    cloud: DeviceProfile
    n2m: LinearN2M
    bytes_per_token: int = 2
    hedge_margin_s: float = 0.0   # 0 => paper-faithful
    name: str = "c-nmt"

    def decide(self, n: int, now_s: float, tx: TxEstimator) -> Decision:
        m_hat = float(np.asarray(self.n2m.predict(float(n))))
        m_hat = max(m_hat, 1.0)
        t_e = float(np.asarray(self.edge.model.predict(float(n), m_hat)))
        payload = float(bytes_for_tokens(n + m_hat, self.bytes_per_token))
        t_c = float(np.asarray(self.cloud.model.predict(float(n), m_hat)))
        t_c_tot = t_c + tx.tx_time(now_s, payload)
        gap = t_c_tot - t_e  # >0 => edge wins
        if abs(gap) <= self.hedge_margin_s:
            device = EDGE  # hedge: local execution has no network variance
        else:
            device = EDGE if t_e <= t_c_tot else CLOUD
        return Decision(device, t_e, t_c_tot, m_hat)

    def decide_batch(self, n: np.ndarray, rtt: np.ndarray) -> np.ndarray:
        """Vectorized Eq. (1) for the analytic simulator.

        ``rtt`` is the scheduler's T_tx estimate (RTT + payload term added
        here) per request.  Returns an int array of EDGE/CLOUD.
        """
        n = np.asarray(n, np.float64)
        m_hat = np.maximum(np.asarray(self.n2m.predict(n), np.float64), 1.0)
        t_e = np.asarray(self.edge.model.predict(n, m_hat), np.float64)
        payload = bytes_for_tokens(n + m_hat, self.bytes_per_token)
        t_tx = np.asarray(rtt, np.float64) + payload * 8.0 / 100e6
        t_c = np.asarray(self.cloud.model.predict(n, m_hat), np.float64) + t_tx
        gap = t_c - t_e
        dev = np.where(t_e <= t_c, EDGE, CLOUD)
        if self.hedge_margin_s > 0:
            dev = np.where(np.abs(gap) <= self.hedge_margin_s, EDGE, dev)
        return dev.astype(np.int32)


def NaiveScheduler(edge: DeviceProfile, cloud: DeviceProfile, n_corpus, m_corpus,
                   **kw) -> CNMTScheduler:
    """Paper §III 'Naive': identical mapping rule, M_hat = corpus average."""
    s = CNMTScheduler(edge=edge, cloud=cloud,
                      n2m=MeanN2M().fit(n_corpus, m_corpus), **kw)
    s.name = "naive"
    return s


@dataclasses.dataclass
class OracleScheduler(BaseScheduler):
    """Ideal lower bound (paper §III): picks the truly fastest device.

    Sees true execution times and the true T_tx of each request — immune to
    regression error, plane mis-fit and stale RTT estimates.
    """

    name: str = "oracle"

    def decide_batch(self, t_edge_true: np.ndarray, t_cloud_true_with_tx: np.ndarray) -> np.ndarray:
        return np.where(t_edge_true <= t_cloud_true_with_tx, EDGE, CLOUD).astype(np.int32)


@dataclasses.dataclass
class StaticScheduler(BaseScheduler):
    """Pure-edge (GW) or pure-cloud (Server) baselines of paper Table I."""

    device: int = EDGE

    @property
    def name(self) -> str:
        return "gw" if self.device == EDGE else "server"

    def decide_batch(self, n: np.ndarray, rtt: np.ndarray) -> np.ndarray:
        return np.full(np.shape(n), self.device, dtype=np.int32)
