"""The paper's experiment (§III): a stream of translation requests hits the
edge gateway, which decides per request whether to run locally or offload.

Faithful points:
* 100k requests replayed against a time-varying RTT trace (Fig. 4) with
  constant symmetric 100 Mbps bandwidth;
* T_exe planes fitted on held-out characterization samples (10k/device);
* T_tx known to the scheduler only through timestamped samples of
  *offloaded* requests (§II-C) — stale whenever traffic stays local;
* Oracle sees true times (ideal lower bound), Naive uses the corpus-mean
  output length; GW/Server are the static baselines;
* requests are independent (no queueing), as in the paper.

The simulator is sequential for estimate-based policies (the T_tx estimate
evolves with past offloading decisions — this coupling is the interesting
dynamics) and vectorized for static/oracle baselines.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.latency_model import DeviceProfile, bytes_for_tokens
from repro.core.profiles import ConnectionProfile
from repro.core.scheduler import (
    CLOUD,
    EDGE,
    CNMTScheduler,
    OracleScheduler,
    StaticScheduler,
)
from repro.core.tx_estimator import TxEstimator


@dataclasses.dataclass
class RequestStream:
    """Arrival times + input/output lengths for one experiment.

    ``m_out`` is the length of the translation the NMT model *actually
    produces* (drives true compute time and response payload); ``m_real``
    is the ground-truth reference length (used only to fit gamma/delta,
    as in the paper: "computed on the ground-truth (N, M_real) pairs").
    """

    t_arrival_s: np.ndarray
    n: np.ndarray
    m_out: np.ndarray
    m_real: np.ndarray

    def __len__(self) -> int:
        return int(self.n.size)


def make_stream(n, m_out, m_real, *, duration_s: float, seed: int = 0) -> RequestStream:
    """Spread requests over the trace window with arrival jitter."""
    rng = np.random.default_rng(seed)
    k = len(n)
    base = np.arange(k) * (duration_s / k)
    jitter = rng.uniform(0, duration_s / k, size=k)
    return RequestStream(
        t_arrival_s=base + jitter,
        n=np.asarray(n, np.float64),
        m_out=np.asarray(m_out, np.float64),
        m_real=np.asarray(m_real, np.float64),
    )


@dataclasses.dataclass
class SimulationResult:
    policy: str
    device: np.ndarray       # per-request EDGE/CLOUD
    latency_s: np.ndarray    # per-request true latency
    offload_frac: float
    total_s: float

    def vs(self, other: "SimulationResult") -> float:
        """Percentage execution-time variation vs a baseline (Table I)."""
        return 100.0 * (self.total_s - other.total_s) / other.total_s


def _true_times(
    stream: RequestStream,
    profile: ConnectionProfile,
    edge: DeviceProfile,
    cloud: DeviceProfile,
    seed: int,
    bytes_per_token: int = 2,
):
    """Draw the ground-truth latencies every policy is evaluated against."""
    rng_e = np.random.default_rng(seed + 1)
    rng_c = np.random.default_rng(seed + 2)
    t_edge = edge.true_time(stream.n, stream.m_out, rng_e)
    t_cloud_exec = cloud.true_time(stream.n, stream.m_out, rng_c)
    payload = bytes_for_tokens(stream.n + stream.m_out, bytes_per_token)
    t_tx = profile.tx_time(stream.t_arrival_s, payload)
    return t_edge, t_cloud_exec + t_tx, t_tx


def simulate(
    policy,
    stream: RequestStream,
    profile: ConnectionProfile,
    edge: DeviceProfile,
    cloud: DeviceProfile,
    *,
    seed: int = 0,
    tx_estimator: Optional[TxEstimator] = None,
    probe_interval_s: Optional[float] = None,
) -> SimulationResult:
    """Replay the request stream under one mapping policy.

    ``probe_interval_s`` (beyond paper) lets the gateway refresh its RTT
    estimate with a cheap ping when no request was offloaded recently;
    None reproduces the paper-faithful timestamp-only mechanism.
    """
    t_edge_true, t_cloud_true, t_tx_true = _true_times(stream, profile, edge, cloud, seed)

    if isinstance(policy, StaticScheduler):
        dev = policy.decide_batch(stream.n, None)
    elif isinstance(policy, OracleScheduler):
        dev = policy.decide_batch(t_edge_true, t_cloud_true)
    elif isinstance(policy, CNMTScheduler):
        dev = _simulate_online(
            policy, stream, profile, t_tx_true,
            tx_estimator=tx_estimator, probe_interval_s=probe_interval_s,
        )
    else:
        raise TypeError(f"unknown policy {policy!r}")

    latency = np.where(dev == EDGE, t_edge_true, t_cloud_true)
    return SimulationResult(
        policy=policy.name,
        device=dev,
        latency_s=latency,
        offload_frac=float(np.mean(dev == CLOUD)),
        total_s=float(latency.sum()),
    )


def _simulate_online(
    policy: CNMTScheduler,
    stream: RequestStream,
    profile: ConnectionProfile,
    t_tx_true: np.ndarray,
    *,
    tx_estimator: Optional[TxEstimator],
    probe_interval_s: Optional[float],
) -> np.ndarray:
    """Sequential replay: the T_tx estimate is coupled to past decisions."""
    est = tx_estimator or TxEstimator(init_rtt_s=float(profile.rtt_at(0.0)))
    n_req = len(stream)
    dev = np.empty(n_req, dtype=np.int32)
    bpt = policy.bytes_per_token
    last_probe = -np.inf
    # Pre-extract plane coefficients & vectorize the (state-free) M_hat:
    # ~100x faster than per-request jnp dispatch.
    em, cm = policy.edge.model, policy.cloud.model
    m_hats = np.maximum(np.asarray(policy.n2m.predict(stream.n), np.float64), 1.0)
    for i in range(n_req):
        t_now = float(stream.t_arrival_s[i])
        n_i = float(stream.n[i])
        m_hat = float(m_hats[i])
        t_e = em.alpha_n * n_i + em.alpha_m * m_hat + em.beta
        payload = (n_i + m_hat) * bpt
        if probe_interval_s is not None and t_now - last_probe >= probe_interval_s:
            est.observe(t_now, float(profile.rtt_at(t_now)))
            last_probe = t_now
        t_tx_hat = est.tx_time(t_now, payload)
        t_c = cm.alpha_n * n_i + cm.alpha_m * m_hat + cm.beta + t_tx_hat
        gap = t_c - t_e
        if abs(gap) <= policy.hedge_margin_s:
            dev[i] = EDGE
        else:
            dev[i] = EDGE if t_e <= t_c else CLOUD
        if dev[i] == CLOUD:
            # response returns with timestamps -> fresh RTT sample (§II-C)
            est.observe(t_now, float(profile.rtt_at(t_now)))
    return dev


def table1_row(
    *,
    dataset: str,
    stream: RequestStream,
    profile: ConnectionProfile,
    edge: DeviceProfile,
    cloud: DeviceProfile,
    cnmt: CNMTScheduler,
    naive: CNMTScheduler,
    seed: int = 0,
    probe_interval_s: Optional[float] = None,
) -> Dict[str, Dict[str, float]]:
    """One dataset x one connection profile block of paper Table I.

    Returns {policy: {"vs_gw": %, "vs_server": %, "vs_oracle": %,
                      "offload_frac": f, "total_s": T}} for Naive and C-NMT.
    Negative percentages = execution-time reduction (as in the paper).
    """
    res = {}
    gw = simulate(StaticScheduler(EDGE), stream, profile, edge, cloud, seed=seed)
    server = simulate(StaticScheduler(CLOUD), stream, profile, edge, cloud, seed=seed)
    oracle = simulate(OracleScheduler(), stream, profile, edge, cloud, seed=seed)
    for pol in (naive, cnmt):
        r = simulate(pol, stream, profile, edge, cloud, seed=seed,
                     probe_interval_s=probe_interval_s)
        res[pol.name] = {
            "vs_gw": r.vs(gw),
            "vs_server": r.vs(server),
            "vs_oracle": r.vs(oracle),
            "offload_frac": r.offload_frac,
            "total_s": r.total_s,
        }
    res["_baselines"] = {
        "gw_total_s": gw.total_s,
        "server_total_s": server.total_s,
        "oracle_total_s": oracle.total_s,
        "oracle_offload_frac": oracle.offload_frac,
        "dataset": dataset,
        "profile": profile.name,
    }
    return res
