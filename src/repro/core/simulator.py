"""Request-stream simulators: the paper's analytic replay (§III) and a
queue-aware discrete-event extension for N-tier serving.

Analytic replay (paper-faithful, :func:`simulate`)
--------------------------------------------------
* 100k requests replayed against a time-varying RTT trace (Fig. 4) with
  constant symmetric 100 Mbps bandwidth;
* T_exe planes fitted on held-out characterization samples (10k/device);
* T_tx known to the scheduler only through timestamped samples of
  *offloaded* requests (§II-C) — stale whenever traffic stays local;
* Oracle sees true times (ideal lower bound), Naive uses the corpus-mean
  output length; GW/Server are the static baselines;
* requests are independent (no queueing), as in the paper.

Sequential for estimate-based policies (the T_tx estimate evolves with
past offloading decisions — this coupling is the interesting dynamics)
and vectorized for static/oracle baselines.

Discrete-event loop (beyond paper, :func:`simulate_des`)
--------------------------------------------------------
The paper's replay treats every request as independent; under real
traffic tiers saturate.  ``simulate_des`` runs an event-driven loop —
arrival / start / finish events over N :class:`SimTier`\\ s, each a
bounded-FIFO multi-server station with its own ground-truth latency
plane and (for remote tiers) its own RTT trace — driven by a
:class:`MultiTierScheduler` whose queue term comes from per-tier
predicted-backlog bookkeeping.  Poisson arrivals (:func:`make_poisson_stream`)
turn the Fig. 4 experiment into a load sweep; an optional
:class:`OnlineCalibrator` refits planes and the N->M regressor from
observed completions every K requests.  At zero load (every completion
before the next arrival, empty queues) the DES reproduces the analytic
replay decision-for-decision on the same seed — the invariant tests pin
it.

Batched continuous serving: a tier with ``batch_size`` b > 1 drains its
FIFO backlog in length-bucketed batches (via
:class:`~repro.data.pipeline.TokenBatcher`): whenever one of its servers
frees up it starts up to b queued requests together, the batch costing

    T_batch = max_i T_exe,true(N_i, M_i) + per_seq_overhead_s * (b - 1)

— one decode pass over the padded batch plus a per-extra-sequence
overhead, the standard sub-linear continuous-batching model.  All batch
members start and finish together.  ``batch_size=1`` takes the exact
PR-1 single-request code path, so the zero-load DES≡analytic invariant
is untouched.

CONTINUOUS in-flight batching (``SimTier(continuous=True)``): the
block-to-completion barrier goes away — each server becomes
``batch_size`` SLOTS, a request occupies one slot for

    T_i = T_exe,true(N_i, M_i) + per_seq_overhead_s * (slots live at start)

and finishes *independently* (its own finish event frees the slot for
the next FIFO request immediately), mirroring
:meth:`~repro.runtime.engine.CollaborativeEngine.serve_continuous`'s
slot table.  At zero load no slot neighbours exist, so the duration is
exactly the solo draw — the zero-load DES≡analytic invariant holds for
continuous tiers too.

Deadline-aware admission (SLO): ``RequestStream.slo_s`` optionally
attaches a relative deadline to each request (``inf`` = none).  A
request whose preferred tier is full is re-routed to the cheapest tier
with space whose *predicted completion* (now + T_queue + T_tx + T_exe)
meets the deadline; if no tier can, the request is **shed** instead of
force-enqueued, and requests whose deadline has already expired by the
time a server would start them are shed at drain.  Requests without
deadlines keep the PR-1 reroute/force-enqueue behaviour bit-for-bit.
``DESResult.summary()`` reports SLO attainment, shed counts and
sustained throughput alongside the latency percentiles.
"""

from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.calibration import OnlineCalibrator
from repro.core.faults import (
    CircuitBreaker,
    FaultSchedule,
    RetryPolicy,
    make_breakers,
)
from repro.core.latency_model import DeviceProfile, bytes_for_tokens
from repro.core.profiles import ConnectionProfile
from repro.core.scheduler import (
    CLOUD,
    EDGE,
    CNMTScheduler,
    MultiTierScheduler,
    OracleScheduler,
    StaticScheduler,
)
from repro.core.tx_estimator import TxEstimator
from repro.data.pipeline import TokenBatcher


def _as_slo_array(slo_s, k: int) -> Optional[np.ndarray]:
    """Normalize a scalar/array SLO spec to a float64 array (inf = none)."""
    if slo_s is None:
        return None
    arr = np.broadcast_to(np.asarray(slo_s, np.float64), (k,)).copy()
    if np.any(arr <= 0):
        raise ValueError("slo_s must be positive (use inf for no deadline)")
    return arr


@dataclasses.dataclass
class RequestStream:
    """Arrival times + input/output lengths for one experiment.

    ``m_out`` is the length of the translation the NMT model *actually
    produces* (drives true compute time and response payload); ``m_real``
    is the ground-truth reference length (used only to fit gamma/delta,
    as in the paper: "computed on the ground-truth (N, M_real) pairs").
    ``slo_s`` (beyond paper) optionally carries a per-request relative
    deadline in seconds (``inf`` = no deadline); the DES sheds requests
    it predicts cannot meet their deadline instead of queueing them.
    """

    t_arrival_s: np.ndarray
    n: np.ndarray
    m_out: np.ndarray
    m_real: np.ndarray
    slo_s: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return int(self.n.size)


def make_stream(n, m_out, m_real, *, duration_s: float, seed: int = 0,
                slo_s=None) -> RequestStream:
    """Spread requests over the trace window with arrival jitter."""
    rng = np.random.default_rng(seed)
    k = len(n)
    base = np.arange(k) * (duration_s / k)
    jitter = rng.uniform(0, duration_s / k, size=k)
    return RequestStream(
        t_arrival_s=base + jitter,
        n=np.asarray(n, np.float64),
        m_out=np.asarray(m_out, np.float64),
        m_real=np.asarray(m_real, np.float64),
        slo_s=_as_slo_array(slo_s, k),
    )


def make_poisson_stream(n, m_out, m_real, *, rate_hz: float,
                        seed: int = 0, slo_s=None) -> RequestStream:
    """Poisson arrivals at ``rate_hz`` (exponential inter-arrival gaps) —
    the load-sweep counterpart of :func:`make_stream`.  ``slo_s`` (scalar
    or per-request array) attaches relative deadlines."""
    if rate_hz <= 0:
        raise ValueError("rate_hz must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=len(n))
    return RequestStream(
        t_arrival_s=np.cumsum(gaps),
        n=np.asarray(n, np.float64),
        m_out=np.asarray(m_out, np.float64),
        m_real=np.asarray(m_real, np.float64),
        slo_s=_as_slo_array(slo_s, len(n)),
    )


def make_trace_stream(arrival_s, n, m_out, m_real=None, *,
                      slo_s=None) -> RequestStream:
    """Trace-replay arrivals: the exact arrival instants of a recorded
    (or synthetic) trace, in seconds.

    This is the DES-twin entry point of the load-generation harness
    (``benchmarks/loadgen.py``): the SAME arrival trace the real
    :class:`~repro.runtime.engine.CollaborativeEngine` was driven with
    — including the *realized* issue times of a closed-loop run — is
    replayed through :func:`simulate_des`, so modelled-vs-real drift is
    measurable per scenario.  ``arrival_s`` is used verbatim (no
    jitter, no re-seeding): the emitted ``t_arrival_s`` is bit-for-bit
    the trace, which the tests pin.  ``m_real`` defaults to ``m_out``
    when the trace carries only realized output lengths.
    """
    t = np.asarray(arrival_s, np.float64)
    if t.ndim != 1:
        raise ValueError("arrival_s must be 1-D")
    if len(t) != len(n) or len(t) != len(m_out):
        raise ValueError("arrival_s / n / m_out length mismatch")
    if t.size and np.any(np.diff(t) < 0):
        raise ValueError("trace arrival times must be non-decreasing")
    if m_real is None:
        m_real = m_out
    return RequestStream(
        t_arrival_s=t,
        n=np.asarray(n, np.float64),
        m_out=np.asarray(m_out, np.float64),
        m_real=np.asarray(m_real, np.float64),
        slo_s=_as_slo_array(slo_s, len(t)),
    )


@dataclasses.dataclass
class SimulationResult:
    """Outcome of one analytic replay (:func:`simulate`).

    All times are seconds of *ground truth* (the drawn execution + true
    T_tx the request experienced), not the scheduler's estimates; the
    policy only influenced which tier each request ran on.  ``total_s``
    is the paper's Table-I objective (sum of per-request latencies).
    """

    policy: str
    device: np.ndarray       # per-request EDGE/CLOUD
    latency_s: np.ndarray    # per-request true latency (seconds)
    offload_frac: float      # fraction of requests sent to CLOUD
    total_s: float           # sum of latencies (Table I objective)

    def vs(self, other: "SimulationResult") -> float:
        """Percentage execution-time variation vs a baseline (Table I)."""
        return 100.0 * (self.total_s - other.total_s) / other.total_s


def _true_times(
    stream: RequestStream,
    profile: ConnectionProfile,
    edge: DeviceProfile,
    cloud: DeviceProfile,
    seed: int,
    bytes_per_token: int = 2,
):
    """Draw the ground-truth latencies every policy is evaluated against."""
    rng_e = np.random.default_rng(seed + 1)
    rng_c = np.random.default_rng(seed + 2)
    t_edge = edge.true_time(stream.n, stream.m_out, rng_e)
    t_cloud_exec = cloud.true_time(stream.n, stream.m_out, rng_c)
    payload = bytes_for_tokens(stream.n + stream.m_out, bytes_per_token)
    t_tx = profile.tx_time(stream.t_arrival_s, payload)
    return t_edge, t_cloud_exec + t_tx, t_tx


def simulate(
    policy,
    stream: RequestStream,
    profile: ConnectionProfile,
    edge: DeviceProfile,
    cloud: DeviceProfile,
    *,
    seed: int = 0,
    tx_estimator: Optional[TxEstimator] = None,
    probe_interval_s: Optional[float] = None,
) -> SimulationResult:
    """Replay the request stream under one mapping policy.

    ``probe_interval_s`` (beyond paper) lets the gateway refresh its RTT
    estimate with a cheap ping when no request was offloaded recently;
    None reproduces the paper-faithful timestamp-only mechanism.
    """
    t_edge_true, t_cloud_true, t_tx_true = _true_times(stream, profile, edge, cloud, seed)

    if isinstance(policy, StaticScheduler):
        dev = policy.decide_batch(stream.n, None)
    elif isinstance(policy, OracleScheduler):
        dev = policy.decide_batch(t_edge_true, t_cloud_true)
    elif isinstance(policy, CNMTScheduler):
        dev = _simulate_online(
            policy, stream, profile, t_tx_true,
            tx_estimator=tx_estimator, probe_interval_s=probe_interval_s,
        )
    else:
        raise TypeError(f"unknown policy {policy!r}")

    latency = np.where(dev == EDGE, t_edge_true, t_cloud_true)
    return SimulationResult(
        policy=policy.name,
        device=dev,
        latency_s=latency,
        offload_frac=float(np.mean(dev == CLOUD)),
        total_s=float(latency.sum()),
    )


def _simulate_online(
    policy: CNMTScheduler,
    stream: RequestStream,
    profile: ConnectionProfile,
    t_tx_true: np.ndarray,
    *,
    tx_estimator: Optional[TxEstimator],
    probe_interval_s: Optional[float],
) -> np.ndarray:
    """Sequential replay: the T_tx estimate is coupled to past decisions."""
    est = tx_estimator or TxEstimator(init_rtt_s=float(profile.rtt_at(0.0)),
                                      bandwidth_bps=profile.bandwidth_bps)
    n_req = len(stream)
    dev = np.empty(n_req, dtype=np.int32)
    bpt = policy.bytes_per_token
    last_probe = -np.inf
    # Pre-extract plane coefficients & vectorize the (state-free) M_hat:
    # ~100x faster than per-request jnp dispatch.
    em, cm = policy.edge.model, policy.cloud.model
    m_hats = np.maximum(np.asarray(policy.n2m.predict(stream.n), np.float64), 1.0)
    for i in range(n_req):
        t_now = float(stream.t_arrival_s[i])
        n_i = float(stream.n[i])
        m_hat = float(m_hats[i])
        t_e = em.alpha_n * n_i + em.alpha_m * m_hat + em.beta
        payload = (n_i + m_hat) * bpt
        if probe_interval_s is not None and t_now - last_probe >= probe_interval_s:
            est.observe(t_now, float(profile.rtt_at(t_now)))
            last_probe = t_now
        t_tx_hat = est.tx_time(t_now, payload)
        t_c = cm.alpha_n * n_i + cm.alpha_m * m_hat + cm.beta + t_tx_hat
        gap = t_c - t_e
        if abs(gap) <= policy.hedge_margin_s:
            dev[i] = EDGE
        else:
            dev[i] = EDGE if t_e <= t_c else CLOUD
        if dev[i] == CLOUD:
            # response returns with timestamps -> fresh RTT sample (§II-C)
            est.observe(t_now, float(profile.rtt_at(t_now)))
    return dev


def table1_row(
    *,
    dataset: str,
    stream: RequestStream,
    profile: ConnectionProfile,
    edge: DeviceProfile,
    cloud: DeviceProfile,
    cnmt: CNMTScheduler,
    naive: CNMTScheduler,
    seed: int = 0,
    probe_interval_s: Optional[float] = None,
) -> Dict[str, Dict[str, float]]:
    """One dataset x one connection profile block of paper Table I.

    Returns {policy: {"vs_gw": %, "vs_server": %, "vs_oracle": %,
                      "offload_frac": f, "total_s": T}} for Naive and C-NMT.
    Negative percentages = execution-time reduction (as in the paper).
    """
    res = {}
    gw = simulate(StaticScheduler(EDGE), stream, profile, edge, cloud, seed=seed)
    server = simulate(StaticScheduler(CLOUD), stream, profile, edge, cloud, seed=seed)
    oracle = simulate(OracleScheduler(), stream, profile, edge, cloud, seed=seed)
    for pol in (naive, cnmt):
        r = simulate(pol, stream, profile, edge, cloud, seed=seed,
                     probe_interval_s=probe_interval_s)
        res[pol.name] = {
            "vs_gw": r.vs(gw),
            "vs_server": r.vs(server),
            "vs_oracle": r.vs(oracle),
            "offload_frac": r.offload_frac,
            "total_s": r.total_s,
        }
    res["_baselines"] = {
        "gw_total_s": gw.total_s,
        "server_total_s": server.total_s,
        "oracle_total_s": oracle.total_s,
        "oracle_offload_frac": oracle.offload_frac,
        "dataset": dataset,
        "profile": profile.name,
    }
    return res


# ===================================================================== DES --
_ARRIVAL, _FINISH, _XARR = 0, 1, 2   # _XARR: encoder states arrive at
                                     # a split plan's decode tier
_DOWN, _UP, _RETRY = 3, 4, 5         # fault edges + retry re-dispatches


@dataclasses.dataclass
class SimTier:
    """Ground truth for one tier in the discrete-event simulator.

    A bounded-FIFO multi-server station: ``servers`` concurrent requests
    (or batches) execute, up to ``queue_capacity`` more wait (None =
    unbounded), and a request routed to a full tier is re-routed to the
    next-best tier with space (counted in ``DESResult.overflow``).
    ``link`` is the tier's RTT trace; None marks the local tier (no T_tx,
    and no §II-C samples).

    ``batch_size`` > 1 turns each server into a continuous-batching
    worker: when it frees up it drains up to ``batch_size`` queued
    requests as one length-bucketed batch (a :class:`TokenBatcher` with
    ``max_batch_tokens`` as its padded-token budget) whose true duration
    is  max over members of the solo execution draw plus
    ``per_seq_overhead_s`` per extra member — all members finish
    together.  ``batch_size=1`` is the exact unbatched PR-1 station.

    ``continuous=True`` removes the block-to-completion barrier: each
    server is ``batch_size`` independent SLOTS, a request occupies one
    slot for its solo draw plus ``per_seq_overhead_s`` per slot live at
    its start, and frees the slot the moment it finishes (FIFO refill) —
    the DES twin of the engine's ``serve_continuous`` slot table.
    """

    name: str
    profile: DeviceProfile
    servers: int = 1
    queue_capacity: Optional[int] = None
    link: Optional[ConnectionProfile] = None
    batch_size: int = 1
    per_seq_overhead_s: float = 0.0
    max_batch_tokens: Optional[int] = None
    continuous: bool = False

    def __post_init__(self):
        if self.servers < 1:
            raise ValueError("servers must be >= 1")
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if self.per_seq_overhead_s < 0:
            raise ValueError("per_seq_overhead_s must be >= 0")
        if self.continuous and self.max_batch_tokens is not None:
            raise ValueError("continuous tiers admit per-slot, not "
                             "per-token-budget batches")


@dataclasses.dataclass
class DESResult:
    """Per-request ground truth of one :func:`simulate_des` run.

    All ``*_s`` arrays are seconds; latency decomposes exactly as
    ``latency_s == wait_s + exec_s + tx_s`` for served requests (the
    invariant tests pin it, including the two-leg split path) and is
    NaN for shed ones.  Everything here is ground truth — what actually
    happened in the event loop — not the scheduler's predictions; the
    scheduler's beliefs only influenced ``tier``.  ``summary()`` is the
    stable reporting surface the benchmarks consume (adding keys is
    allowed, renaming/removing them is a breaking change).
    """

    policy: str
    tier_names: List[str]
    tier: np.ndarray          # per-request tier index (-1 = shed unadmitted)
    t_arrival_s: np.ndarray
    t_start_s: np.ndarray     # execution start (arrival + queue wait)
    t_finish_s: np.ndarray    # execution end
    wait_s: np.ndarray        # T_queue actually experienced
    tx_s: np.ndarray          # true T_tx (0 for local tiers)
    exec_s: np.ndarray        # true T_exe (batch duration for batched tiers)
    latency_s: np.ndarray     # wait + exec + tx (NaN for shed requests)
    overflow: np.ndarray      # per-tier count of forced enqueues (all full)
    shed: Optional[np.ndarray] = None   # per-request deadline-shed flags
    slo_s: Optional[np.ndarray] = None  # relative deadlines (inf = none)
    events: Optional[List] = None   # (time, kind, req, tier) as processed
    # fault-tolerance extras (None unless faults/retry were armed)
    attempts: Optional[np.ndarray] = None       # dispatches per request
    retry_after_s: Optional[np.ndarray] = None  # backpressure hint on shed
    fault_stats: Optional[Dict] = None          # availability/retry/... keys

    @property
    def served(self) -> np.ndarray:
        """Boolean mask of requests that actually executed (not shed)."""
        if self.shed is None:
            return np.ones(len(self.tier), bool)
        return ~self.shed

    @property
    def total_s(self) -> float:
        return float(self.latency_s[self.served].sum())

    def tier_frac(self) -> Dict[str, float]:
        r = max(len(self.tier), 1)
        return {name: float(np.sum(self.tier == k)) / r
                for k, name in enumerate(self.tier_names)}

    def p95_latency_s(self) -> float:
        return float(np.percentile(self.latency_s[self.served], 95))

    def slo_attainment(self) -> float:
        """Fraction of deadline-carrying requests that completed within
        their deadline (shed requests count as missed); 1.0 when no
        request carried a deadline (vacuously attained)."""
        if self.slo_s is None:
            return 1.0
        has_dl = np.isfinite(self.slo_s)
        if not has_dl.any():
            return 1.0
        met = self.served & np.where(
            np.isnan(self.latency_s), False, self.latency_s <= self.slo_s)
        return float(met[has_dl].sum() / has_dl.sum())

    def throughput_rps(self) -> float:
        """Served requests per second of makespan (sustained throughput)."""
        served = self.served
        if not served.any():
            return 0.0
        span = float(self.t_finish_s[served].max()
                     - self.t_arrival_s.min())
        return float(served.sum()) / span if span > 0 else float("inf")

    def summary(self) -> Dict[str, float]:
        srv = self.served
        lat = self.latency_s[srv]
        wait = self.wait_s[srv]
        if lat.size == 0:              # everything shed: no latency stats
            lat = wait = np.array([np.nan])
        out = {
            "requests": float(len(self.tier)),
            "served": float(srv.sum()),
            "mean_latency_s": float(lat.mean()),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "mean_wait_s": float(wait.mean()),
            "max_wait_s": float(wait.max()),
            "overflow": float(self.overflow.sum()),
            "shed": float(len(self.tier) - srv.sum()),
            "slo_attainment": self.slo_attainment(),
            "throughput_rps": self.throughput_rps(),
        }
        if self.fault_stats is not None:
            out.update(self.fault_stats)
        return out


def simulate_des(
    scheduler: MultiTierScheduler,
    stream: RequestStream,
    tiers: Sequence[SimTier],
    *,
    seed: int = 0,
    bytes_per_token: Optional[int] = None,
    calibrator: Optional[OnlineCalibrator] = None,
    collect_events: bool = False,
    inter_links: Optional[Dict] = None,
    faults: Optional[FaultSchedule] = None,
    retry: Optional[RetryPolicy] = None,
    breaker: Optional[CircuitBreaker] = None,
) -> DESResult:
    """Event-driven replay of ``stream`` over N queued tiers.

    Ground truth mirrors :func:`simulate`: per-tier execution times are
    drawn vectorized with ``default_rng(seed + 1 + k)`` (tier 0 = edge,
    tier 1 = cloud reproduces ``_true_times`` exactly) and true T_tx
    comes from each tier's own trace at the request's arrival time.
    Tiers with ``batch_size`` > 1 serve length-bucketed batches whose
    duration is the max of the members' solo draws plus the per-sequence
    overhead (see :class:`SimTier`).

    The scheduler sees queues only through its batch-aware
    :meth:`~repro.core.scheduler.MultiTierScheduler.queue_delay` term
    (its own predicted backlog ÷ effective service rate) and sees each
    link only through §II-C timestamped samples that become available —
    and are timestamped — when an offloaded request *completes* (the RTT
    value is the one the request actually experienced; completions
    arriving out of order cannot rewind the estimator).  ``calibrator``
    (optional) receives every completion and refits the scheduler's
    planes + N->M regressor whenever its interval elapses — pass
    scheduler-owned model copies, not the ground-truth profiles.

    Requests carrying a finite ``stream.slo_s`` deadline are admitted
    only where the predicted completion meets it, shed otherwise (see
    module docstring); without deadlines admission is PR-1-exact.

    ``inter_links`` maps directed tier pairs ``(e, k)`` to ground-truth
    :class:`~repro.core.profiles.ConnectionProfile` traces for the
    encoder-state hop of a split placement.  When it is provided *and*
    the scheduler is split-ready (links + activation + allow_split), the
    DES runs two-leg service: the encode leg occupies tier ``e``, a
    transfer event delivers the states after a one-way ship time, and
    the decode leg queues at tier ``k`` from its own arrival instant.
    Client up/down legs are priced one-way and added post-hoc, exactly
    like whole-request T_tx.  With splits disabled the run is bit-for-bit
    identical to the single-leg simulator.

    Fault injection (ISSUE 8): ``faults`` schedules tier outages, link
    degradation/blackholes and straggler windows.  A crash fails all
    in-flight AND queued work at the tier; a dispatch to a down (or
    blackholed) tier fails after the detection time.  ``retry`` bounds
    re-dispatches with exponential backoff + jitter and arms the
    per-tier circuit breakers (cloned from ``breaker``) that mask
    unhealthy tiers out of the placement argmin; ``retry=None`` is the
    no-retry baseline — failed work is simply lost.  ``retry.replay_shed``
    additionally replays deadline-shed requests after their
    ``retry_after_s`` backpressure hint (ROADMAP 5c).  Split plans are
    disabled while a non-empty schedule is armed (the engine, not the
    DES, models mid-plan decode failover).  With ``faults=None`` — or an
    EMPTY schedule — every path below is pinned bit-for-bit identical to
    the fault-free simulator (tests enforce it).
    """
    k_tiers = len(tiers)
    if k_tiers != len(scheduler.tiers):
        raise ValueError("scheduler/tier count mismatch")
    n_req = len(stream)
    bpt = scheduler.bytes_per_token if bytes_per_token is None \
        else bytes_per_token

    # ground truth, drawn exactly like the analytic replay
    true_exec = [t.profile.true_time(stream.n, stream.m_out,
                                     np.random.default_rng(seed + 1 + k))
                 for k, t in enumerate(tiers)]
    payload_true = bytes_for_tokens(stream.n + stream.m_out, bpt)
    true_tx = [np.zeros(n_req) if t.link is None
               else t.link.tx_time(stream.t_arrival_s, payload_true)
               for t in tiers]

    # ---- split (two-leg) placement support ------------------------------
    # Everything below is gated on ``split_enabled``; with splits disabled
    # (no inter_links, or a scheduler without links/activation/allow_split)
    # the run is bit-for-bit identical to the single-leg simulator.
    # ---- fault-tolerance state ------------------------------------------
    # ``ft`` gates every injection branch; an EMPTY schedule leaves it off
    # so arming the machinery cannot perturb a fault-free run.  Breakers
    # (routing belief) exist only under a retry policy — ``retry=None``
    # is the pre-fault-tolerance baseline where failures just lose work.
    ft = faults is not None and not faults.empty
    use_breakers = ft and retry is not None
    breakers = make_breakers(k_tiers, breaker) if use_breakers else None
    replay_armed = retry is not None and retry.replay_shed
    arm_extras = faults is not None or retry is not None
    rng_retry = np.random.default_rng(seed + 7777)
    down = [False] * k_tiers
    outstanding: List[set] = [set() for _ in range(k_tiers)]
    req_failed: List[set] = [set() for _ in range(n_req)]
    attempts = np.zeros(n_req, np.int64)
    retries_used = np.zeros(n_req, np.int64)
    replays_used = np.zeros(n_req, np.int64)
    retry_after_v = np.full(n_req, np.nan)
    tx_override = np.full(n_req, np.nan)
    fault_failures = np.zeros(k_tiers, np.int64)
    n_retries = n_replays = fault_lost = 0
    retry_req: Dict = {}
    _detect = (retry if retry is not None else RetryPolicy()).detect_s

    want_split = (
        inter_links is not None and len(inter_links) > 0
        and getattr(scheduler, "_split_ready", None) is not None
        and scheduler._split_ready())
    split_enabled = want_split and not ft
    if want_split and ft:
        # ROADMAP item 6 leftover: the DES has no mid-plan decode-leg
        # failover model (the engine does — see runtime/engine.py
        # `_submit_split`), so a non-empty FaultSchedule downgrades every
        # request to whole placements.  Warn instead of silently
        # degrading; the limitation is documented in docs/architecture.md.
        warnings.warn(
            "simulate_des: split placement is disabled while a non-empty "
            "FaultSchedule is armed — the DES does not model mid-plan "
            "decode-leg failover (the engine does); requests fall back to "
            "whole placements.  See docs/architecture.md.",
            RuntimeWarning, stacklevel=2)
    leg_of = np.zeros(n_req, np.int8)   # 0 whole, 1 encode leg, 2 decode leg
    split_mask = np.zeros(n_req, bool)
    split_enc = np.full(n_req, -1, np.int32)
    split_dec = np.full(n_req, -1, np.int32)
    up_v = np.zeros(n_req)     # client uplink, one-way (added post-hoc)
    ship_v = np.zeros(n_req)   # encoder-state transfer (simulated in-line)
    down_v = np.zeros(n_req)   # client downlink, one-way (added post-hoc)
    true_enc: List[np.ndarray] = []
    true_dec: List[np.ndarray] = []
    if split_enabled:
        for k, t in enumerate(tiers):
            te, td = t.profile.true_leg_times(
                stream.n, stream.m_out, np.random.default_rng(seed + 101 + k))
            true_enc.append(te)
            true_dec.append(td)

    # absolute deadlines (inf = none); None disables every deadline branch
    deadline_abs = None
    if stream.slo_s is not None and np.any(np.isfinite(stream.slo_s)):
        deadline_abs = np.asarray(stream.t_arrival_s, np.float64) \
            + np.asarray(stream.slo_s, np.float64)

    def m_hats_vec():
        return np.maximum(
            np.asarray(scheduler.n2m.predict(stream.n), np.float64), 1.0)

    m_hats = m_hats_vec()

    # per-tier station state; a continuous tier's concurrency unit is a
    # SLOT (servers x batch_size of them), a batched tier's is a server
    busy = [0] * k_tiers
    slots = [t.servers * t.batch_size if t.continuous else t.servers
             for t in tiers]
    queues: List[List[int]] = [[] for _ in range(k_tiers)]
    qhead = [0] * k_tiers                 # pop index (amortized O(1) FIFO)
    batchers = [TokenBatcher(max_batch=t.batch_size,
                             max_tokens_per_batch=t.max_batch_tokens
                             if t.max_batch_tokens is not None else 1 << 40)
                if t.batch_size > 1 and not t.continuous else None
                for t in tiers]
    pred_backlog = np.zeros(k_tiers)      # scheduler-predicted work in system
    in_system = [0] * k_tiers             # admitted-but-unfinished count
    pred_exec = np.zeros(n_req)           # predicted T_exe at the chosen tier

    tier_of = np.full(n_req, -1, np.int32)
    t_start = np.zeros(n_req)
    t_finish = np.zeros(n_req)
    exec_used = np.zeros(n_req)           # actual service duration
    shed = np.zeros(n_req, bool)
    overflow = np.zeros(k_tiers, np.int64)
    events: Optional[List] = [] if collect_events else None

    heap = [(float(stream.t_arrival_s[i]), i, _ARRIVAL, -1)
            for i in range(n_req)]
    heapq.heapify(heap)
    seq = n_req  # tie-break counter for events pushed during the run
    if ft:
        # outage edges become first-class events: _DOWN fails in-flight
        # and queued work at the tier, _UP merely flips the ground truth
        # back (the router rediscovers it via half-open probes)
        for t_ev, kind_ev, k_ev in faults.outage_events():
            if kind_ev == "down":
                heapq.heappush(heap, (float(t_ev), seq, _DOWN, int(k_ev)))
            elif kind_ev == "up":
                heapq.heappush(heap, (float(t_ev), seq, _UP, int(k_ev)))
            else:
                continue   # link episodes are sampled at dispatch time
            seq += 1

    def start(i: int, k: int, now: float) -> None:
        nonlocal seq
        if split_enabled and leg_of[i] == 1:
            base = float(true_enc[k][i])
        elif split_enabled and leg_of[i] == 2:
            base = float(true_dec[k][i])
        else:
            base = float(true_exec[k][i])
        # continuous slot admission: the solo draw pays the per-sequence
        # overhead once per slot already live at its start (zero at zero
        # load, so the solo path stays bit-for-bit)
        dur = base \
            + (tiers[k].per_seq_overhead_s * busy[k]
               if tiers[k].continuous else 0.0)
        if ft:
            s = faults.slowdown(k, now)
            if s != 1.0:           # straggler window: degraded, not failed
                dur = dur * s
            # reset first so a retry on a clean (or link-less) tier
            # clears an override left by a degraded earlier attempt
            tx_override[i] = np.nan
            if tiers[k].link is not None:
                # the true T_tx this request pays reflects the link's
                # degradation episode at its (possibly retried) start
                rf, bf = faults.link_factors(k, now)
                if rf != 1.0 or bf != 1.0:
                    tx_override[i] = (
                        float(tiers[k].link.rtt_at(
                            float(stream.t_arrival_s[i]))) * rf
                        + float(payload_true[i]) * 8.0
                        / (tiers[k].link.bandwidth_bps * bf))
        busy[k] += 1
        if split_enabled and leg_of[i] == 2:
            exec_used[i] += dur   # decode leg stacks on the encode leg
        else:
            t_start[i] = now
            exec_used[i] = dur
        fin = now + dur
        heapq.heappush(heap, (fin, seq, _FINISH, k))
        seq += 1
        finish_req[(fin, seq - 1)] = i
        if ft:
            outstanding[k].add((fin, seq - 1))

    def start_batch(ids: List[int], k: int, now: float) -> None:
        nonlocal seq
        busy[k] += 1
        dur = max(float(true_exec[k][i]) for i in ids) \
            + tiers[k].per_seq_overhead_s * (len(ids) - 1)
        if ft:
            s = faults.slowdown(k, now)
            if s != 1.0:
                dur = dur * s
        for i in ids:
            t_start[i] = now
            exec_used[i] = dur
        fin = now + dur
        heapq.heappush(heap, (fin, seq, _FINISH, k))
        seq += 1
        finish_req[(fin, seq - 1)] = tuple(ids)
        if ft:
            outstanding[k].add((fin, seq - 1))

    finish_req: Dict = {}
    xfer_req: Dict = {}

    def shed_request(i: int, k: int, now: float, admitted: bool) -> None:
        """Deadline miss: drop ``i`` (predicted or certain to miss)."""
        shed[i] = True
        if admitted:                       # leaving the tier's backlog
            pred_backlog[k] = max(pred_backlog[k] - pred_exec[i], 0.0)
            in_system[k] -= 1
        if events is not None:
            events.append((now, "shed", i, k))

    def waiting(k: int) -> int:
        if batchers[k] is not None:
            return len(batchers[k])
        return len(queues[k]) - qhead[k]

    def has_space(k: int) -> bool:
        cap = tiers[k].queue_capacity
        return cap is None or waiting(k) < cap or busy[k] < slots[k]

    def drain(k: int, now: float) -> None:
        """Fill freed servers of tier k from its waiting line, shedding
        queued requests whose deadline already expired (they would
        certainly miss; dropping them protects the rest)."""
        if batchers[k] is not None:
            while busy[k] < slots[k] and len(batchers[k]) > 0:
                ids, _ = batchers[k].next_batch_ids()
                if deadline_abs is not None:
                    live = [i for i in ids if deadline_abs[i] >= now]
                    for i in ids:
                        if deadline_abs[i] < now:
                            shed_request(i, k, now, admitted=True)
                    ids = live
                if ids:
                    start_batch(ids, k, now)
        else:
            while busy[k] < slots[k] and waiting(k) > 0:
                j = queues[k][qhead[k]]
                qhead[k] += 1
                if qhead[k] > 1024 and qhead[k] * 2 > len(queues[k]):
                    queues[k] = queues[k][qhead[k]:]
                    qhead[k] = 0
                if deadline_abs is not None and deadline_abs[j] < now:
                    shed_request(j, k, now, admitted=True)
                    continue
                start(j, k, now)

    # ---- fault-tolerance helpers (all no-ops when ft is off) ------------
    def push_retry(i: int, t: float) -> None:
        nonlocal seq
        heapq.heappush(heap, (t, seq, _RETRY, -1))
        retry_req[(t, seq)] = i
        seq += 1

    def breaker_mask(now: float) -> set:
        """Tiers the routing belief refuses to dispatch to right now
        (an OPEN breaker past its cool-down admits the caller as the
        half-open probe, so probing happens through normal dispatch)."""
        if not use_breakers:
            return set()
        return {j for j in range(k_tiers) if not breakers[j].allow(now)}

    def probe_after(now: float) -> float:
        if not use_breakers:
            return 0.0
        return min(b.time_to_probe(now) for b in breakers)

    def fault_shed(i: int, now: float, retry_after: float) -> None:
        nonlocal fault_lost
        shed[i] = True
        fault_lost += 1
        retry_after_v[i] = retry_after
        if events is not None:
            events.append((now, "fault_shed", i, -1))

    def fail_attempt(i: int, k: int, now: float, blackhole: bool) -> None:
        """One failed dispatch/in-flight attempt on tier k: trip the
        breaker, then either schedule the bounded retry (after the
        detection time + backoff with jitter) or lose the request —
        ``retry=None`` is the no-retry baseline."""
        nonlocal n_retries
        fault_failures[k] += 1
        req_failed[i].add(k)
        if use_breakers:
            breakers[k].record_failure(now)
        detect = _detect(blackhole)
        if events is not None:
            events.append((now, "fault", i, k))
        if retry is not None and retries_used[i] < retry.max_retries:
            retries_used[i] += 1
            n_retries += 1
            push_retry(i, now + detect
                       + retry.backoff(int(retries_used[i]) - 1, rng_retry))
        else:
            fault_shed(i, now + detect, probe_after(now + detect))

    def dispatch(i: int, now: float) -> None:
        """Route + admit one (possibly re-tried/replayed) request — the
        PR-1 arrival body, with unhealthy tiers masked out of the argmin
        and injected failures intercepting the dispatch."""
        nonlocal n_replays
        if arm_extras:
            attempts[i] += 1
        qd = [scheduler.queue_delay(k, pred_backlog[k], in_system[k],
                                    tiers[k].servers)
              for k in range(k_tiers)]
        excl = None
        if ft:
            mask = set(req_failed[i]) | breaker_mask(now)
            if len(mask) >= k_tiers:
                # this request has failed everywhere once — its history
                # may be stale (a tier can have restarted), so keep only
                # the breaker belief
                mask = breaker_mask(now)
            if len(mask) >= k_tiers:
                # every tier dark: graceful degradation bottoms out here
                fault_shed(i, now, probe_after(now))
                return
            excl = frozenset(mask) if mask else None
        d = (scheduler.decide_plan_fast(float(stream.n[i]),
                                        float(m_hats[i]), now, qd,
                                        exclude=excl)
             if split_enabled else
             scheduler.decide_fast(float(stream.n[i]), float(m_hats[i]),
                                   now, qd, exclude=excl))
        k = d.tier
        if split_enabled and d.plan is not None and d.plan.is_split:
            e, kd = d.plan.encode_tier, d.plan.decode_tier
            # two-leg service needs plain (unbatched, non-continuous)
            # stations on both legs, a ground-truth inter-tier link,
            # no deadline, and room on both stations
            eligible = (
                (e, kd) in inter_links
                and batchers[e] is None and not tiers[e].continuous
                and batchers[kd] is None and not tiers[kd].continuous
                and (deadline_abs is None
                     or not np.isfinite(deadline_abs[i]))
                and has_space(e) and has_space(kd))
            if eligible:
                n_i = float(stream.n[i])
                if tiers[e].link is not None:
                    up_v[i] = (float(tiers[e].link.rtt_at(now)) / 2.0
                               + n_i * bpt * 8.0
                               / tiers[e].link.bandwidth_bps)
                if tiers[kd].link is not None:
                    down_v[i] = (float(tiers[kd].link.rtt_at(now)) / 2.0
                                 + float(stream.m_out[i]) * bpt * 8.0
                                 / tiers[kd].link.bandwidth_bps)
                inter = inter_links[(e, kd)]
                ship_v[i] = (
                    float(inter.rtt_at(now)) / 2.0
                    + float(scheduler.activation.payload_bytes(n_i))
                    * 8.0 / inter.bandwidth_bps)
                leg_of[i] = 1
                split_mask[i] = True
                split_enc[i] = e
                split_dec[i] = kd
                tier_of[i] = kd   # reported tier = decode placement
                m_e = scheduler.tiers[e].model
                pred_exec[i] = max(m_e.alpha_n * n_i + 0.5 * m_e.beta,
                                   0.0)
                pred_backlog[e] += pred_exec[i]
                in_system[e] += 1
                if events is not None:
                    events.append((now, "arrival", i, e))
                if busy[e] < slots[e]:
                    start(i, e, now)
                else:
                    queues[e].append(i)
                return
            # degrade to the best whole placement
            k = scheduler._select(list(d.t_pred))
        if not has_space(k):
            ranked = sorted(range(k_tiers), key=lambda j: d.t_pred[j])
            if excl is not None:
                # unhealthy tiers are not re-route targets either
                ranked = [j for j in ranked if j not in excl]
            dl = None if deadline_abs is None else float(deadline_abs[i])
            if dl is None or not np.isfinite(dl):
                # PR-1 semantics: next-best tier with space, else force
                for j in ranked:
                    if has_space(j):
                        k = j
                        break
                else:
                    overflow[k] += 1  # everything full: force-enqueue
            else:
                # deadline-aware: cheapest tier with space whose
                # predicted completion meets the deadline; else shed
                # (force-enqueue only if the preferred full tier is
                # still predicted to make it).
                spaced = [j for j in ranked if has_space(j)]
                feasible = [j for j in spaced
                            if now + d.t_pred[j] <= dl]
                if feasible:
                    k = feasible[0]
                elif not spaced and now + d.t_pred[k] <= dl:
                    overflow[k] += 1
                else:
                    # retry-after backpressure (ROADMAP 5c): a client
                    # honoring the hint re-submits after the predicted
                    # queue drain instead of losing the request outright
                    ra = max(min(qd), 0.0)
                    if (replay_armed
                            and replays_used[i] < retry.max_retries):
                        ra = max(ra, retry.backoff_base_s)
                        if now + ra <= dl:
                            replays_used[i] += 1
                            n_replays += 1
                            retry_after_v[i] = ra
                            if events is not None:
                                events.append((now, "backpressure", i, k))
                            push_retry(i, now + ra)
                            return
                    retry_after_v[i] = ra
                    shed_request(i, k, now, admitted=False)
                    return
        if ft and (down[k] or (tiers[k].link is not None
                               and faults.link_blackhole(k, now))):
            # injected failure at dispatch: the schedule is ground truth
            # the router only experiences through this failed attempt
            fail_attempt(i, k, now, blackhole=not down[k])
            return
        tier_of[i] = k
        pe = (scheduler.tiers[k].model.alpha_n * float(stream.n[i])
              + scheduler.tiers[k].model.alpha_m * float(m_hats[i])
              + scheduler.tiers[k].model.beta)
        pred_exec[i] = max(pe, 0.0)
        pred_backlog[k] += pred_exec[i]
        in_system[k] += 1
        if events is not None:
            events.append((now, "arrival", i, k))
        if busy[k] < slots[k]:
            if batchers[k] is not None:
                start_batch([i], k, now)
            else:
                start(i, k, now)
        elif batchers[k] is not None:
            batchers[k].add(i, length=int(stream.n[i]))
        else:
            queues[k].append(i)

    while heap:
        now, sq, kind, k_fin = heapq.heappop(heap)
        if kind == _ARRIVAL:
            dispatch(sq, now)
        elif kind == _RETRY:
            i = retry_req.pop((now, sq))
            if events is not None:
                events.append((now, "retry", i, -1))
            dispatch(i, now)
        elif kind == _DOWN:
            k = k_fin
            down[k] = True
            if events is not None:
                events.append((now, "tier_down", -1, k))
            # the crash fails everything in flight at the tier...
            for key in sorted(outstanding[k]):
                done = finish_req.pop(key, None)
                if done is None:
                    continue
                busy[k] -= 1
                for i in (done if isinstance(done, tuple) else (done,)):
                    pred_backlog[k] = max(pred_backlog[k] - pred_exec[i],
                                          0.0)
                    in_system[k] -= 1
                    fail_attempt(i, k, now, blackhole=False)
            outstanding[k].clear()
            # ...and everything still queued there dies with it
            doomed: List[int] = []
            if batchers[k] is not None:
                while len(batchers[k]) > 0:
                    ids, _ = batchers[k].next_batch_ids()
                    doomed.extend(ids)
            else:
                doomed = queues[k][qhead[k]:]
                queues[k] = []
                qhead[k] = 0
            for i in doomed:
                pred_backlog[k] = max(pred_backlog[k] - pred_exec[i], 0.0)
                in_system[k] -= 1
                fail_attempt(i, k, now, blackhole=False)
        elif kind == _UP:
            down[k_fin] = False   # half-open probing rediscovers the tier
            if events is not None:
                events.append((now, "tier_up", -1, k_fin))
        elif kind == _XARR:
            # encoder states reached the decode tier: queue the second leg
            i = xfer_req.pop((now, sq))
            k = k_fin
            leg_of[i] = 2
            m_d = scheduler.tiers[k].model
            pred_exec[i] = max(
                m_d.alpha_m * float(m_hats[i]) + 0.5 * m_d.beta, 0.0)
            pred_backlog[k] += pred_exec[i]
            in_system[k] += 1
            if events is not None:
                events.append((now, "xfer", i, k))
            if busy[k] < slots[k]:
                start(i, k, now)
            else:
                queues[k].append(i)
        else:
            done = finish_req.pop((now, sq), None)
            if done is None:
                continue   # voided: its tier crashed while it ran
            members = done if isinstance(done, tuple) else (done,)
            k = k_fin
            busy[k] -= 1
            if ft:
                outstanding[k].discard((now, sq))
            if use_breakers and breakers[k].record_success():
                # breaker recovery: the link estimators warmed during the
                # episode describe a network that no longer exists
                st = scheduler.tiers[k]
                if st.tx is not None:
                    st.tx.invalidate()
                if getattr(scheduler, "links", None) is not None:
                    scheduler.links.invalidate(k)
            for i in members:
                if split_enabled and leg_of[i] == 1:
                    # encode leg done: ship the activations; completion
                    # bookkeeping waits for the decode leg
                    pred_backlog[k] = max(pred_backlog[k] - pred_exec[i],
                                          0.0)
                    in_system[k] -= 1
                    if events is not None:
                        events.append((now, "encode_done", i, k))
                    if tiers[k].link is not None:
                        scheduler.observe_rtt(
                            k, now, float(tiers[k].link.rtt_at(
                                float(stream.t_arrival_s[i]))))
                    x_at = now + float(ship_v[i])
                    heapq.heappush(heap,
                                   (x_at, seq, _XARR, int(split_dec[i])))
                    seq += 1
                    xfer_req[(x_at, seq - 1)] = i
                    continue
                t_finish[i] = now
                pred_backlog[k] = max(pred_backlog[k] - pred_exec[i], 0.0)
                in_system[k] -= 1
                if events is not None:
                    events.append((now, "finish", i, k))
                arr = float(stream.t_arrival_s[i])
                if tiers[k].link is not None:
                    # §II-C: the response carries timestamps -> RTT sample
                    # for this tier's link.  The RTT *value* is the one the
                    # request experienced (trace at its arrival); the sample
                    # is timestamped `now`, when the response came back —
                    # timestamping it at arrival let out-of-order
                    # completions rewind the estimator's clock.
                    rtt_obs = float(tiers[k].link.rtt_at(arr))
                    if ft:
                        rf, _bf = faults.link_factors(k, float(t_start[i]))
                        if rf != 1.0:
                            rtt_obs *= rf   # degraded episode: the sample
                            # the response really carried (§II-C)
                    scheduler.observe_rtt(k, now, rtt_obs)
                if split_enabled and leg_of[i] == 2:
                    # completed split: feed the inter-tier link estimator;
                    # leg samples are half-planes, so skip the calibrator
                    e = int(split_enc[i])
                    scheduler.links.observe(
                        e, k, now, float(inter_links[(e, k)].rtt_at(arr)))
                    continue
                if calibrator is not None:
                    due = calibrator.record(k, float(stream.n[i]),
                                            float(stream.m_out[i]),
                                            float(true_exec[k][i]))
                    if due:
                        calibrator.refit([t.model for t in scheduler.tiers],
                                         scheduler.n2m)
                        m_hats = m_hats_vec()
            drain(k, now)

    rows = np.arange(n_req)
    ok = ~shed & (tier_of >= 0)
    safe_tier = np.where(tier_of >= 0, tier_of, 0)
    tx_s = np.where(ok, np.stack(true_tx)[safe_tier, rows], 0.0)
    if ft:
        # requests served during a link-degradation episode paid the
        # degraded transfer, not the trace baseline
        tx_s = np.where(ok & ~np.isnan(tx_override), tx_override, tx_s)
    exec_s = np.where(ok, exec_used, 0.0)
    wait = np.where(ok, t_start - stream.t_arrival_s, 0.0)
    latency = np.where(ok, wait + exec_s + tx_s, np.nan)
    if split_enabled and split_mask.any():
        # split requests: tx = up + ship + down (all one-way); latency
        # follows the event timeline (which embeds ship and both waits)
        # plus the post-hoc client legs; wait is the residual so the
        # latency = wait + exec + tx invariant holds by construction
        sm = split_mask & ok
        tx_s = np.where(sm, up_v + ship_v + down_v, tx_s)
        latency = np.where(
            sm, (t_finish - stream.t_arrival_s) + up_v + down_v, latency)
        wait = np.where(sm, latency - exec_s - tx_s, wait)
    fault_stats = None
    if arm_extras:
        served = int(ok.sum())
        span = max(float(stream.t_arrival_s[-1]) if n_req else 0.0, 1e-9)
        n_good = served
        if stream.slo_s is not None:
            slo = np.asarray(stream.slo_s, np.float64)
            n_good = int((ok & (latency <= slo)).sum())
        fault_stats = {
            "availability": served / max(n_req, 1),
            "fault_failures": float(fault_failures.sum()),
            "retries": float(n_retries),
            "replays": float(n_replays),
            "fault_lost": float(fault_lost),
            "failover_served": float(int((ok & (attempts > 1)).sum())),
            "breaker_opens": (float(sum(b.n_opens for b in breakers))
                              if use_breakers else 0.0),
            "goodput_rps": n_good / span,
        }
    return DESResult(
        policy=scheduler.name,
        tier_names=[t.name for t in tiers],
        tier=tier_of,
        t_arrival_s=np.asarray(stream.t_arrival_s, np.float64),
        t_start_s=t_start,
        t_finish_s=t_finish,
        wait_s=wait,
        tx_s=tx_s,
        exec_s=exec_s,
        latency_s=latency,
        overflow=overflow,
        shed=shed,
        slo_s=None if stream.slo_s is None
        else np.asarray(stream.slo_s, np.float64),
        events=events,
        attempts=attempts if arm_extras else None,
        retry_after_s=retry_after_v if arm_extras else None,
        fault_stats=fault_stats,
    )
