"""Output-length estimation: the linear N->M mapping of paper §II-B.

The paper's key enabler for collaborative seq2seq inference is that the
(unknown) output length M of a translation correlates strongly with the
(known) input length N, and that a *linear* model

    M_hat = gamma * N + delta                                   (Eq. 2, inner)

fitted per language pair reaches R^2 ~ 0.99 (paper Fig. 3).  gamma captures
relative verbosity of the language pair (gamma < 1 for FR->EN, EN->ZH;
~1 for DE->EN), delta a constant offset.

This module implements the paper's estimator (:class:`LinearN2M`), the
Naive baseline (:class:`MeanN2M`, M_hat = corpus mean, paper §III), and
three beyond-paper estimators the paper's conclusion calls for ("more
advanced output length estimation methods"):

* :class:`RidgeN2M`   — L2-regularized fit, stable for tiny corpora.
* :class:`HuberN2M`   — robust to mis-aligned sentence pairs (the outliers
  the paper removes by pre-filtering; Huber handles them without a filter).
* :class:`BucketN2M`  — piecewise (per-N-bucket) conditional mean/quantile,
  captures mild nonlinearity at extreme lengths; an optional quantile knob
  lets the scheduler hedge latency-critical decisions.

All estimators share fit(N, M) / predict(N) with jnp arrays and are
deterministic given their inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


def prefilter_pairs(
    n: np.ndarray,
    m: np.ndarray,
    *,
    max_len: int = 200,
    max_ratio: float = 3.0,
    min_len: int = 1,
) -> Tuple[np.ndarray, np.ndarray]:
    """ParaCrawl-style corpus pre-filtering (paper §III, ref [21]).

    Removes wrongly-matched sentence pairs before fitting gamma/delta:
    pairs where either side is empty/too long, or where the length ratio
    exceeds ``max_ratio`` in either direction.
    """
    n = np.asarray(n, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    if n.shape != m.shape:
        raise ValueError(f"N/M shape mismatch: {n.shape} vs {m.shape}")
    keep = (
        (n >= min_len)
        & (m >= min_len)
        & (n <= max_len)
        & (m <= max_len)
        & (m <= max_ratio * n)
        & (n <= max_ratio * m)
    )
    return n[keep], m[keep]


@dataclasses.dataclass
class LinearN2M:
    """The paper's estimator: ordinary-least-squares M_hat = gamma*N + delta.

    gamma/delta depend only on the language pair (paper §II-B) — they are
    fitted once on ground-truth (N, M_real) corpus pairs and reused for
    every device and model.
    """

    gamma: float = 1.0
    delta: float = 0.0

    def fit(self, n, m) -> "LinearN2M":
        n = jnp.asarray(n, dtype=jnp.float64 if jnp.array(0.0).dtype == jnp.float64 else jnp.float32)
        m = jnp.asarray(m, dtype=n.dtype)
        if n.size < 2:
            raise ValueError("need >= 2 pairs to fit a line")
        a = jnp.stack([n, jnp.ones_like(n)], axis=1)
        coef, *_ = jnp.linalg.lstsq(a, m)
        self.gamma = float(coef[0])
        self.delta = float(coef[1])
        return self

    def predict(self, n):
        n = jnp.asarray(n)
        return self.gamma * n + self.delta

    # --- quality metrics reported in the paper's Fig. 3 caption -----------
    def r2(self, n, m) -> float:
        n = jnp.asarray(n, dtype=jnp.float32)
        m = jnp.asarray(m, dtype=jnp.float32)
        pred = self.predict(n)
        ss_res = jnp.sum((m - pred) ** 2)
        ss_tot = jnp.sum((m - jnp.mean(m)) ** 2)
        return float(1.0 - ss_res / jnp.maximum(ss_tot, 1e-12))

    def mse(self, n, m) -> float:
        pred = self.predict(jnp.asarray(n, jnp.float32))
        return float(jnp.mean((jnp.asarray(m, jnp.float32) - pred) ** 2))


@dataclasses.dataclass
class MeanN2M:
    """The Naive baseline of paper §III: M_hat = mean output length.

    Ignores N entirely; used to quantify the value of the N->M mapping.
    """

    mean_m: float = 0.0

    def fit(self, n, m) -> "MeanN2M":
        self.mean_m = float(jnp.mean(jnp.asarray(m, jnp.float32)))
        return self

    def predict(self, n):
        n = jnp.asarray(n)
        return jnp.full(n.shape, self.mean_m, dtype=jnp.float32)


@dataclasses.dataclass
class RidgeN2M(LinearN2M):
    """L2-regularized linear fit (beyond paper): stable under tiny corpora."""

    lam: float = 1.0

    def fit(self, n, m) -> "RidgeN2M":
        n = jnp.asarray(n, jnp.float32)
        m = jnp.asarray(m, jnp.float32)
        a = jnp.stack([n, jnp.ones_like(n)], axis=1)
        ata = a.T @ a + self.lam * jnp.eye(2, dtype=a.dtype)
        atb = a.T @ m
        coef = jnp.linalg.solve(ata, atb)
        self.gamma = float(coef[0])
        self.delta = float(coef[1])
        return self


@dataclasses.dataclass
class HuberN2M(LinearN2M):
    """Huber-loss robust linear fit via IRLS (beyond paper).

    Handles wrongly-matched pairs without the explicit pre-filter the paper
    applies; with heavy outliers this recovers the inlier line.
    """

    huber_delta: float = 5.0
    iters: int = 50

    def fit(self, n, m) -> "HuberN2M":
        n = jnp.asarray(n, jnp.float32)
        m = jnp.asarray(m, jnp.float32)
        a = jnp.stack([n, jnp.ones_like(n)], axis=1)
        # init from OLS
        coef, *_ = jnp.linalg.lstsq(a, m)
        for _ in range(self.iters):
            resid = m - a @ coef
            absr = jnp.abs(resid)
            w = jnp.where(absr <= self.huber_delta, 1.0, self.huber_delta / jnp.maximum(absr, 1e-9))
            aw = a * w[:, None]
            coef = jnp.linalg.solve(a.T @ aw + 1e-9 * jnp.eye(2), aw.T @ m)
        self.gamma = float(coef[0])
        self.delta = float(coef[1])
        return self


@dataclasses.dataclass
class BucketN2M:
    """Per-N-bucket conditional mean/quantile estimator (beyond paper).

    Splits N into ``n_buckets`` equal-width buckets and stores the
    ``quantile`` of M in each; prediction falls back to the fitted global
    line outside observed support. quantile=0.5 is a robust conditional
    median; quantile>0.5 gives a pessimistic estimate that lets the
    scheduler hedge against under-predicting M (useful because the latency
    cost of under-predicting is asymmetric when the edge is slow).
    """

    n_buckets: int = 32
    quantile: float = 0.5

    def __post_init__(self):
        self._edges: np.ndarray | None = None
        self._values: np.ndarray | None = None
        self._fallback = LinearN2M()

    def fit(self, n, m) -> "BucketN2M":
        n = np.asarray(n, np.float64)
        m = np.asarray(m, np.float64)
        self._fallback.fit(n, m)
        lo, hi = float(n.min()), float(n.max())
        if hi <= lo:
            hi = lo + 1.0
        self._edges = np.linspace(lo, hi, self.n_buckets + 1)
        idx = np.clip(np.digitize(n, self._edges) - 1, 0, self.n_buckets - 1)
        values = np.zeros(self.n_buckets)
        for b in range(self.n_buckets):
            sel = m[idx == b]
            if sel.size:
                values[b] = np.quantile(sel, self.quantile)
            else:
                mid = 0.5 * (self._edges[b] + self._edges[b + 1])
                values[b] = float(self._fallback.predict(mid))
        self._values = values
        return self

    def predict(self, n):
        n_arr = np.atleast_1d(np.asarray(n, np.float64))
        if self._edges is None:
            raise RuntimeError("BucketN2M not fitted")
        idx = np.clip(np.digitize(n_arr, self._edges) - 1, 0, self.n_buckets - 1)
        out = self._values[idx]
        # extrapolate with the global line outside support
        below = n_arr < self._edges[0]
        above = n_arr > self._edges[-1]
        if below.any() or above.any():
            lin = np.asarray(self._fallback.predict(n_arr))
            out = np.where(below | above, lin, out)
        res = jnp.asarray(out, jnp.float32)
        return res if np.ndim(n) else res[0]
