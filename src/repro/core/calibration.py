"""Offline device characterization (paper §II-B last paragraph / §III).

The paper fits each device's T_exe plane on 10k inferences with inputs held
out from the 100k evaluation set.  Here:

* :func:`measure_seq2seq` times a real JAX seq2seq model on this CPU over a
  grid of input lengths (the model's own greedy decoder determines M), and
  returns (N, M, T) samples.
* :func:`fit_device` least-squares-fits the (N, M, T) plane.
* :func:`make_edge_cloud_pair` synthesizes the paper's two-tier setup from
  one set of measurements: the *edge* device carries the measured plane
  (optionally scaled) and the *cloud* is ``speedup``x faster — mirroring
  the Jetson-TX2-vs-Titan-XP gap (the paper's Fig. 2a slopes differ by
  roughly this factor).  Hardware adaptation note: this container has one
  CPU, so relative speed is the modelled quantity, exactly like the
  paper's simulated network.
* :func:`device_from_roofline` prices an un-runnable target (a TPU v5e
  mesh) from dry-run cost analysis — beyond paper; used by the tiered
  serving engine.
* :func:`measure_batched_seq2seq` + :func:`fit_batch_overhead` calibrate
  the sub-linear batched-decode model  T(b) = T1 + o·(b−1)  that the
  batched serving tiers use (beyond paper): the plane comes from the
  single-sequence grid, the per-extra-sequence overhead ``o`` from a
  batch-size sweep at fixed (N, M).
* :class:`OnlineCalibrator` closes the loop at serve time (beyond paper):
  it accumulates observed (N, M_out, T_exe) completions per tier and
  periodically refits both the scheduler's per-tier planes and the
  LinearN2M length regressor, so a drifting device (thermal throttling,
  noisy neighbors) or a mis-fit offline plane self-corrects online.
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from repro.core.latency_model import DeviceProfile, LinearLatencyModel


def measure_seq2seq(
    translate: Callable[[np.ndarray], Tuple[int, np.ndarray]],
    lengths: Sequence[int],
    *,
    reps: int = 3,
    warmup: int = 1,
    seed: int = 0,
    vocab: int = 1000,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Time ``translate(tokens) -> (m_out, _)`` over a grid of input lengths.

    Returns (N, M, T_seconds) sample arrays, one per (length, rep).
    The first ``warmup`` calls per length are discarded (JIT compilation).
    """
    rng = np.random.default_rng(seed)
    ns, ms, ts = [], [], []
    for n in lengths:
        tokens = rng.integers(1, vocab, size=(int(n),), dtype=np.int32)
        for r in range(warmup + reps):
            t0 = time.perf_counter()
            m_out, _ = translate(tokens)
            dt = time.perf_counter() - t0
            if r >= warmup:
                ns.append(float(n))
                ms.append(float(m_out))
                ts.append(dt)
    return np.asarray(ns), np.asarray(ms), np.asarray(ts)


def measure_seq2seq_grid(
    translate_forced: Callable[[np.ndarray, int], Tuple[int, np.ndarray]],
    n_lengths: Sequence[int],
    m_lengths_for: Callable[[int], Sequence[int]],
    *,
    reps: int = 2,
    warmup: int = 1,
    seed: int = 0,
    vocab: int = 1000,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Characterize T(N, M) on a CONTROLLED grid with real execution.

    ``translate_forced(tokens, m)`` must decode exactly ``m`` tokens
    (``greedy_decode(forced_len=...)``).  The paper fits the plane on 10k
    natural translations; an untrained model's natural output length is
    degenerate, so the grid sweep supplies the (N, M) coverage while the
    per-call wall-clock stays a real model measurement.
    """
    rng = np.random.default_rng(seed)
    ns, ms, ts = [], [], []
    for n in n_lengths:
        tokens = rng.integers(1, vocab, size=(int(n),), dtype=np.int32)
        warmed = False
        for m in m_lengths_for(int(n)):
            for r in range(warmup + reps) if not warmed else range(reps):
                t0 = time.perf_counter()
                m_out, _ = translate_forced(tokens, int(m))
                dt = time.perf_counter() - t0
                if warmed or r >= warmup:
                    ns.append(float(n))
                    ms.append(float(m_out))
                    ts.append(dt)
            warmed = True
    return np.asarray(ns), np.asarray(ms), np.asarray(ts)


def fit_device(
    name: str, n: np.ndarray, m: np.ndarray, t: np.ndarray, *, noise_frac: float = 0.05
) -> DeviceProfile:
    model = LinearLatencyModel().fit(n, m, t)
    return DeviceProfile(name=name, model=model, noise_frac=noise_frac)


def measure_batched_seq2seq(
    translate_batch: Callable[[np.ndarray, int], object],
    batch_sizes: Sequence[int],
    *,
    n_len: int = 16,
    m_len: int = 16,
    reps: int = 2,
    warmup: int = 1,
    seed: int = 0,
    vocab: int = 1000,
) -> Tuple[np.ndarray, np.ndarray]:
    """Time ``translate_batch(tokens_2d, forced_len)`` over a batch-size grid.

    The single-sequence grid (:func:`measure_seq2seq_grid`) characterizes
    the T_exe(N, M) plane; this sweep holds (N, M) fixed and varies only
    the batch size b, measuring the *marginal* cost of each extra
    sequence in a padded decode batch.  Returns (b, T_seconds) samples
    for :func:`fit_batch_overhead`.
    """
    rng = np.random.default_rng(seed)
    bs, ts = [], []
    for b in batch_sizes:
        tokens = rng.integers(1, vocab, size=(int(b), n_len), dtype=np.int32)
        for r in range(warmup + reps):
            t0 = time.perf_counter()
            translate_batch(tokens, m_len)
            dt = time.perf_counter() - t0
            if r >= warmup:
                bs.append(float(b))
                ts.append(dt)
    return np.asarray(bs), np.asarray(ts)


def fit_batch_overhead(b: np.ndarray, t: np.ndarray) -> Tuple[float, float]:
    """Fit the sub-linear batch latency model  T(b) = T1 + o * (b - 1).

    Least-squares on (batch size, batch wall-clock) samples from
    :func:`measure_batched_seq2seq`; returns ``(t_base_s,
    per_seq_overhead_s)`` with the overhead clamped non-negative (same
    physical constraint as the plane slopes).  ``per_seq_overhead_s``
    plugs directly into ``SimTier`` / ``Tier`` / ``SchedTier``.
    """
    b = np.asarray(b, np.float64)
    t = np.asarray(t, np.float64)
    if b.size < 2 or np.ptp(b) == 0:
        raise ValueError("need samples at >= 2 distinct batch sizes")
    a = np.stack([np.ones_like(b), b - 1.0], axis=1)
    coef, *_ = np.linalg.lstsq(a, t, rcond=None)
    return float(coef[0]), float(max(coef[1], 0.0))


def make_edge_cloud_pair(
    n: np.ndarray,
    m: np.ndarray,
    t: np.ndarray,
    *,
    speedup: float = 5.0,
    edge_scale: float = 1.0,
    edge_noise: float = 0.05,
    cloud_noise: float = 0.08,
) -> Tuple[DeviceProfile, DeviceProfile]:
    """Edge = measured plane (x ``edge_scale``), cloud = ``speedup``x faster.

    cloud_noise > edge_noise reflects the shared, loaded server (the
    paper's Titan fit has visibly wider bands: MSE 1.2 ms vs 0.13 ms).
    """
    base = LinearLatencyModel().fit(n, m, t)
    # physical constraint: per-token costs cannot be negative (tiny-scale
    # CPU measurements can produce a slightly negative alpha_N from noise)
    base.alpha_n = max(base.alpha_n, 0.0)
    base.alpha_m = max(base.alpha_m, 0.0)
    edge = DeviceProfile("edge-gw", base.scaled(1.0 / edge_scale), edge_noise)
    cloud = DeviceProfile("cloud-server", base.scaled(speedup / edge_scale), cloud_noise)
    return edge, cloud


class OnlineCalibrator:
    """Online feedback refitting for the multi-tier scheduler.

    ``record`` ingests one completed request's observation; every
    ``interval`` records it reports a refit as due, and ``refit``
    re-estimates (in place):

    * each tier's T_exe plane from its last ``window`` (N, M, T) samples
      (skipped below ``min_samples`` — a tier that never wins keeps its
      offline plane), with per-token slopes clamped non-negative exactly
      like the offline fit; and
    * the shared LinearN2M gamma/delta from the pooled (N, M_out) pairs.

    The caller owns which model objects get mutated — pass copies if the
    originals double as ground truth (the DES does exactly that).
    """

    def __init__(self, n_tiers: int, *, interval: int = 256,
                 min_samples: int = 16, window: int = 4096):
        if interval < 1:
            raise ValueError("interval must be >= 1")
        self.interval = interval
        self.min_samples = max(int(min_samples), 3)
        self._samples = [collections.deque(maxlen=window)
                         for _ in range(n_tiers)]
        self._since_refit = 0
        self.n_recorded = 0
        self.n_refits = 0
        self.n_excluded = 0

    def record(self, tier: int, n: float, m_out: float, t_exe_s: float,
               ok: bool = True) -> bool:
        """Ingest one completion; True when a refit is due.

        ``ok=False`` marks a failed/timed-out request: its ``t_exe_s``
        is a timeout artifact, not a device measurement, and its
        ``m_out`` is whatever the failure left behind — feeding either
        into the plane fit or the N→M regressor would corrupt the
        latency model, so the sample is counted (``n_excluded``) and
        dropped without advancing the refit clock.
        """
        if not ok:
            self.n_excluded += 1
            return False
        self._samples[tier].append((float(n), float(m_out), float(t_exe_s)))
        self.n_recorded += 1
        self._since_refit += 1
        return self._since_refit >= self.interval

    def refit(self, models: Sequence[LinearLatencyModel],
              n2m=None) -> Dict[str, float]:
        """Refit tier planes (and optionally the N->M regressor) in place."""
        self._since_refit = 0
        refit_tiers = 0
        for k, model in enumerate(models):
            samples = self._samples[k]
            if len(samples) < self.min_samples:
                continue
            n, m, t = (np.asarray(col) for col in zip(*samples))
            model.fit(n, m, t)
            model.alpha_n = max(model.alpha_n, 0.0)
            model.alpha_m = max(model.alpha_m, 0.0)
            refit_tiers += 1
        pooled = [s for tier in self._samples for s in tier]
        if n2m is not None and len(pooled) >= 2:
            n, m, _ = (np.asarray(col) for col in zip(*pooled))
            if np.ptp(n) > 0:          # degenerate single-N pools: keep fit
                n2m.fit(n, m)
        self.n_refits += 1
        return {"refit_tiers": float(refit_tiers),
                "pooled_samples": float(len(pooled)),
                "n_refits": float(self.n_refits)}


def device_from_roofline(
    name: str,
    *,
    prefill_flops_per_token: float,
    decode_flops_per_token: float,
    decode_bytes_per_token: float,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    chips: int = 1,
    overhead_s: float = 0.002,
    mfu: float = 0.4,
    noise_frac: float = 0.05,
) -> DeviceProfile:
    """Beyond paper: a DeviceProfile priced from dry-run roofline terms."""
    model = LinearLatencyModel.from_roofline(
        prefill_flops_per_token=prefill_flops_per_token / chips,
        decode_flops_per_token=decode_flops_per_token / chips,
        decode_bytes_per_token=decode_bytes_per_token / chips,
        peak_flops=peak_flops,
        hbm_bw=hbm_bw,
        overhead_s=overhead_s,
        mfu=mfu,
    )
    return DeviceProfile(name=name, model=model, noise_frac=noise_frac)
