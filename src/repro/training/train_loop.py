"""Train-step factory: loss -> grads -> clip -> AdamW, one jit-able unit.

``make_train_step`` builds the exact function the multi-pod dry-run
lowers for the ``train_4k`` shape, so what we roofline is what we train.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.training.losses import lm_loss
from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(model, key, moments_dtype=jnp.float32) -> TrainState:
    params = model.init(key)
    return TrainState(params=params,
                      opt=adamw_init(params, moments_dtype=moments_dtype))


def make_train_step(model, *, lr_schedule: Optional[Callable] = None,
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    remat: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return lm_loss(model, params, batch)

    if remat:
        loss_fn = jax.checkpoint(loss_fn, static_argnums=())

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
        lr = (lr_schedule(state.opt.step) if lr_schedule is not None
              else opt_cfg.lr)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   cfg=opt_cfg)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params=params, opt=opt), metrics

    return train_step
