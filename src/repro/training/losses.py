"""Loss functions for the LM stack."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _token_ce(logits, targets, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_loss(model, params, batch, *, aux_weight: float = 0.001,
            mtp_weight: float = 0.3):
    """Causal-LM cross entropy + MoE load-balance aux + optional MTP loss.

    batch: {"tokens": (B,S), "targets": (B,S)[, "mask", "frames"]}.
    Returns (loss, metrics dict).
    """
    kw = {}
    if "frames" in batch:
        kw["frames"] = batch["frames"]
    out = model.train_logits(params, batch["tokens"], **kw)
    mask = batch.get("mask")
    ce = _token_ce(out["logits"], batch["targets"], mask)
    loss = ce + aux_weight * out["aux_loss"]
    metrics = {"ce": ce, "aux": out["aux_loss"]}
    if "mtp_logits" in out:
        # MTP predicts token t+2: shift targets one extra step
        mtp_targets = jnp.roll(batch["targets"], -1, axis=1)
        valid = jnp.ones_like(mtp_targets, jnp.float32).at[:, -2:].set(0.0)
        if mask is not None:
            valid = valid * mask
        mtp_ce = _token_ce(out["mtp_logits"], mtp_targets, valid)
        loss = loss + mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics
