"""AdamW + gradient clipping + LR schedules, as pure pytree transforms.

No optax in this environment — the implementation follows the standard
decoupled-weight-decay AdamW (Loshchilov & Hutter) with bias correction.
Moments are stored in f32 regardless of param dtype (mixed-precision
training discipline: bf16 params would otherwise lose the small updates).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # scalar int32
    mu: Any                # first moment  (f32 pytree)
    nu: Any                # second moment (f32 pytree)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params, moments_dtype=jnp.float32) -> AdamWState:
    """``moments_dtype=bf16`` halves optimizer memory (ZeRO-style knob used
    by the >=100B dry-runs; f32 moments remain the training default)."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, moments_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, global_norm)."""
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def _is_matrix(p) -> bool:
    return p.ndim >= 2  # decay only matrices (norms/bias/scalars exempt)


def adamw_update(params, grads, state: AdamWState, *, lr,
                 cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step. ``lr`` may be a traced scalar (schedule value)."""
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mdt = m.dtype
        m = (b1 * m.astype(jnp.float32) + (1 - b1) * gf).astype(mdt)
        v = (b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf).astype(mdt)
        update = (m.astype(jnp.float32) / c1) / \
            (jnp.sqrt(v.astype(jnp.float32) / c2) + cfg.eps)
        if _is_matrix(p):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_frac: float = 0.1):
    """Linear warmup -> cosine decay to ``min_frac * base_lr``."""

    def lr_at(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)

    return lr_at
