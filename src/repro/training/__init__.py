"""Training substrate: AdamW, LR schedules, losses, train step, checkpoints."""

from repro.training.optimizer import (
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.training.losses import lm_loss
from repro.training.train_loop import make_train_step, TrainState
from repro.training.checkpoint import save_checkpoint, load_checkpoint

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "lm_loss",
    "make_train_step",
    "TrainState",
    "save_checkpoint",
    "load_checkpoint",
]
