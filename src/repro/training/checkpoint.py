"""Checkpointing: pytree <-> .npz on disk, with structure manifest.

No orbax offline — this is a dependency-free implementation with the
same guarantees a trainer needs: atomic write (tmp + rename), exact
dtype/shape restore, and a JSON manifest for inspection.  Leaves are
flattened with jax.tree_util key paths so arbitrary nested dict/list/
NamedTuple states (TrainState, AdamWState, decode caches) round-trip.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat, treedef


def save_checkpoint(path: str, tree, *, step: int | None = None) -> str:
    """Atomically write ``tree`` to ``path`` (.npz). Returns final path."""
    flat, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "num_leaves": len(flat),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __manifest__=json.dumps(manifest), **flat)
        # np.savez appends .npz to the filename it writes
        os.replace(tmp + ".npz", path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def load_checkpoint(path: str, like) -> Any:
    """Restore into the structure of ``like`` (same treedef)."""
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files if k != "__manifest__"}
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(path_)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def checkpoint_step(path: str) -> int | None:
    with np.load(path, allow_pickle=False) as z:
        m = json.loads(str(z["__manifest__"]))
    return m.get("step")
