"""End-to-end training driver: train the Marian-style transformer on a
synthetic parallel corpus for a few hundred steps with the full
substrate — bucketing pipeline, AdamW + cosine schedule, grad clipping,
checkpointing.  Loss is expected to drop steeply as the model learns the
corpus statistics (it is synthetic, but the machinery is the real one).

Run:  PYTHONPATH=src python examples/train_nmt.py [--steps 200]
(REPRO_SMOKE=1 defaults to a 60-step run for the examples smoke test.)
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import padded_batches
from repro.data.synthetic import make_corpus
from repro.nmt import MarianTransformer, TransformerConfig
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)


def main():
    smoke = bool(int(os.environ.get("REPRO_SMOKE", "0")))
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60 if smoke else 200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/repro_nmt_ckpt.npz")
    args = ap.parse_args()

    cfg = TransformerConfig(vocab_src=512, vocab_tgt=512, d_model=128,
                            heads=4, d_ff=256, enc_layers=2, dec_layers=2,
                            max_decode_len=64, max_src_len=64)
    model = MarianTransformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=3e-4, weight_decay=0.01)
    sched = cosine_schedule(3e-4, warmup_steps=20, total_steps=args.steps)

    corpus = make_corpus("de-en", 4000, seed=0, with_tokens=True)
    # clip token ids into the tiny vocab for this demo
    src = [np.minimum(s, cfg.vocab_src - 1) for s in corpus.src]
    tgt = [np.minimum(t, cfg.vocab_tgt - 1) for t in corpus.tgt]

    @jax.jit
    def step(params, opt, batch, lr):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, gn = clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt = adamw_update(params, grads, opt, lr=lr, cfg=opt_cfg)
        return params, opt, loss, gn

    t0 = time.time()
    it = 0
    losses = []
    while it < args.steps:
        for batch in padded_batches(src, tgt, batch_size=args.batch,
                                    max_len=48, seed=it):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            lr = sched(opt.step)
            params, opt, loss, gn = step(params, opt, batch, lr)
            losses.append(float(loss))
            if it % 25 == 0:
                print(f"step {it:4d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(gn):.2f}  lr {float(lr):.2e}")
            it += 1
            if it >= args.steps:
                break
    print(f"\nfirst-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f} "
          f"({time.time()-t0:.0f}s)")
    save_checkpoint(args.ckpt, {"params": params, "opt": opt},
                    step=args.steps)
    print(f"checkpoint written to {args.ckpt}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not drop"


if __name__ == "__main__":
    main()
