"""Fault-tolerant collaborative serving: outages, failover, breakers.

Part 1 replays the same request stream through the CollaborativeEngine
twice against an injected mid-run cloud outage (a deterministic
:class:`FaultSchedule` — the DES and the engine consume the same
object):

* ``retry=None``  — the no-retry baseline: an attempt that hits the
  dead tier is lost after the detection time.
* ``retry=RetryPolicy()`` — failover: the failed attempt re-enters the
  router with the dead tier masked, the tier's circuit breaker opens
  after consecutive failures and steers later requests away up front,
  and a half-open probe rediscovers the tier once the outage ends.

Part 2 crashes a REAL executor: :func:`build_executor(kind="raw", faults=...)` wraps the
edge's ``tokens -> (m_out, out)`` callable so chosen calls raise
:class:`TierFaultError` through the engine's execution boundary — the
same failover loop catches it and re-dispatches to the cloud.

Run:  PYTHONPATH=src python examples/fault_tolerant_serving.py
(REPRO_SMOKE=1 shrinks the request stream for the examples smoke test.)
"""

import os

import numpy as np

from repro.core.faults import FaultSchedule, RetryPolicy, TierOutage
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import TierFaultError, build_executor

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))
N_REQ = 120 if SMOKE else 400
RATE_HZ = 20.0

edge_prof = DeviceProfile("edge", LinearLatencyModel(2e-3, 8e-3, 0.01), 0.0)
cloud_prof = DeviceProfile("cloud", LinearLatencyModel(4e-4, 1.6e-3, 0.002),
                           0.0)
profile = make_profile("cp2", seed=7)

span = N_REQ / RATE_HZ
faults = FaultSchedule(outages=(TierOutage(1, 0.2 * span, 0.6 * span),))
print(f"== part 1: cloud outage {faults.outages[0].start_s:.1f}s -> "
      f"{faults.outages[0].end_s:.1f}s over a {span:.0f}s stream ==")


def build(retry):
    return CollaborativeEngine(
        tiers=[Tier(edge_prof),
               Tier(cloud_prof,
                    rtt_fn=lambda t: float(profile.rtt_at(t)))],
        n2m=LinearN2M(1.0, 0.0),
        seed=0, faults=faults, retry=retry)


rng = np.random.default_rng(3)
lengths = rng.integers(2, 200, N_REQ)
for name, retry in (("no-retry", None), ("failover", RetryPolicy())):
    eng = build(retry)
    for i, n in enumerate(lengths):
        eng.submit(np.zeros(int(n), np.int32), now_s=i / RATE_HZ)
    s = eng.stats()
    print(f"  {name:9s} availability={s['availability']:.3f} "
          f"lost={s['fault_lost']} retries={s['retries']} "
          f"failovers={s['failovers']} "
          f"breaker_opens={s['breaker_opens']} "
          f"mean_attempts={s['mean_attempts']:.3f}")

print("== part 2: a REAL executor that crashes (TierFaultError) ==")


def toy_translate(tokens):
    # stand-in for a GenerationSession executor: echo-length "translation"
    return len(tokens), np.asarray(tokens, np.int32)


crashing = build_executor(toy_translate, kind="raw", faults={1, 2},
                          fault_message="edge process killed")
eng = CollaborativeEngine(
    tiers=[Tier(edge_prof, executor=crashing),
           # WAN so bad the edge always wins...
           Tier(cloud_prof, rtt_fn=lambda t: 5.0)],
    n2m=LinearN2M(1.0, 0.0),
    seed=0, retry=RetryPolicy())
# ...except when its executor crashes: calls 1 and 2 raise inside
# tier.run and the failover loop re-dispatches them to the cloud
for i in range(4):
    r = eng.submit(np.zeros(4, np.int32), now_s=float(i))
    print(f"  req {i}: device={'edge' if r.device == 0 else 'cloud'} "
          f"attempts={r.attempts} failed_tiers={r.failed_tiers}")
assert crashing.calls["faults"] == 2, crashing.calls
try:
    build_executor(toy_translate, kind="raw", faults={0})(
        np.zeros(4, np.int32))
except TierFaultError as e:
    print(f"  raw executor raise: {type(e).__name__}: {e}")
print("done.")
