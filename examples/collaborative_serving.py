"""End-to-end collaborative serving: REAL model at the edge, modelled
cloud tier, live C-NMT routing (the paper's testbed in miniature).

The edge gateway runs the actual BiLSTM seq2seq (JAX, this CPU); the
cloud tier is its calibrated plane sped up 5x behind a replayed RTT
trace.  200 requests stream through the CollaborativeEngine; compare
total latency against always-edge / always-cloud.

Run:  PYTHONPATH=src python examples/collaborative_serving.py
(REPRO_SMOKE=1 shrinks the request stream for the examples smoke test.)
"""

import os
import time

import jax
import numpy as np

from repro.core.calibration import make_edge_cloud_pair, measure_seq2seq_grid
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.profiles import make_profile
from repro.data.synthetic import LANGUAGE_PAIRS, make_corpus
from repro.models.registry import resolve
from repro.runtime.engine import CollaborativeEngine, Tier

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))
N_REQ = 30 if SMOKE else 200

print("== calibrating the edge model (real measurements) ==")
_r = resolve("cnmt:de-en", scale=0.15, vocab=1000, max_decode_len=64)
model, pair = _r.model, _r.pair
params = model.init(jax.random.PRNGKey(0))
translate = model.make_translate(params)
lp = LANGUAGE_PAIRS["de-en"]
n, m, t = measure_seq2seq_grid(
    lambda toks, fl: translate(toks, forced_len=fl),
    (4, 8, 16, 32), lambda nn: [max(2, int(0.5 * nn)), nn, 2 * nn],
    reps=1, vocab=1000)
edge_prof, cloud_prof = make_edge_cloud_pair(n, m, t, speedup=5.0)
print(f"  plane: aN={edge_prof.model.alpha_n*1e3:.3f}ms "
      f"aM={edge_prof.model.alpha_m*1e3:.3f}ms "
      f"b={edge_prof.model.beta*1e3:.1f}ms")

corpus = make_corpus("de-en", 2200, seed=1, with_tokens=True)
fit, eval_ = corpus.split(2000)
nf, mf = prefilter_pairs(fit.n, fit.m_real)
n2m = LinearN2M().fit(nf, mf)
profile = make_profile("cp2", seed=1)

# the tiny demo model is far faster than the paper's Jetson-scale edge, so
# use a LAN-class link (RTT/5) to keep the edge/cloud crossover inside the
# corpus length range (benchmarks/table1.py reproduces the paper's WAN
# setting with Jetson-scaled planes)
engine = CollaborativeEngine(
    tiers=[Tier(edge_prof, executor=lambda toks: translate(toks)),
           # cloud is modelled (as the paper simulates)
           Tier(cloud_prof, rtt_fn=lambda t: float(profile.rtt_at(t)) * 0.2)],
    n2m=n2m, seed=0)

print(f"== streaming {N_REQ} requests through the gateway ==")
t0 = time.perf_counter()
for i in range(N_REQ):
    engine.submit(eval_.src[i][:64], now_s=i * 0.5)
stats = engine.stats()
wall = time.perf_counter() - t0
print(f"  mean latency {stats['mean_latency_s']*1e3:.1f}ms  "
      f"p95 {stats['p95_latency_s']*1e3:.1f}ms  "
      f"offloaded {stats['offload_frac']*100:.0f}%  "
      f"(wall {wall:.1f}s)")
print(f"  tx estimate now: {stats['tx_estimate_s']*1e3:.1f}ms")
