"""Continuous in-flight batching vs block-to-completion, side by side.

The same smoke-scale LM serves the same Poisson arrival schedule twice
through ``CollaborativeEngine.serve_continuous``:

* ``refill=False`` — PR 3 block-to-completion: a block of up to
  ``max_slots`` prompts is admitted only when the slot table is EMPTY
  and runs until every member finishes.  One long sequence holds the
  whole block hostage, and arrivals wait a full block.
* ``refill=True``  — continuous batching (ROADMAP item 1): finished
  rows evict between decode steps and queued prompts prefill into the
  freed slots of the LIVE batch, so short requests exit in their own
  time.

Both runs execute real decode steps; the engine lays the measured
wall-clock onto the virtual arrival schedule, so the printed latencies
are comparable and deterministic in shape (absolute numbers vary with
the machine).  The per-sequence outputs are bit-for-bit identical
between the two modes — batching never changes what a row computes,
only when it runs (tests/test_continuous_batching.py pins this).

Run:  PYTHONPATH=src python examples/continuous_serving.py
(REPRO_SMOKE=1 shrinks the schedule for the examples smoke test.)
"""

import os

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.models.model import LM
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import ContinuousGenerationSession

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))
N_REQ = 10 if SMOKE else 32
MAX_SLOTS = 4
MAX_NEW = 10

print("== building the slot-table session (smoke-scale qwen3 family) ==")
cfg = smoke_config("qwen3-8b")
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(7)
prompts = [rng.integers(3, cfg.vocab_size,
                        size=int(rng.integers(2, 12))).astype(np.int32)
           for _ in range(N_REQ)]
arrivals = np.cumsum(rng.exponential(1 / 30.0, N_REQ))
npu = DeviceProfile("npu", LinearLatencyModel(0.0, 0.0, 0.01), 0.0)

for refill in (False, True):
    session = ContinuousGenerationSession(
        model, params, max_slots=MAX_SLOTS,
        max_len=max(len(p) for p in prompts) + MAX_NEW + 8)
    # warm the admission shapes, then reset the table for the clean run
    session.serve(prompts, max_new=MAX_NEW, refill=refill)
    session.reset()
    engine = CollaborativeEngine(
        n2m=LinearN2M(1.0, 0.0),
        tiers=[Tier(npu, name="npu", servers=1, queue_capacity=256,
                    batch_size=MAX_SLOTS, continuous_session=session)],
        seed=7)
    results = engine.serve_continuous(prompts, arrival_s=arrivals,
                                      max_new=MAX_NEW, refill=refill)
    s = engine.stats()
    mode = "continuous (refill=True) " if refill \
        else "block-to-completion     "
    print(f"  {mode} p50={s['p50_latency_s']*1e3:7.1f}ms "
          f"p95={s['p95_latency_s']*1e3:7.1f}ms  "
          f"steps={session.n_steps} prefill waves={session.n_prefills} "
          f"peak live={session.peak_live}")
