"""Serve a reduced big-stack architecture with batched requests.

Resolves the qwen3-8b FAMILY at smoke scale through the unified model
registry (2 layers, d_model 256 — the full config is exercised by the
multi-pod dry-run) and runs batched prefill + greedy decode through the
serving runtime, then routes a mixed request stream through the C-NMT
engine with the big model as the cloud tier and rwkv6-family
(O(1)-state decode) as the edge tier.

When more than one JAX device is visible (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=4``), the cloud-tier
qwen session is built SHARDED over a (data, model) mesh via
``runtime.sharded.make_sharded_session`` — same decode tokens, more
devices.

Run:  PYTHONPATH=src python examples/big_model_serving.py
(REPRO_SMOKE=1 shrinks the routed stream for the examples smoke test.)
"""

import os
import time

import jax
import numpy as np

from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.launch.mesh import make_host_mesh
from repro.models.registry import resolve
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import GenerationSession, build_executor
from repro.runtime.sharded import make_sharded_session

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))
N_REQ = 6 if SMOKE else 20

print("== batched serving with the big-model runtime (smoke scale) ==")
cloud_r = resolve("qwen3-8b")             # size="smoke" is the default
model, cfg = cloud_r.model, cloud_r.cfg
params = model.init(jax.random.PRNGKey(0))
if len(jax.devices()) >= 4:
    mesh = make_host_mesh((2, 2))
    sess = make_sharded_session(model, params, mesh, max_len=48,
                                batch_size=4)
    print(f"  qwen tier sharded over a 2x2 mesh (layout={sess.layout})")
else:
    sess = GenerationSession(model, params, max_len=48)

rng = np.random.default_rng(0)
prompts = rng.integers(4, cfg.vocab_size, (4, 12)).astype(np.int32)
t0 = time.perf_counter()
out = sess.generate(prompts, max_new=8)
print(f"  generated {out.shape} tokens in {time.perf_counter()-t0:.2f}s "
      f"(includes jit)")
t0 = time.perf_counter()
out = sess.generate(prompts, max_new=8)
print(f"  warm generate: {time.perf_counter()-t0:.3f}s for 4x8 tokens")

print("\n== C-NMT routing between two model tiers ==")
edge_r = resolve("rwkv6_3b")              # underscores normalize too
edge_params = edge_r.model.init(jax.random.PRNGKey(1))
edge_sess = GenerationSession(edge_r.model, edge_params, max_len=48)
edge_exec = build_executor(edge_sess, kind="solo", max_new=8,
                           vocab_clip=edge_r.cfg.vocab_size)
cloud_exec = build_executor(sess, kind="solo", max_new=8,
                            vocab_clip=cfg.vocab_size)

profile = make_profile("cp2", seed=3)
engine = CollaborativeEngine(
    tiers=[
        Tier(DeviceProfile("edge-rwkv", LinearLatencyModel(1e-4, 2e-3, 0.01)),
             executor=edge_exec),
        Tier(DeviceProfile("pod-qwen", LinearLatencyModel(2e-5, 4e-4, 0.002)),
             executor=cloud_exec, rtt_fn=profile.rtt_at),
    ],
    n2m=LinearN2M(0.7, 1.0), seed=0)

for i in range(N_REQ):
    n_len = int(rng.integers(4, 40))
    engine.submit(rng.integers(4, 256, (n_len,)).astype(np.int32),
                  now_s=float(i))
s = engine.stats()
print(f"  {N_REQ} requests: mean {s['mean_latency_s']*1e3:.1f}ms, "
      f"offloaded {s['offload_frac']*100:.0f}% to the pod tier")
