"""Serve a reduced big-stack architecture with batched requests.

Instantiates the qwen3-8b FAMILY at smoke scale (2 layers, d_model 256 —
the full config is exercised by the multi-pod dry-run) and runs batched
prefill + greedy decode through the serving runtime, then routes a mixed
request stream through the C-NMT engine with the big model as the cloud
tier and rwkv6-family (O(1)-state decode) as the edge tier.

Run:  PYTHONPATH=src python examples/big_model_serving.py
(REPRO_SMOKE=1 shrinks the routed stream for the examples smoke test.)
"""

import os
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.latency_model import DeviceProfile, LinearLatencyModel
from repro.core.length_regressor import LinearN2M
from repro.core.profiles import make_profile
from repro.models.model import LM
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import GenerationSession

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))
N_REQ = 6 if SMOKE else 20

print("== batched serving with the big-model runtime (smoke scale) ==")
cfg = smoke_config("qwen3-8b")
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))
sess = GenerationSession(model, params, max_len=48)

rng = np.random.default_rng(0)
prompts = rng.integers(4, cfg.vocab_size, (4, 12)).astype(np.int32)
t0 = time.perf_counter()
out = sess.generate(prompts, max_new=8)
print(f"  generated {out.shape} tokens in {time.perf_counter()-t0:.2f}s "
      f"(includes jit)")
t0 = time.perf_counter()
out = sess.generate(prompts, max_new=8)
print(f"  warm generate: {time.perf_counter()-t0:.3f}s for 4x8 tokens")

print("\n== C-NMT routing between two model tiers ==")
edge_cfg = smoke_config("rwkv6-3b")
edge_model = LM(edge_cfg)
edge_params = edge_model.init(jax.random.PRNGKey(1))
edge_sess = GenerationSession(edge_model, edge_params, max_len=48)


def edge_exec(tokens):
    toks = np.asarray(tokens, np.int32)[None, :]
    res = edge_sess.generate(np.minimum(toks, edge_cfg.vocab_size - 1),
                             max_new=8)
    return res.shape[1], res[0]


profile = make_profile("cp2", seed=3)
engine = CollaborativeEngine(
    edge=Tier(DeviceProfile("edge-rwkv", LinearLatencyModel(1e-4, 2e-3, 0.01)),
              executor=edge_exec),
    cloud=Tier(DeviceProfile("pod-qwen", LinearLatencyModel(2e-5, 4e-4, 0.002))),
    n2m=LinearN2M(0.7, 1.0), rtt_fn=profile.rtt_at, seed=0)

for i in range(N_REQ):
    n_len = int(rng.integers(4, 40))
    engine.submit(rng.integers(4, 256, (n_len,)).astype(np.int32),
                  now_s=float(i))
s = engine.stats()
print(f"  {N_REQ} requests: mean {s['mean_latency_s']*1e3:.1f}ms, "
      f"offloaded {s['offload_frac']*100:.0f}% to the pod tier")
