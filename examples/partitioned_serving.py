"""Partitioned serving: encoder at the edge, decoder in the cloud.

PR 7's ``PlacementPlan`` generalizes C-NMT's whole-request tier choice:
the scheduler may place the encode and decode legs of ONE request on
DIFFERENT tiers, shipping the encoder states (n x d_model activations)
over the inter-tier backbone instead of paying the slow client<->cloud
link for the whole round trip.

Two parts:

1. The real split path on an actual seq2seq model: ``encode()`` at one
   tier produces an ``EncoderStates`` pytree, its exact wire payload is
   priced, and ``decode_from_states()`` at another tier finishes the
   translation — bit-for-bit identical to the fused path.
2. A modelled A/B: the same request stream through a 3-tier engine with
   splits disabled vs enabled.  The winning plan (encode at the edge,
   decode in the cloud) shows up in the stats as a strict latency win.

Run:  PYTHONPATH=src python examples/partitioned_serving.py
(REPRO_SMOKE=1 shrinks the streams for the examples smoke test.)
"""

import os

import jax
import numpy as np

from repro.core.latency_model import (ActivationCostModel, DeviceProfile,
                                      LinearLatencyModel)
from repro.core.length_regressor import LinearN2M
from repro.core.tx_estimator import LinkModel, TxEstimator
from repro.models.registry import resolve
from repro.runtime.engine import CollaborativeEngine, Tier
from repro.runtime.serving import build_executor

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))
N_REQ = 60 if SMOKE else 400

# ---------------------------------------------------------------- part 1
print("== real split execution: encode -> EncoderStates -> decode ==")
model = resolve("cnmt:de-en", scale=0.15, vocab=1000,
                max_decode_len=48).model
params = model.init(jax.random.PRNGKey(0))
encode_exec, decode_exec = build_executor(model, kind="split",
                                          params=params)
fused = model.make_translate_batched(params)

rng = np.random.default_rng(7)
src = rng.integers(3, 1000, size=24).astype(np.int32)
states = encode_exec(src)                      # "edge" leg
payload = states.payload_bytes()               # what the backbone ships
m_split, toks_split = decode_exec(states)      # "cloud" leg
lens_f, toks_f = fused(src[None, :])
m_fused = int(np.asarray(lens_f)[0])
same = (m_split == m_fused and np.array_equal(
    toks_split, np.asarray(toks_f, np.int32)[0, :max(m_fused, 1)]))
print(f"  n={src.size} -> EncoderStates payload {payload} bytes "
      f"({payload / src.size:.0f} B/token)")
print(f"  split decode: m={m_split}, fused: m={m_fused}, "
      f"tokens identical: {same}")
assert same, "split path diverged from the fused path"

# ---------------------------------------------------------------- part 2
print("== modelled 3-tier A/B: whole-only vs split-capable routing ==")
# device: no network, slow decode; edge: cheap encoder on a 5 ms LAN;
# cloud: 25x faster decode behind a 90 ms WAN.  A 100 Mbps backbone
# connects edge -> cloud: the classic split regime.
DEV = LinearLatencyModel(3e-4, 5e-3, 2e-3)
EDGE = LinearLatencyModel(2e-5, 2.5e-3, 4e-3)
CLOUD = LinearLatencyModel(1e-5, 1e-4, 2e-3)
BACKBONE_RTT, BACKBONE_BW = 4e-3, 1e9


def build_engine(allow_split: bool) -> CollaborativeEngine:
    links = LinkModel(3)
    links.add_link(1, 2, TxEstimator(init_rtt_s=BACKBONE_RTT,
                                     bandwidth_bps=BACKBONE_BW))
    return CollaborativeEngine(
        n2m=LinearN2M(1.0, 0.0),
        tiers=[
            Tier(DeviceProfile("dev", DEV, 0.05), name="dev"),
            Tier(DeviceProfile("edge", EDGE, 0.05), name="edge",
                 rtt_fn=lambda t: 5e-3, bandwidth_bps=200e6),
            Tier(DeviceProfile("cloud", CLOUD, 0.05), name="cloud",
                 rtt_fn=lambda t: 90e-3, bandwidth_bps=20e6),
        ],
        links=links,
        inter_rtt_fns={(1, 2): lambda t: BACKBONE_RTT},
        activation=ActivationCostModel(d_model=512, dtype_bytes=4),
        allow_split=allow_split,
        seed=0)


lens = rng.integers(24, 160, N_REQ)
arrivals = np.cumsum(rng.exponential(0.2, N_REQ))
stats = {}
for mode, split in (("whole-only", False), ("split-capable", True)):
    eng = build_engine(split)
    for i in range(N_REQ):
        toks = np.ones(int(lens[i]), np.int32)
        eng.submit(toks, now_s=float(arrivals[i]))
    s = eng.stats()
    stats[mode] = s
    frac = "  ".join(f"{k}={v*100:.0f}%" for k, v in s["tier_frac"].items())
    print(f"  {mode:14s} mean {s['mean_latency_s']*1e3:6.1f}ms  "
          f"p95 {s['p95_latency_s']*1e3:6.1f}ms  splits {s['split']}")
    print(f"  {'':14s} routed: {frac}")

gain = (1.0 - stats["split-capable"]["mean_latency_s"]
        / stats["whole-only"]["mean_latency_s"]) * 100.0
print(f"  split-capable routing cut mean latency by {gain:.1f}% "
      f"({stats['split-capable']['split']}/{N_REQ} requests split)")
