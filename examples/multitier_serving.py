"""Three-tier collaborative serving: a REAL BiLSTM seq2seq at the edge
gateway between a modelled on-device NPU below it and a modelled
continuous-batching cloud pod above it, with live queue-aware C-NMT
routing and deadline-aware admission control.

The generalized rule argmin_k [T_queue,k + T_tx,k + T_exe,k(N, M_hat)]
routes each of 300 requests; a mid-run burst (10 near-simultaneous
arrivals) shows the queue term diverting traffic off the busy gateway —
something the paper's two-device, load-blind Eq. (1) cannot express.
The cloud pod serves batches of up to 8 (sub-linear batch cost), and a
second, harsher Poisson burst arrives with a tight per-request SLO: the
engine sheds what no tier can finish in time instead of letting the
queues poison every later request, and stats() reports SLO attainment.

Run:  PYTHONPATH=src python examples/multitier_serving.py
(REPRO_SMOKE=1 shrinks both request streams for the examples smoke test.)
"""

import os
import time

import jax
import numpy as np

from repro.core.calibration import make_edge_cloud_pair, measure_seq2seq_grid
from repro.core.latency_model import DeviceProfile
from repro.core.length_regressor import LinearN2M, prefilter_pairs
from repro.core.profiles import make_profile
from repro.data.synthetic import make_corpus
from repro.models.registry import resolve
from repro.runtime.engine import CollaborativeEngine, Tier

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))
N_REQ = 60 if SMOKE else 300          # main stream length
BURST_AT = N_REQ // 2                 # 10 back-to-back arrivals start here
N_SLO = 40 if SMOKE else 200          # overload-burst length

print("== calibrating the edge model (real measurements) ==")
_r = resolve("cnmt:de-en", scale=0.15, vocab=1000, max_decode_len=64)
model, pair = _r.model, _r.pair
params = model.init(jax.random.PRNGKey(0))
translate = model.make_translate(params)
n, m, t = measure_seq2seq_grid(
    lambda toks, fl: translate(toks, forced_len=fl),
    (4, 8, 16, 32), lambda nn: [max(2, int(0.5 * nn)), nn, 2 * nn],
    reps=1, vocab=1000)
edge_prof, cloud_prof = make_edge_cloud_pair(n, m, t, speedup=6.0)
# the on-device NPU sits below the gateway: 3x slower, but zero network
npu_prof = DeviceProfile("npu", edge_prof.model.scaled(1 / 3.0), 0.05)

corpus = make_corpus("de-en", 2300, seed=2, with_tokens=True)
fit, eval_ = corpus.split(2000)
nf, mf = prefilter_pairs(fit.n, fit.m_real)
n2m = LinearN2M().fit(nf, mf)
lan = make_profile("cp2", seed=2)
wan = make_profile("cp1", seed=2)

engine = CollaborativeEngine(
    tiers=[
        Tier(npu_prof, name="npu", servers=1, queue_capacity=4),
        Tier(edge_prof, executor=lambda toks: translate(toks),
             name="edge-gw", rtt_fn=lambda t: float(lan.rtt_at(t)) * 0.1,
             servers=1, queue_capacity=16),
        Tier(cloud_prof, name="cloud-pod",
             rtt_fn=lambda t: float(wan.rtt_at(t)) * 0.2, servers=4,
             queue_capacity=16, batch_size=8, per_seq_overhead_s=2e-3),
    ],
    n2m=n2m, seed=0, refit_interval=100)

print(f"== streaming {N_REQ} requests (mid-run burst) ==")
t0 = time.perf_counter()
for i in range(N_REQ):
    # a burst of 10 back-to-back arrivals mid-run saturates the gateway
    now = BURST_AT * 0.5 + (i - BURST_AT) * 0.005 \
        if BURST_AT <= i < BURST_AT + 10 else i * 0.5
    engine.submit(eval_.src[i][:64], now_s=now)
wall = time.perf_counter() - t0
s = engine.stats()
frac = "  ".join(f"{k}={v*100:.0f}%" for k, v in s["tier_frac"].items())
print(f"  mean latency {s['mean_latency_s']*1e3:.1f}ms  "
      f"p95 {s['p95_latency_s']*1e3:.1f}ms  "
      f"mean wait {s['mean_wait_s']*1e3:.2f}ms  (wall {wall:.1f}s)")
print(f"  routed: {frac}")
burst = [r for r in engine.results if BURST_AT <= r.req_id < BURST_AT + 10]
print(f"  burst tiers: {[r.tier_name for r in burst]}")
print(f"  tx estimate now: {s['tx_estimate_s']*1e3:.1f}ms, "
      f"refits: {engine.calibrator.n_refits}")

print("== Poisson overload burst with an 80 ms SLO (deadline shedding) ==")
rate = 10_000.0
rng = np.random.default_rng(5)
t_burst = N_REQ * 0.5 + 50.0 + np.cumsum(
    rng.exponential(1 / rate, size=N_SLO))
slo_results = []
for j, now in enumerate(t_burst):
    slo_results.append(engine.submit(eval_.src[100 + j % 200][:64],
                                     now_s=float(now), deadline_s=0.08))
served = [r for r in slo_results if not r.shed]
shed = [r for r in slo_results if r.shed]
met = [r for r in served if r.slo_met]
s2 = engine.stats()
print(f"  burst of {len(slo_results)} @{rate:.0f}/s: served {len(served)} "
      f"({len(met)} within SLO), shed {len(shed)} "
      f"(admission predicted a certain miss)")
print(f"  overall SLO attainment {s2['slo_attainment']*100:.1f}%  "
      f"shed total {s2['shed']}  rejected(force-enqueued) {s2['rejected']}")
