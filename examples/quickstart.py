"""Quickstart: the C-NMT collaborative-inference decision in ~40 lines.

Builds the paper's pipeline from the public API: synthetic parallel
corpus -> N->M length regressor -> per-device latency planes -> the
CI decision rule routing requests between an edge gateway and a cloud
server over a time-varying connection.

Run:  PYTHONPATH=src python examples/quickstart.py
(REPRO_SMOKE=1 shrinks the corpus for the examples smoke test.)
"""

import os

import numpy as np

from repro.core import (
    CNMTScheduler,
    DeviceProfile,
    LinearLatencyModel,
    LinearN2M,
    TxEstimator,
    prefilter_pairs,
)
from repro.core.profiles import make_profile
from repro.data.synthetic import make_corpus

SMOKE = bool(int(os.environ.get("REPRO_SMOKE", "0")))

# 1. fit the N->M length regressor on (pre-filtered) corpus pairs
corpus = make_corpus("en-zh", 2000 if SMOKE else 20_000, seed=0)
n, m = prefilter_pairs(corpus.n, corpus.m_real)
n2m = LinearN2M().fit(n, m)
print(f"N->M fit: gamma={n2m.gamma:.3f} delta={n2m.delta:.2f} "
      f"(paper Fig. 3: gamma<1 for EN->ZH)")

# 2. device latency planes: T = alpha_N*N + alpha_M*M + beta  (Eq. 2)
edge = DeviceProfile("edge-gw", LinearLatencyModel(5e-4, 9e-3, 0.010))
cloud = DeviceProfile("cloud", edge.model.scaled(5.0))   # 5x faster

# 3. the CI decision rule (Eq. 1) with online RTT tracking
sched = CNMTScheduler(edge=edge, cloud=cloud, n2m=n2m)
profile = make_profile("cp1", seed=0)
tx = TxEstimator(init_rtt_s=float(profile.rtt_at(0.0)))

print(f"\n{'N':>4s} {'M_hat':>6s} {'T_edge':>8s} {'T_cloud':>8s} route")
for t_now, n_in in [(0.0, 4), (10.0, 12), (20.0, 30), (30.0, 80),
                    (40.0, 150)]:
    d = sched.decide(n_in, t_now, tx)
    print(f"{n_in:4d} {d.m_hat:6.1f} {d.t_edge_pred*1e3:7.1f}ms "
          f"{d.t_cloud_pred*1e3:7.1f}ms "
          f"{'EDGE' if d.device == 0 else 'CLOUD'}")
